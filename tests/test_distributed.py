"""Multi-device semantics (subprocesses with forced host-platform devices;
the main pytest process keeps the real 1-CPU view)."""
from __future__ import annotations

import pytest


def test_sharded_train_step_matches_single_device(subproc):
    subproc("""
import warnings; warnings.filterwarnings('ignore')
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.launch.mesh import make_mesh
from repro.distributed.api import use_mesh
from repro.launch.train import make_train_step, init_state
from repro.data import ShardedLoader
from repro.optim import get_schedule

cfg = get_config('tiny-dense')
sched = get_schedule('cosine', 1e-3, 5, 50)
loader = ShardedLoader(cfg.vocab_size, 8, 32, seed=4)

# single device reference
_, sf, _, _ = make_train_step(cfg, schedule=sched, zero1=False)
params, opt = init_state(cfg, 0, zero1=False)
step = sf(jax.eval_shape(lambda: jax.tree.map(jnp.asarray, loader.batch(0))))
losses_1 = []
for i in range(3):
    params, opt, m = step(params, opt, loader.batch(i), i)
    losses_1.append(float(m['loss']))

# 8-device (2 data x 4 model) mesh
mesh = make_mesh((2, 4), ('data', 'model'))
with use_mesh(mesh):
    _, sf, _, _ = make_train_step(cfg, schedule=sched, zero1=True)
    params, opt = init_state(cfg, 0)
    step = sf(jax.eval_shape(lambda: jax.tree.map(jnp.asarray, loader.batch(0))))
    losses_8 = []
    for i in range(3):
        params, opt, m = step(params, opt, loader.batch(i), i)
        losses_8.append(float(m['loss']))

np.testing.assert_allclose(losses_1, losses_8, rtol=2e-4, atol=2e-4)
print('OK', losses_1, losses_8)
""", n_devices=8)


def test_pipeline_parallel_exact(subproc):
    subproc("""
import warnings; warnings.filterwarnings('ignore')
import jax, jax.numpy as jnp, numpy as np
from repro.distributed.pipeline import pipeline_apply
from repro.launch.mesh import make_mesh

mesh = make_mesh((4,), ('pipe',))
L, B, D = 8, 8, 16
key = jax.random.PRNGKey(0)
params = {'w': jax.random.normal(key, (L, D, D)) * 0.2,
          'b': jax.random.normal(key, (L, D)) * 0.1}
def block(p, x):
    return jnp.tanh(x @ p['w'] + p['b'])
x = jax.random.normal(key, (B, D))
def ref(params, x):
    def body(c, p):
        return block(p, c), None
    return jax.lax.scan(body, x, params)[0]
want = ref(params, x)
for n_micro in (2, 4, 8):
    got = pipeline_apply(block, params, x, mesh=mesh, n_micro=n_micro)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
print('OK')
""", n_devices=4)


def test_compressed_psum_close_to_exact(subproc):
    subproc("""
import warnings; warnings.filterwarnings('ignore')
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import make_mesh
from repro.distributed.api import shard_map
from repro.optim import compressed_psum, ef_init

mesh = make_mesh((4,), ('data',))
key = jax.random.PRNGKey(0)
g = jax.random.normal(key, (4, 64))          # per-shard gradients

def fn(g_local, err):
    mean, new_err = compressed_psum({'g': g_local}, {'g': err}, ('data',))
    return mean['g'], new_err['g']

sharded = shard_map(fn, mesh=mesh, in_specs=(P('data'), P('data')),
                        out_specs=(P(), P('data')), check_vma=False)
got, err = sharded(g.reshape(4, 64), jnp.zeros((4, 64)))
want = g.mean(0)
err_inf = float(jnp.abs(got[0] - want).max())
scale = float(jnp.abs(g).max()) / 127.0
assert err_inf <= scale + 1e-6, (err_inf, scale)
print('OK', err_inf, scale)
""", n_devices=4)


def test_ef_compressed_training_converges(subproc):
    """EF-int8 DP training converges on a toy problem (within noise of
    exact all-reduce)."""
    subproc("""
import warnings; warnings.filterwarnings('ignore')
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import make_mesh
from repro.distributed.api import shard_map
from repro.optim import compressed_psum

mesh = make_mesh((4,), ('data',))
key = jax.random.PRNGKey(0)
X = jax.random.normal(key, (64, 16))
true_w = jax.random.normal(jax.random.PRNGKey(1), (16,))
y = X @ true_w

def local_grad(w, Xl, yl):
    return jax.grad(lambda w: jnp.mean((Xl @ w - yl) ** 2))(w)

def train(compressed):
    w = jnp.zeros(16)
    err = jnp.zeros((4, 16))
    for i in range(150):
        def step(Xl, yl, errl):
            g = local_grad(w, Xl, yl)
            if compressed:
                m, ne = compressed_psum({'g': g}, {'g': errl}, ('data',))
                return m['g'], ne['g']
            return jax.lax.pmean(g, 'data'), errl
        sm = shard_map(step, mesh=mesh,
                           in_specs=(P('data'), P('data'), P('data')),
                           out_specs=(P(), P('data')), check_vma=False)
        g, err = sm(X, y, err)
        w = w - 0.1 * g[0] if g.ndim > 1 else w - 0.1 * g
    return float(jnp.mean((X @ w - y) ** 2))

exact = train(False)
comp = train(True)
assert comp < 1e-2, (exact, comp)
print('OK', exact, comp)
""", n_devices=4)


def test_moments_match_under_data_parallel(subproc):
    """Calibration moments accumulated from sharded activations equal the
    host computation (the psum-merge property, via XLA auto-reduction)."""
    subproc("""
import warnings; warnings.filterwarnings('ignore')
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.launch.mesh import make_mesh
from repro.distributed.api import use_mesh
from repro.core.moments import init_moments, update_moments, finalize

mesh = make_mesh((4,), ('data',))
key = jax.random.PRNGKey(0)
x = jax.random.normal(key, (64, 16))
y = jax.random.normal(jax.random.PRNGKey(1), (64, 16))
host = finalize(update_moments(init_moments(16, 16), x, y))
with use_mesh(mesh):
    xs = jax.device_put(x, NamedSharding(mesh, P('data')))
    ys = jax.device_put(y, NamedSharding(mesh, P('data')))
    mom = jax.jit(lambda a, b: update_moments(init_moments(16, 16), a, b))(xs, ys)
    dist = finalize(jax.device_get(mom))
for k in ('cxx', 'cyx', 'cypyp'):
    np.testing.assert_allclose(host[k], dist[k], rtol=1e-4, atol=1e-4)
print('OK')
""", n_devices=4)


def test_elastic_checkpoint_reshard(subproc):
    """Save params on a (2,4) mesh, restore onto (4,2) and (1,) — elastic."""
    subproc("""
import warnings; warnings.filterwarnings('ignore')
import tempfile
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding
from repro.launch.mesh import make_mesh
from repro.distributed.api import use_mesh
from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.distributed.sharding import param_specs, named
from repro.models import init_params

cfg = get_config('tiny-dense')
params = init_params(jax.random.PRNGKey(0), cfg)
with tempfile.TemporaryDirectory() as d:
    m1 = make_mesh((2, 4), ('data', 'model'))
    with use_mesh(m1):
        sh = named(param_specs(params), m1)
        p1 = jax.tree.map(jax.device_put, params, sh)
        mgr = CheckpointManager(d)
        mgr.save(1, p1)
    m2 = make_mesh((4, 2), ('data', 'model'))
    with use_mesh(m2):
        sh2 = named(param_specs(params), m2)
        flatsh = {}
        paths = jax.tree_util.tree_flatten_with_path(sh2)[0]
        for path, s in paths:
            key = '/'.join(str(getattr(p, 'key', getattr(p, 'idx', p))) for p in path)
            flatsh[key] = s
        step, p2 = mgr.restore_latest(params, sharding_fn=lambda k, l: flatsh[k])
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print('OK')
""", n_devices=8)
