"""Continuous-batching engine: parity with generate(), ragged admission,
slot recycling, NBL-aware admission budget."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.surgery import compress_config, nbl_variant
from repro.launch.engine import Engine
from repro.launch.scheduler import Scheduler, nbl_slot_budget
from repro.launch.serve import generate, serve_requests
from repro.models import init_params
from repro.models.kv_cache import cache_bytes


def _setup(arch="tiny-dense", seed=0):
    cfg = get_config(arch)
    params = init_params(jax.random.PRNGKey(seed), cfg)
    return cfg, params


def _ref(cfg, params, prompt, max_new):
    """Single-request greedy reference via the fixed-batch loop."""
    out = generate(cfg, params, jnp.asarray(prompt)[None], max_new=max_new)
    return np.asarray(out)[0]


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
            for n in lens]


# ------------------------------------------------------------- parity ------

@pytest.mark.parametrize("arch", ["tiny-dense", "tiny-swa", "tiny-mamba"])
def test_engine_parity_matches_generate(arch):
    """Greedy tokens from the continuous-batching engine match the
    single-request generate() loop, per request, across cache families
    (global attn / sliding-window ring / SSM state)."""
    cfg, params = _setup(arch)
    prompts = _prompts(cfg, [6, 10, 8])
    refs = [_ref(cfg, params, p, 5) for p in prompts]

    eng = Engine(cfg, params, max_len=20, n_slots=2)
    rids = [eng.submit(p, 5) for p in prompts]
    out = eng.run()
    for i, rid in enumerate(rids):
        np.testing.assert_array_equal(out[rid], refs[i], err_msg=f"req {i}")


def test_engine_parity_nbl_compressed():
    """The engine serves an NBL-compressed stack (linearized layers carry
    no cache slots) with exact parity to generate()."""
    cfg, _ = _setup()
    ncfg = compress_config(cfg, cfg.attn_layer_indices()[-2:], "nbl")
    params = init_params(jax.random.PRNGKey(1), ncfg)
    prompts = _prompts(ncfg, [7, 9])
    refs = [_ref(ncfg, params, p, 4) for p in prompts]

    eng = Engine(ncfg, params, max_len=16, n_slots=2)
    rids = [eng.submit(p, 4) for p in prompts]
    out = eng.run()
    for i, rid in enumerate(rids):
        np.testing.assert_array_equal(out[rid], refs[i])


# -------------------------------------------- ragged admission / stream ----

def test_ragged_admission_mid_stream():
    """More requests than slots, mixed prompt lengths: requests are admitted
    as slots free up mid-stream, every request completes, and concurrency
    never exceeds the slot pool."""
    cfg, params = _setup()
    lens = [4, 12, 6, 9, 5]
    prompts = _prompts(cfg, lens, seed=3)
    refs = [_ref(cfg, params, p, 4) for p in prompts]

    eng = Engine(cfg, params, max_len=20, n_slots=2)
    rids = [eng.submit(p, 4) for p in prompts]
    max_active = 0
    while eng.has_work:
        eng.step()
        max_active = max(max_active, len(eng.active_slots))
    out = {rid: np.asarray(r.tokens) for rid, r in eng.finished.items()}

    assert len(out) == len(prompts)          # all retired
    assert max_active <= 2
    assert eng.n_prefills == len(prompts)    # each admitted exactly once
    for i, rid in enumerate(rids):
        np.testing.assert_array_equal(out[rid], refs[i], err_msg=f"req {i}")


def test_late_submission_joins_running_batch():
    """A request submitted while the engine is mid-decode is admitted on a
    later step and still decodes correctly next to in-flight requests."""
    cfg, params = _setup()
    p1, p2 = _prompts(cfg, [8, 5], seed=7)
    r1 = _ref(cfg, params, p1, 6)
    r2 = _ref(cfg, params, p2, 4)

    eng = Engine(cfg, params, max_len=16, n_slots=2)
    rid1 = eng.submit(p1, 6)
    eng.step()                               # p1 prefilled + 1 decode
    eng.step()
    rid2 = eng.submit(p2, 4)                 # joins mid-stream
    out = eng.run()
    np.testing.assert_array_equal(out[rid1], r1)
    np.testing.assert_array_equal(out[rid2], r2)


# ----------------------------------------------------- slot recycling ------

def test_slot_recycling_no_stale_kv():
    """One slot, sequential tenancy: the second request's tokens must be
    identical to a fresh engine's — any stale KV/state left by the first
    tenant (longer prompt, fully filled cache) would corrupt them."""
    cfg, params = _setup()
    long_p, short_p = _prompts(cfg, [14, 4], seed=11)

    eng = Engine(cfg, params, max_len=20, n_slots=1)
    rid_a = eng.submit(long_p, 6)
    rid_b = eng.submit(short_p, 6)
    out = eng.run()
    assert len(out[rid_a]) == 6

    fresh = Engine(cfg, params, max_len=20, n_slots=1)
    rid_f = fresh.submit(short_p, 6)
    np.testing.assert_array_equal(out[rid_b], fresh.run()[rid_f])
    np.testing.assert_array_equal(out[rid_b],
                                  _ref(cfg, params, short_p, 6))


def test_eos_retires_early_and_slot_is_reused():
    cfg, params = _setup()
    p1, p2 = _prompts(cfg, [6, 9], seed=5)
    ref1 = _ref(cfg, params, p1, 8)
    eos = int(ref1[2])                       # some token generate() emits
    stop = int(np.argmax(ref1 == eos)) + 1   # engine must stop at FIRST hit

    eng = Engine(cfg, params, max_len=20, n_slots=1, eos_id=eos)
    rid1 = eng.submit(p1, 8)
    rid2 = eng.submit(p2, 3)
    out = eng.run()
    assert list(out[rid1]) == list(ref1[:stop])   # eos inclusive, early
    assert len(out[rid1]) < 8
    assert len(out[rid2]) <= 3               # second tenant ran after


# ------------------------------------------------- NBL-aware admission -----

def test_reset_slot_scrubs_one_row():
    """reset_slot invalidates exactly the given slot: kpos -> -1, state
    leaves -> 0; other slots untouched."""
    import jax.tree_util as jtu
    from repro.models import prefill
    from repro.models.kv_cache import assign_slot, init_slot_cache, reset_slot

    cfg, params = _setup()
    prompts = _prompts(cfg, [6, 6], seed=21)
    slot_cache = init_slot_cache(cfg, 2, 12)
    for slot, p in enumerate(prompts):
        _, pc = prefill(cfg, params, jnp.asarray(p)[None], cache_len=12)
        slot_cache = assign_slot(slot_cache, pc, jnp.int32(slot))
    scrubbed = reset_slot(slot_cache, jnp.int32(0))
    for (path, got), (_, was) in zip(
            jtu.tree_flatten_with_path(scrubbed)[0],
            jtu.tree_flatten_with_path(slot_cache)[0]):
        name = str(getattr(path[-1], "key", ""))
        want0 = -1 if name == "kpos" else 0
        assert (np.asarray(got[:, 0]) == want0).all(), (path, "row 0")
        np.testing.assert_array_equal(np.asarray(got[:, 1]),
                                      np.asarray(was[:, 1]))  # row 1 intact


def test_engine_budget_clamps_explicit_n_slots():
    """cache_budget_bytes is a ceiling even when n_slots is also given."""
    cfg, params = _setup()
    budget = 2 * cache_bytes(cfg, 1, 16)
    eng = Engine(cfg, params, max_len=16, n_slots=64,
                 cache_budget_bytes=budget)
    assert eng.n_slots == 2
    with pytest.raises(ValueError):
        Engine(cfg, params, max_len=16, n_slots=0)


def test_nbl_slot_budget_monotone_in_m():
    """Fixed byte budget: linearizing more layers -> more concurrent slots
    (the paper's (K-m)/K cache saving, converted to admission)."""
    cfg, _ = _setup()
    max_len = 128
    budget = 4 * cache_bytes(cfg, 1, max_len)   # 4 slots at m=0
    slots = []
    for m in range(0, 4):
        slots.append(nbl_slot_budget(nbl_variant(cfg, m), budget, max_len))
    assert slots[0] == 4
    assert slots == sorted(slots)               # monotone non-decreasing
    assert slots[-1] > slots[0]                 # strictly more by m=3 (K=6)


def test_more_slots_fewer_decode_sweeps():
    """The throughput mechanism: at fixed work, a bigger slot pool drains
    the queue in fewer batched decode steps."""
    cfg, params = _setup()
    prompts = _prompts(cfg, [5, 5, 5, 5], seed=9)
    steps = {}
    for n_slots in (1, 4):
        eng = Engine(cfg, params, max_len=12, n_slots=n_slots)
        for p in prompts:
            eng.submit(p, 4)
        eng.run()
        steps[n_slots] = eng.n_decode_steps
    assert steps[4] < steps[1]


def test_serve_requests_wrapper():
    cfg, params = _setup()
    prompts = _prompts(cfg, [6, 10], seed=13)
    refs = [_ref(cfg, params, p, 4) for p in prompts]
    outs, stats = serve_requests(cfg, params, prompts, max_new=4, n_slots=2)
    for got, want in zip(outs, refs):
        np.testing.assert_array_equal(got, want)
    assert stats["n"] == 2 and stats["n_slots"] == 2


def test_engine_sharded_parity(subproc):
    """The engine under a (data, model) mesh — params/caches sharded with
    their production specs — emits the same greedy tokens as the unmeshed
    single-request reference."""
    subproc("""
import warnings; warnings.filterwarnings('ignore')
import jax, numpy as np, jax.numpy as jnp
from repro.configs import get_config
from repro.distributed.api import use_mesh
from repro.launch.mesh import make_mesh
from repro.launch.engine import Engine
from repro.launch.serve import generate
from repro.models import init_params

cfg = get_config('tiny-dense')
params = init_params(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(0)
prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
           for n in (6, 9, 7)]
refs = [np.asarray(generate(cfg, params, jnp.asarray(p)[None],
                            max_new=4))[0] for p in prompts]
with use_mesh(make_mesh((2, 2), ('data', 'model'))):
    eng = Engine(cfg, params, max_len=16, n_slots=2)
    rids = [eng.submit(p, 4) for p in prompts]
    out = eng.run()
for i, r in enumerate(rids):
    np.testing.assert_array_equal(out[r], refs[i])
print('OK')
""", n_devices=4)


@pytest.mark.parametrize("paged", [False, True])
def test_direct_scheduler_submit_overflow_rejected(paged):
    """Regression: a request submitted DIRECTLY to the scheduler (the
    benchmark construction path) with prompt + max_new > max_len used to
    bypass Engine.submit's guard and decode past max_len — a host-side
    IndexError into the page table mid-serve. The engine now validates at
    admission: the oversize request is rejected (error set, excluded from
    latency percentiles) and every other request still completes."""
    from repro.launch.scheduler import latency_stats

    cfg, params = _setup()
    good, big = _prompts(cfg, [5, 14], seed=17)
    ref = _ref(cfg, params, good, 4)

    kw = dict(paged=True, page_size=4) if paged else {}
    eng = Engine(cfg, params, max_len=16, n_slots=1, **kw)
    rid_bad = eng.scheduler.submit(big, 10)      # 14 + 10 > 16
    rid_ok = eng.submit(good, 4)
    out = eng.run(max_steps=200)                 # must not raise
    np.testing.assert_array_equal(out[rid_ok], ref)
    assert eng.n_rejected == 1
    bad = eng.finished[rid_bad]
    assert bad.error is not None and "max_len" in bad.error
    assert len(bad.tokens) == 0
    s = latency_stats(list(eng.finished.values()))
    assert s["n"] == 1 and s["n_rejected"] == 1  # percentiles exclude it
    # Engine.submit shares the same reject-with-error surface (PR 5): the
    # oversize submission is RECORDED with a rid instead of raising, so a
    # serving host loop never dies on it; strict=True keeps the raise.
    rid_eager = eng.submit(big, 10)
    assert eng.finished[rid_eager].error is not None
    assert eng.n_rejected == 2
    with pytest.raises(ValueError):
        eng.submit(big, 10, strict=True)


def test_scheduler_fifo_and_prefill_cap():
    sched = Scheduler(max_prefill_per_step=2)
    for i in range(5):
        sched.submit(np.array([1, 2, 3]), 4)
    got = sched.admit(free_slots=4)
    assert [r.rid for r in got] == [0, 1]     # capped at 2 despite 4 free
    got = sched.admit(free_slots=1)
    assert [r.rid for r in got] == [2]
    assert len(sched) == 2


def test_scheduler_token_budget_paces_admission():
    """Regression: the request-count cap admits several long prompts into
    one step (their serial prefills stall every in-flight decode); the
    TOKEN budget stops admission before the step's prompt tokens exceed it
    — while the queue HEAD always admits, so an over-budget prompt can
    never starve the queue."""
    sched = Scheduler(max_prefill_per_step=4,
                      max_prefill_tokens_per_step=10)
    for n in (8, 8, 3, 2):
        sched.submit(np.arange(n), 4)
    got = sched.admit(free_slots=4)
    assert [r.rid for r in got] == [0]        # 8 + 8 > 10: stop after head
    got = sched.admit(free_slots=4)
    assert [r.rid for r in got] == [1]        # 8 + 3 > 10
    got = sched.admit(free_slots=4)
    assert [r.rid for r in got] == [2, 3]     # 3 + 2 <= 10
    # an over-budget head request still admits (no starvation)
    sched.submit(np.arange(64), 1)
    assert [r.rid for r in sched.admit(free_slots=4)] == [4]
    # request-count cap still binds under an ample token budget
    loose = Scheduler(max_prefill_per_step=2,
                      max_prefill_tokens_per_step=1000)
    for _ in range(4):
        loose.submit(np.array([1, 2]), 1)
    assert len(loose.admit(free_slots=4)) == 2
    with pytest.raises(ValueError):
        Scheduler(max_prefill_tokens_per_step=0)
