"""Per-architecture smoke tests (reduced configs, one fwd/train step on CPU,
shape + finite checks) and decode-vs-full-forward consistency."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (ASSIGNED_ARCHS, PAPER_ARCHS, get_config, reduced,
                           SHAPES, shape_applicable)
from repro.models import (apply, count_params, decode_step, init_cache,
                          init_params, loss_fn, prefill)

ALL_ARCHS = ASSIGNED_ARCHS + PAPER_ARCHS


def _batch(cfg, b=2, s=32, seed=0):
    key = jax.random.PRNGKey(seed)
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    labels = jnp.concatenate(
        [toks[:, 1:], jnp.full((b, 1), -1, toks.dtype)], axis=1)
    batch = {"tokens": toks, "labels": labels}
    if cfg.family == "vlm":
        batch["enc"] = jax.random.normal(
            key, (b, cfg.n_frontend_tokens, cfg.d_model),
            jnp.dtype(cfg.compute_dtype))
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_smoke_forward_and_grad(arch):
    """Reduced same-family config: one loss+grad step, shapes, no NaNs."""
    cfg = reduced(get_config(arch))
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    (loss, m), grads = jax.jit(jax.value_and_grad(
        lambda p, b: loss_fn(cfg, p, b), has_aux=True))(params, batch)
    assert np.isfinite(float(loss)), arch
    gn = sum(float(jnp.sum(g.astype(jnp.float32) ** 2))
             for g in jax.tree.leaves(grads)) ** 0.5
    assert np.isfinite(gn) and gn > 0, arch
    logits, _ = jax.jit(lambda p, t, e: apply(cfg, p, t, enc=e))(
        params, batch["tokens"], batch.get("enc"))
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_smoke_decode(arch):
    cfg = reduced(get_config(arch))
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    logits, cache = jax.jit(lambda p, t, e: prefill(
        cfg, p, t, enc=e, cache_len=40))(params, batch["tokens"],
                                         batch.get("enc"))
    assert logits.shape == (2, 1, cfg.vocab_size)
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    logits2, cache = jax.jit(lambda p, t, c, i: decode_step(
        cfg, p, t, c, i))(params, tok, cache, jnp.int32(32))
    assert logits2.shape == (2, 1, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits2, np.float32)))


@pytest.mark.parametrize("arch", ["tiny-dense", "tiny-swa", "tiny-gemma",
                                  "tiny-mamba", "tiny-zamba"])
def test_decode_matches_full_forward(arch):
    """prefill(x[:n]) + decode steps reproduce apply(x) logits stepwise."""
    cfg = get_config(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    b, s, n_dec = 2, 24, 4
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                              cfg.vocab_size)
    full_logits, _ = apply(cfg, params, toks)

    pre = s - n_dec
    logits, cache = prefill(cfg, params, toks[:, :pre], cache_len=s)
    np.testing.assert_allclose(
        np.asarray(logits[:, 0]), np.asarray(full_logits[:, pre - 1]),
        atol=2e-3, rtol=2e-3)
    for i in range(pre, s):
        logits, cache = decode_step(cfg, params, toks[:, i:i + 1], cache,
                                    jnp.int32(i))
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]), np.asarray(full_logits[:, i]),
            atol=2e-3, rtol=2e-3)


def test_swa_ring_cache_bounded():
    """Sliding-window layers keep a ring cache of window size, not seq."""
    cfg = get_config("tiny-swa")     # window 32
    cache = init_cache(cfg, batch=2, max_len=128)
    k = cache["groups"][0]["blocks"][0]["k"]
    assert k.shape[3] == 32, k.shape  # (L, B, KV, W, hd)


def test_count_params_matches_init():
    for arch in ("tiny-dense", "tiny-moe", "tiny-mamba", "tiny-vlm"):
        cfg = get_config(arch)
        params = init_params(jax.random.PRNGKey(0), cfg)
        got = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
        assert got == count_params(cfg), arch


def test_moe_active_params_smaller():
    cfg = get_config("tiny-moe")
    assert count_params(cfg, active_only=True) < count_params(cfg)


def test_long_500k_applicability_gates():
    runs, skips = [], []
    for arch in ASSIGNED_ARCHS:
        ok, _ = shape_applicable(get_config(arch), SHAPES["long_500k"])
        (runs if ok else skips).append(arch)
    assert set(runs) == {"h2o-danube-3-4b", "zamba2-1.2b", "mamba2-2.7b"}
    assert len(skips) == 7


def test_full_configs_match_assignment():
    """The exact published numbers from the assignment block."""
    c = get_config("gemma2-2b")
    assert (c.n_blocks, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (26, 2304, 8, 4, 9216, 256_000)
    c = get_config("kimi-k2-1t-a32b")
    assert (c.n_blocks, c.d_model, c.moe.n_experts, c.moe.top_k) == \
        (61, 7168, 384, 8)
    assert c.n_params() > 0.9e12        # the trillion-param check
    c = get_config("mamba2-2.7b")
    assert c.n_blocks == 64 and c.ssm.d_state == 128 and c.n_heads == 0
    c = get_config("deepseek-moe-16b")
    assert c.moe.n_shared == 2 and c.moe.top_k == 6
    c = get_config("zamba2-1.2b")
    assert sum(b.kind == "mamba" for b in c.blocks()) == 38
    shared = [b for b in c.blocks() if b.shared]
    assert len(shared) == 6 and all(b.kind == "attn" for b in shared)
    c = get_config("llama-3.2-vision-11b")
    assert sum(b.kind == "cross_attn" for b in c.blocks()) == 8
    assert sum(b.kind == "attn" for b in c.blocks()) == 40
    c = get_config("musicgen-medium")
    assert (c.n_blocks, c.d_model, c.vocab_size) == (48, 1536, 2048)


def test_ring_cache_decode_beyond_window():
    """Decode 3× past the SWA window: the ring cache must keep exactly the
    last `window` tokens — logits must match a full-forward reference at
    every step (tiny-swa window=32)."""
    cfg = get_config("tiny-swa")
    params = init_params(jax.random.PRNGKey(0), cfg)
    b, pre, total = 1, 8, 72                     # 72 >> window 32
    toks = jax.random.randint(jax.random.PRNGKey(2), (b, total), 0,
                              cfg.vocab_size)
    full_logits, _ = apply(cfg, params, toks)
    logits, cache = prefill(cfg, params, toks[:, :pre], cache_len=total)
    np.testing.assert_allclose(np.asarray(logits[:, 0]),
                               np.asarray(full_logits[:, pre - 1]),
                               atol=2e-3, rtol=2e-3)
    for i in range(pre, total):
        logits, cache = decode_step(cfg, params, toks[:, i:i + 1], cache,
                                    jnp.int32(i))
        np.testing.assert_allclose(np.asarray(logits[:, 0]),
                                   np.asarray(full_logits[:, i]),
                                   atol=2e-3, rtol=2e-3)


def test_ring_cache_bounded_memory_long_decode():
    """The ring cache never grows past the window even when cache_len is
    huge — the structural property that makes long_500k feasible on SWA."""
    cfg = get_config("tiny-swa")
    cache = init_cache(cfg, batch=1, max_len=500_000)
    k = cache["groups"][0]["blocks"][0]["k"]
    assert k.shape[3] == 32, k.shape
