"""Optimizer, schedules, gradient compression (local math), data pipeline."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.data import ShardedLoader, ZipfMarkov, lm_batches
from repro.optim import (adamw_init, adamw_update, cosine_schedule,
                         dequantize_int8, global_norm_clip, quantize_int8,
                         wsd_schedule)


def test_adamw_converges_quadratic():
    params = {"w": jnp.ones((8,)) * 5.0}
    opt = adamw_init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    for i in range(200):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(g, opt, params, lr=0.1,
                                      weight_decay=0.0)
    assert float(loss(params)) < 1e-2


def test_global_norm_clip():
    g = {"a": jnp.ones((100,)) * 10.0}
    clipped, gn = global_norm_clip(g, 1.0)
    assert float(gn) == 100.0
    np.testing.assert_allclose(
        float(jnp.linalg.norm(clipped["a"])), 1.0, rtol=1e-5)


def test_schedules():
    cos = cosine_schedule(1.0, warmup=10, total=100)
    assert float(cos(0)) == 0.0
    assert float(cos(10)) == 1.0
    assert float(cos(100)) < float(cos(50)) < 1.0
    wsd = wsd_schedule(1.0, warmup=10, total=100, decay_frac=0.2)
    assert float(wsd(50)) == 1.0          # stable plateau
    assert float(wsd(99)) < 0.2           # decayed
    assert float(wsd(5)) == 0.5           # warming


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 1000), scale=st.floats(1e-6, 1e3))
def test_int8_quantize_roundtrip_error_bounded(seed, scale):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(256) * scale, jnp.float32)
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s) - x))
    assert err.max() <= float(s) / 2 + 1e-12    # half-ULP of the int8 grid


def test_error_feedback_unbiased_over_steps():
    """EF compensates: sum of sent messages ≈ sum of true gradients."""
    rng = np.random.default_rng(0)
    err = jnp.zeros(64)
    sent_total = np.zeros(64)
    true_total = np.zeros(64)
    for i in range(64):
        g = jnp.asarray(rng.standard_normal(64) * 0.1, jnp.float32)
        acc = g + err
        q, s = quantize_int8(acc)
        sent = dequantize_int8(q, s)
        err = acc - sent
        sent_total += np.asarray(sent)
        true_total += np.asarray(g)
    resid = np.abs(sent_total - true_total).max()
    assert resid <= float(np.abs(np.asarray(err)).max()) + 1e-6


def test_zipf_markov_deterministic_and_learnable():
    proc = ZipfMarkov(512, seed=0)
    a = proc.sample(4, 64, seed=7)
    b = proc.sample(4, 64, seed=7)
    np.testing.assert_array_equal(a, b)
    c = proc.sample(4, 64, seed=8)
    assert not np.array_equal(a, c)
    # successor structure present at the configured rate
    hits = (proc.succ[a[:, :-1]] == a[:, 1:]).mean()
    assert 0.4 < hits < 0.9, hits


def test_lm_batches_labels_shifted():
    b = next(iter(lm_batches(128, 2, 16, 1, seed=3)))
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
    assert np.all(b["labels"][:, -1] == -1)


def test_sharded_loader_partition_and_reassign():
    """Union of host shards == global batch; straggler reassignment is
    deterministic and complete."""
    gb, hosts = 16, 4
    loaders = [ShardedLoader(128, gb, 8, seed=1, host_index=h,
                             n_hosts=hosts) for h in range(hosts)]
    glob = loaders[0].global_batch_at(step=5)["tokens"]
    got = np.concatenate([ld.batch(5)["tokens"] for ld in loaders])
    np.testing.assert_array_equal(got, glob)
    # host 2 dies; host 0 covers its rows
    loaders[0].reassign(2)
    b0 = loaders[0].batch(5)["tokens"]
    np.testing.assert_array_equal(b0[4:8], loaders[2].batch(5)["tokens"][:4])


def test_elastic_restart_same_stream():
    """Re-partitioning the same step across a different host count yields
    the same global rows (host-count-elastic restarts)."""
    gb = 16
    a = ShardedLoader(128, gb, 8, seed=2, n_hosts=4).global_batch_at(3)
    b = ShardedLoader(128, gb, 8, seed=2, n_hosts=4)
    got = np.concatenate([
        ShardedLoader(128, gb, 8, seed=2, host_index=h, n_hosts=4).batch(3)
        ["tokens"] for h in range(4)])
    np.testing.assert_array_equal(got, a["tokens"])
