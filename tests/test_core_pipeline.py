"""End-to-end NBL pipeline on a trained model: the paper's qualitative
claims (NBL ≥ DROP at equal m; NBL approximation is locally faithful;
bound ranks layers sensibly)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import calibrate, drop_compress, nbl_compress, select_layers
from repro.data import calib_factory
from repro.eval import perplexity
from repro.launch.train import train
from repro.models import apply, init_params


@pytest.fixture(scope="module")
def trained():
    cfg = get_config("tiny-dense")
    out = train(cfg, steps=120, global_batch=16, seq=64, peak_lr=3e-3,
                log_fn=lambda s: None)
    return cfg, out["params"]


def test_nbl_beats_drop_at_equal_m(trained):
    """Table 2/3/4 ordering: Attn NBL-m ≥ Attn DROP-m (perplexity)."""
    cfg, params = trained
    fac = calib_factory(cfg, batch=4, seq=64, n_batches=6)
    evalfac = calib_factory(cfg, batch=4, seq=64, n_batches=4, seed=321)
    base = perplexity(cfg, params, evalfac)
    for m in (2, 3):
        ncfg, np_, _ = nbl_compress(cfg, params, fac, m)
        dcfg, dp_, _ = drop_compress(cfg, params, fac, m)
        nbl_ppl = perplexity(ncfg, np_, evalfac)
        drop_ppl = perplexity(dcfg, dp_, evalfac)
        assert nbl_ppl <= drop_ppl * 1.02, (m, nbl_ppl, drop_ppl)
        assert nbl_ppl < base * 1.5, (m, nbl_ppl, base)


def test_nbl_local_fidelity(trained):
    """Replacing the single best layer barely moves the output dist."""
    cfg, params = trained
    fac = calib_factory(cfg, batch=4, seq=64, n_batches=6)
    ncfg, nparams, rep = nbl_compress(cfg, params, fac, 1)
    toks = next(fac())["tokens"]
    l0, _ = apply(cfg, params, toks)
    l1, _ = apply(ncfg, nparams, toks)
    tv = 0.5 * float(jnp.abs(jax.nn.softmax(l0) - jax.nn.softmax(l1))
                     .sum(-1).mean())
    assert tv < 0.25, tv


def test_bound_correlates_with_true_nmse(trained):
    """Theorem 3.2 as a *criterion*: the bound's ranking should broadly
    agree with the achieved-NMSE ranking (rank corr > 0)."""
    cfg, params = trained
    fac = calib_factory(cfg, batch=4, seq=64, n_batches=6)
    calib = calibrate(cfg, params, fac)
    bounds = np.array([calib[i].bound for i in sorted(calib)])
    nmses = np.array([calib[i].nmse for i in sorted(calib)])
    assert np.all(nmses <= bounds + 1e-6)          # Thm 3.2 per layer
    rb = np.argsort(np.argsort(bounds)).astype(float)
    rn = np.argsort(np.argsort(nmses)).astype(float)
    corr = np.corrcoef(rb, rn)[0, 1]
    assert corr > 0.0, corr


def test_selection_picks_lowest_bound(trained):
    cfg, params = trained
    fac = calib_factory(cfg, batch=4, seq=64, n_batches=4)
    calib = calibrate(cfg, params, fac)
    sel = select_layers(calib, 2)
    best = sorted(calib, key=lambda i: calib[i].bound)[:2]
    assert set(sel) == set(best)


def test_block_nbl_runs(trained):
    cfg, params = trained
    fac = calib_factory(cfg, batch=4, seq=64, n_batches=4)
    ncfg, nparams, _ = nbl_compress(cfg, params, fac, 2, block=True)
    kinds = [b.kind for b in ncfg.blocks()]
    assert kinds.count("nbl_block") == 2
    evalfac = calib_factory(cfg, batch=2, seq=64, n_batches=2, seed=5)
    assert np.isfinite(perplexity(ncfg, nparams, evalfac))


def test_mamba_block_nbl_ablation():
    """NBL's 'any block' generality: linearize SSD mixers in the pure-SSM
    arch (the technique is inapplicable to attention there — DESIGN.md)."""
    cfg = get_config("tiny-mamba")
    params = init_params(jax.random.PRNGKey(0), cfg)
    fac = calib_factory(cfg, batch=2, seq=64, n_batches=3)
    ncfg, nparams, rep = nbl_compress(cfg, params, fac, 1,
                                      block_kinds=("mamba",))
    assert [b.kind for b in ncfg.blocks()].count("nbl") == 1
    evalfac = calib_factory(cfg, batch=2, seq=64, n_batches=2, seed=5)
    assert np.isfinite(perplexity(ncfg, nparams, evalfac))
