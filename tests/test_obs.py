"""Observability layer: registry/tracer/timeline units + engine wiring.

Unit coverage for the three obs subsystems (metrics registry with
Prometheus rendering, request tracer, step-timeline ring), then the
integration claims the layer is sold on:

* attaching ``Observability`` changes ZERO device work — a deterministic
  replay produces identical tokens and identical dispatch counts
  (decode sweeps, prefills, prefill tokens) with obs on vs off, in every
  engine mode;
* registry counters equal the engine's own counters after any replay;
* every request's span tree is well-formed (nested, terminated, no
  overlap) including cancellation in EVERY lifecycle state — queued,
  mid-chunking, decoding;
* the Chrome-trace export of a chunked+shared workload makes the
  prefill-decode interleaving claim visible: decode-carrying step events
  on the engine track overlap the window spanned by a request's chunk
  spans;
* ``Engine.stats()`` windowing keeps ``n`` = lifetime while clipping the
  percentile set to ``stats_window`` (and reporting ``window_n``).
"""
from __future__ import annotations

import json
import re

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.engine import Engine
from repro.models import init_params
from repro.obs import (
    LATENCY_BUCKETS, MetricsRegistry, Observability, StepRecord,
    StepTimeline, Tracer,
)

MAX_LEN = 32
PAGE_SIZE = 4

MODES = {
    "ring": {},
    "paged": dict(paged=True, page_size=PAGE_SIZE),
    "prefix": dict(paged=True, page_size=PAGE_SIZE, prefix_sharing=True),
    "chunked": dict(paged=True, page_size=PAGE_SIZE, chunked_prefill=True,
                    prefill_chunk_tokens=PAGE_SIZE),
    "chunked_shared": dict(paged=True, page_size=PAGE_SIZE,
                           chunked_prefill=True, prefix_sharing=True,
                           prefill_chunk_tokens=PAGE_SIZE),
}


# ------------------------------------------------------------- registry --

def test_counter_and_gauge():
    r = MetricsRegistry()
    c = r.counter("c_total", "help text")
    c.inc()
    c.inc(4)
    assert c.value == 5
    with pytest.raises(ValueError):
        c.inc(-1)
    g = r.gauge("g")
    g.set(7)
    g.add(-2)
    assert g.value == 5
    # factories are idempotent by name, and type mismatches are errors
    assert r.counter("c_total") is c
    with pytest.raises(ValueError):
        r.gauge("c_total")
    assert r.get("c_total") == 5 and r.get("nope") is None


def test_histogram_buckets_and_percentile():
    r = MetricsRegistry()
    h = r.histogram("h_seconds", buckets=(0.1, 1.0, 10.0))
    for v in (0.05, 0.5, 0.5, 5.0, 50.0):       # last lands in +Inf
        h.observe(v)
    assert h.count == 5 and h.sum == pytest.approx(56.05)
    snap = r.snapshot()["histograms"]["h_seconds"]
    assert snap["count"] == 5
    assert snap["buckets"] == [[0.1, 1], [1.0, 3], [10.0, 4]]
    # percentiles interpolate within the winning bucket and stay bounded
    assert 0.0 < h.percentile(50) <= 1.0
    assert h.percentile(100) == 10.0            # +Inf clamps to top bound
    assert MetricsRegistry().histogram("empty").percentile(99) == 0.0
    # the shared latency ladder is strictly ascending, 10 us .. 100 s
    assert list(LATENCY_BUCKETS) == sorted(set(LATENCY_BUCKETS))
    assert LATENCY_BUCKETS[0] == pytest.approx(1e-5)
    assert LATENCY_BUCKETS[-1] == pytest.approx(1e2)


def test_prometheus_rendering_parses():
    r = MetricsRegistry(labels={"engine_mode": "paged"})
    r.bind(nbl_m="2", engine_mode="clobber-must-not-win")
    r.counter("x_total", "a counter").inc(3)
    r.gauge("g").set(1.5)
    h = r.histogram("lat_seconds", "a histogram")
    h.observe(0.02)
    text = r.render_prometheus()
    assert re.search(r"^# TYPE x_total counter$", text, re.M)
    assert re.search(r"^# TYPE lat_seconds histogram$", text, re.M)
    assert 'engine_mode="paged"' in text and 'nbl_m="2"' in text
    assert "clobber" not in text                 # bind never overwrites
    # every sample line parses as <name>{labels} <value>
    sample = re.compile(r'^[a-zA-Z_:][a-zA-Z0-9_:]*(\{[^}]*\})? '
                        r'[-+0-9.einfEINF]+$')
    samples = [ln for ln in text.splitlines() if not ln.startswith("#")]
    assert samples and all(sample.match(ln) for ln in samples), samples
    # histogram: cumulative buckets are monotone and +Inf == _count
    cums = [float(ln.rsplit(" ", 1)[1]) for ln in samples
            if ln.startswith("lat_seconds_bucket")]
    assert cums == sorted(cums) and cums[-1] == 1.0
    assert re.search(r"^lat_seconds_count\{.*\} 1$", text, re.M)


def test_snapshot_is_json_ready():
    obs = Observability()
    obs.tokens.inc(3)
    obs.h_ttft.observe(0.01)
    json.dumps(obs.snapshot())                   # must not raise
    json.dumps(obs.tracer.chrome_trace())


# --------------------------------------------------------------- tracer --

def test_tracer_lifecycle_and_exports(tmp_path):
    tr = Tracer()
    tr.begin(1, "queued", t=0.0)
    tr.end(1, "queued", t=1.0)
    tr.begin(1, "prefill", t=1.0)
    tr.end(1, "wrong-name", t=1.5)               # mismatched close: no-op
    tr.end(1, "prefill", t=2.0, tokens=8)
    tr.begin(1, "decoding", t=2.0)
    tr.instant(1, "first_token", t=2.5)
    tr.terminate(1, "retired", t=3.0)            # closes open decoding span
    tr.terminate(1, "cancelled", t=9.0)          # idempotent: first wins
    got = tr.get(1)
    assert got.status == "retired"
    assert [s.name for s in got.spans] == ["queued", "prefill", "decoding"]
    assert got.spans[1].args == {"tokens": 8}
    got.validate()
    tr.validate_all()

    n = tr.export_jsonl(str(tmp_path / "t.jsonl"))
    assert n == 1
    row = json.loads((tmp_path / "t.jsonl").read_text().splitlines()[0])
    assert row["status"] == "retired" and len(row["spans"]) == 3

    tr.step_event("step", 0.0, 0.5, n_decoding=1)
    chrome = tr.chrome_trace()
    names = {e["ph"] for e in chrome["traceEvents"]}
    assert {"M", "X", "i"} <= names
    tids = {e["tid"] for e in chrome["traceEvents"] if e["ph"] == "X"}
    assert 0 in tids and 2 in tids               # engine track + rid 1
    n = tr.export_chrome_trace(str(tmp_path / "t.trace.json"))
    assert n == len(chrome["traceEvents"])
    json.loads((tmp_path / "t.trace.json").read_text())


def test_tracer_validate_catches_malformed():
    tr = Tracer()
    tr.begin(5, "queued", t=0.0)
    with pytest.raises(AssertionError):          # open span at terminal
        tr.get(5).validate()
    tr.terminate(5, "retired", t=1.0)
    tr.get(5).validate()
    bad = Tracer()
    bad.begin(6, "a", t=0.0)
    bad.end(6, "a", t=2.0)
    bad.begin(6, "b", t=1.0)                     # overlaps span a
    bad.end(6, "b", t=3.0)
    bad.terminate(6, "retired", t=3.0)
    with pytest.raises(AssertionError):
        bad.get(6).validate()


def test_tracer_evicts_only_terminal():
    tr = Tracer(max_traces=2)
    tr.begin(1, "queued", t=0.0)
    tr.terminate(1, "retired", t=1.0)
    tr.begin(2, "queued", t=0.0)                 # live
    tr.begin(3, "queued", t=0.0)                 # forces eviction of rid 1
    rids = {t.rid for t in tr.traces()}
    assert rids == {2, 3}


# ------------------------------------------------------------- timeline --

def test_timeline_ring_bounds_and_order():
    tl = StepTimeline(capacity=3)
    assert len(tl) == 0 and tl.last() is None
    # regression: an EMPTY timeline is falsy (len 0) but must still accept
    # appends — guards have to be `is not None`, not truthiness
    assert not tl and tl is not None
    for i in range(5):
        tl.append(StepRecord(step=i, t=float(i), host_s=0.0, dispatch_s=0.0,
                             n_decoding=1, n_chunking=0, n_queued=0,
                             tokens_emitted=1, prefill_tokens=0,
                             chunk_tokens=0))
    assert len(tl) == 3 and tl.total_steps == 5
    assert [r.step for r in tl.snapshot()] == [2, 3, 4]   # oldest first
    assert tl.last().step == 4
    assert tl.snapshot_dicts()[0]["step"] == 2
    with pytest.raises(ValueError):
        StepTimeline(capacity=0)


# ----------------------------------------------------- engine integration --

def _workload(cfg, rng, n=4, shared=0):
    sys_p = rng.integers(0, cfg.vocab_size, shared)
    reqs = []
    for _ in range(n):
        tail = rng.integers(0, cfg.vocab_size, int(rng.integers(2, 10)))
        reqs.append(np.concatenate([sys_p, tail]).astype(np.int32))
    return reqs


def _run(mode, obs, n_slots=2, max_new=5, shared=0):
    cfg = get_config("tiny-dense")
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(7)
    eng = Engine(cfg, params, max_len=MAX_LEN, n_slots=n_slots, obs=obs,
                 **MODES[mode])
    rids = [eng.submit(p, max_new)
            for p in _workload(cfg, rng, shared=shared)]
    out = eng.run()
    return eng, {r: tuple(out[r]) for r in rids}


@pytest.mark.parametrize("mode", list(MODES))
def test_engine_obs_zero_dispatch_and_counters(mode):
    shared = 2 * PAGE_SIZE if "shared" in mode or mode == "prefix" else 0
    obs = Observability()
    eng_on, out_on = _run(mode, obs, shared=shared)
    eng_off, out_off = _run(mode, None, shared=shared)
    # obs is host-side bookkeeping only: identical tokens + device work
    assert out_on == out_off
    assert eng_on.n_decode_steps == eng_off.n_decode_steps
    assert eng_on.n_prefills == eng_off.n_prefills
    assert eng_on.n_prefill_tokens == eng_off.n_prefill_tokens
    # registry counters == the engine's own counters
    assert obs.decode_steps.value == eng_on.n_decode_steps
    assert obs.prefills.value == eng_on.n_prefills
    assert obs.prefill_tokens.value == eng_on.n_prefill_tokens
    assert obs.chunks.value == eng_on.n_chunks
    assert obs.finished.value == eng_on.n_finished == len(out_on)
    assert obs.tokens.value == \
        sum(len(t) for t in out_on.values()) + obs.tokens_discarded.value
    assert obs.submitted.value == len(out_on)
    assert obs.prefix_hits.value == eng_on.n_prefix_hits
    # spans: every request retired with a well-formed tree
    for rid in out_on:
        t = obs.tracer.get(rid)
        assert t is not None and t.status == "retired"
        t.validate()
        assert t.spans[0].name == "queued"
        assert t.spans[-1].name == "decoding"
        assert any(e[0] == "first_token" for e in t.events)
    # timeline recorded every step (incl. the falsy-when-empty first one)
    assert len(obs.timeline) > 0
    assert obs.timeline.last().step == obs.timeline.total_steps - 1
    # histograms saw every request
    assert obs.h_ttft.count == len(out_on)
    assert obs.h_latency.count == len(out_on)


@pytest.mark.parametrize("state", ["queued", "chunking", "decoding"])
def test_cancel_span_wellformed_in_every_state(state):
    cfg = get_config("tiny-dense")
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    obs = Observability()
    eng = Engine(cfg, params, max_len=MAX_LEN, n_slots=1, obs=obs,
                 **MODES["chunked_shared"])
    decoy = eng.submit(rng.integers(0, cfg.vocab_size, 4).astype(np.int32),
                       6)
    victim = eng.submit(
        rng.integers(0, cfg.vocab_size, 16).astype(np.int32), 4)
    if state == "queued":
        pass                                      # slot 0 busy: never admitted
    elif state == "chunking":
        eng.step()                                # decoy admitted + decoding
        while not eng.finished.get(decoy):
            eng.step()
        eng.step()                                # victim starts chunking
        assert eng.slot_chunk_pos[0] >= 0         # mid-prompt
    else:
        while not eng.finished.get(decoy):
            eng.step()
        while not eng.finished.get(victim) and \
                not any(r is not None and r.rid == victim and r.tokens
                        for r in eng.slot_req):
            eng.step()                            # victim has emitted
    assert eng.cancel(victim)
    assert not eng.cancel(victim)                 # already terminal
    eng.run()
    t = obs.tracer.get(victim)
    assert t.status == "cancelled"
    t.validate()
    obs.tracer.validate_all()
    assert obs.cancelled.value == eng.n_cancelled == 1
    if state == "chunking":
        assert any(s.name == "chunk" for s in t.spans)
    assert eng.allocator.in_use == eng.prefix_index.n_entries


def test_stats_windowing_keeps_lifetime_n():
    cfg = get_config("tiny-dense")
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(5)
    eng = Engine(cfg, params, max_len=MAX_LEN, n_slots=2, stats_window=2)
    for _ in range(5):
        eng.submit(rng.integers(0, cfg.vocab_size, 6).astype(np.int32), 3)
    eng.run()
    s = eng.stats()
    assert s["n"] == 5                            # lifetime served count
    assert s["window_n"] == 2                     # percentile subset
    # unbounded window: no clipping marker
    eng2 = Engine(cfg, params, max_len=MAX_LEN, n_slots=2, stats_window=None)
    for _ in range(3):
        eng2.submit(rng.integers(0, cfg.vocab_size, 6).astype(np.int32), 3)
    eng2.run()
    s2 = eng2.stats()
    assert s2["n"] == 3 and "window_n" not in s2


def test_chrome_trace_shows_interleaving():
    """Acceptance: in a chunked+shared workload the exported trace makes
    the interleaving visible — decode-carrying engine step events overlap
    the window spanned by the long request's chunk spans."""
    cfg = get_config("tiny-dense")
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(11)
    obs = Observability()
    eng = Engine(cfg, params, max_len=MAX_LEN, n_slots=3, obs=obs,
                 **MODES["chunked_shared"])
    sys_p = rng.integers(0, cfg.vocab_size, 2 * PAGE_SIZE)
    shorts = [np.concatenate(
        [sys_p, rng.integers(0, cfg.vocab_size, 2)]).astype(np.int32)
        for _ in range(2)]
    for p in shorts:
        eng.submit(p, 12)
    eng.step()                                    # shorts admitted, decoding
    eng.step()
    long_p = np.concatenate(
        [sys_p, rng.integers(0, cfg.vocab_size, 16)]).astype(np.int32)
    lid = eng.submit(long_p, 3)
    eng.run()
    assert eng.n_interleaved_decode_steps >= 1
    assert obs.interleaved.value == eng.n_interleaved_decode_steps
    assert obs.prefix_hits.value >= 1             # shared prefix was reused

    t = obs.tracer.get(lid)
    chunks = sorted((s for s in t.spans if s.name == "chunk"),
                    key=lambda s: s.t0)
    assert len(chunks) >= 2                       # genuinely chunked
    chrome = obs.tracer.chrome_trace()
    lo, hi = chunks[0].t0, chunks[-1].t1
    lo_us = (lo - obs.tracer._t0) * 1e6
    hi_us = (hi - obs.tracer._t0) * 1e6
    interleaved = [
        e for e in chrome["traceEvents"]
        if e.get("tid") == 0 and e.get("ph") == "X"
        and e["args"].get("n_decoding", 0) > 0
        and e["args"].get("n_chunking", 0) > 0
        and e["ts"] < hi_us and e["ts"] + e["dur"] > lo_us]
    assert interleaved, "no decode step overlaps the chunk window"
