"""LoRA refinement (paper F.2) and speculative decoding (paper Table 6)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import nbl_compress
from repro.core.lora import lora_apply, lora_finetune, lora_init
from repro.data import ZipfMarkov, calib_factory
from repro.eval import perplexity
from repro.launch.engine import Engine
from repro.launch.serve import generate
from repro.launch.speculative import (
    accept_greedy, make_nbl_draft, speculative_generate,
)
from repro.launch.train import train
from repro.models import apply, init_params


@pytest.fixture(scope="module")
def compressed():
    cfg = get_config("tiny-dense")
    params = train(cfg, steps=120, global_batch=16, seq=64, peak_lr=3e-3,
                   log_fn=lambda s: None)["params"]
    fac = calib_factory(cfg, batch=4, seq=64, n_batches=4)
    ncfg, nparams, _ = nbl_compress(cfg, params, fac, 2)
    return cfg, params, ncfg, nparams


def test_lora_zero_init_is_identity(compressed):
    _, _, ncfg, nparams = compressed
    lora = lora_init(ncfg, rank=4, key=jax.random.PRNGKey(0))
    assert lora, "nbl layers must produce adapter sites"
    merged = lora_apply(ncfg, nparams, lora)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              ncfg.vocab_size)
    a, _ = apply(ncfg, nparams, toks)
    b, _ = apply(ncfg, merged, toks)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_lora_finetune_marginal_improvement(compressed):
    """Paper F.2: LoRA on NBL layers gives at-most-marginal gains —
    specifically it must not HURT (loss non-increasing on the tuning
    distribution)."""
    _, _, ncfg, nparams = compressed
    fac = calib_factory(ncfg, batch=4, seq=64, n_batches=2)
    before = perplexity(ncfg, nparams, fac)
    tuned = lora_finetune(ncfg, nparams, fac, steps=20, rank=4, lr=5e-4)
    after = perplexity(ncfg, tuned, fac)
    assert after <= before * 1.01, (before, after)


def test_speculative_equals_plain_greedy(compressed):
    """Greedy speculative decoding is exact wrt the verifier."""
    cfg, params, ncfg, nparams = compressed
    proc = ZipfMarkov(cfg.vocab_size, seed=0)
    prompts = jnp.asarray(proc.sample(2, 12, seed=5))
    max_new = 10

    # plain greedy with the verifier (full re-forward per token)
    toks = np.asarray(prompts)
    want = []
    for _ in range(max_new):
        logits, _ = apply(cfg, params, jnp.asarray(toks))
        nxt = np.asarray(jnp.argmax(logits[:, -1], -1).astype(jnp.int32))
        want.append(nxt)
        toks = np.concatenate([toks, nxt[:, None]], axis=1)
    want = np.stack(want, axis=1)

    # NBL model drafts, original model verifies
    got, stats = speculative_generate(ncfg, nparams, cfg, params,
                                      prompts, max_new=max_new, gamma=3)
    np.testing.assert_array_equal(got, want)
    assert stats["verifier_calls"] <= max_new      # never worse than plain
    assert 0.0 <= stats["acceptance_rate"] <= 1.0


def test_speculative_nbl_draft_accepts_often(compressed):
    """NBL's fidelity makes it a good draft: acceptance well above chance."""
    cfg, params, ncfg, nparams = compressed
    proc = ZipfMarkov(cfg.vocab_size, seed=1)
    prompts = jnp.asarray(proc.sample(2, 12, seed=9))
    _, stats = speculative_generate(ncfg, nparams, cfg, params,
                                    prompts, max_new=12, gamma=4)
    assert stats["acceptance_rate"] > 0.3, stats


def test_accept_greedy_is_per_row():
    """Regression: acceptance is each row's OWN agreeing prefix, not the
    batch minimum (the lockstep bug chained every row to the slowest
    acceptor)."""
    proposal = np.array([[1, 2, 3], [7, 8, 9], [4, 4, 4]], np.int32)
    want = np.array([[1, 2, 3, 5], [7, 5, 6, 0], [0, 1, 2, 3]], np.int32)
    np.testing.assert_array_equal(accept_greedy(proposal, want), [3, 1, 0])


def test_speculative_rows_independent(compressed):
    """Ragged per-row acceptance means a batched run is row-for-row
    identical to running each prompt alone, and finishes in the SLOWEST
    row's round count rather than the batch-min lockstep's."""
    cfg, params, ncfg, nparams = compressed
    proc = ZipfMarkov(cfg.vocab_size, seed=2)
    prompts = np.asarray(proc.sample(2, 10, seed=11), np.int32)
    batched, bstats = speculative_generate(
        ncfg, nparams, cfg, params, jnp.asarray(prompts),
        max_new=8, gamma=3)
    solo_calls = []
    for r in range(2):
        solo, sstats = speculative_generate(
            ncfg, nparams, cfg, params, jnp.asarray(prompts[r:r + 1]),
            max_new=8, gamma=3)
        np.testing.assert_array_equal(batched[r], solo[0])
        assert bstats["row_lengths"][r] == sstats["row_lengths"][0]
        solo_calls.append(sstats["verifier_calls"])
    assert bstats["verifier_calls"] == max(solo_calls), \
        (bstats["verifier_calls"], solo_calls)


def test_speculative_eos_truncates_per_row(compressed):
    """Regression: each row stops at its OWN first EOS (inclusive), the
    tail stays zero-padded, and row_lengths carries the true counts."""
    cfg, params, ncfg, nparams = compressed
    proc = ZipfMarkov(cfg.vocab_size, seed=3)
    prompts = jnp.asarray(proc.sample(2, 12, seed=7))
    max_new = 10
    ref, _ = speculative_generate(ncfg, nparams, cfg, params, prompts,
                                  max_new=max_new, gamma=3)
    # EOS drawn from the reference rollout so it provably fires mid-row
    # (greedy emission is deterministic: with eos set, each row is the
    # same stream cut at its first hit)
    eos = int(ref[0, 2])
    got, stats = speculative_generate(ncfg, nparams, cfg, params, prompts,
                                      max_new=max_new, gamma=3, eos_id=eos)
    assert stats["row_lengths"][0] <= 3
    for r in range(2):
        hits = np.nonzero(ref[r] == eos)[0]
        want = ref[r][:hits[0] + 1] if hits.size else ref[r]
        assert stats["row_lengths"][r] == len(want)
        np.testing.assert_array_equal(got[r, :len(want)], want)
        assert not got[r, len(want):].any()

    # the engine path honors the same EOS contract — oracled against the
    # cached-decode generate() reference (the numerics the engine runs),
    # truncated at ITS first EOS
    eng = Engine(cfg, params, max_len=32, n_slots=2, eos_id=eos,
                 paged=True, page_size=4,
                 drafts={2: make_nbl_draft(cfg, params, 2)})
    prompt0 = np.asarray(prompts[0], np.int32)
    rid = eng.submit(prompt0, max_new, spec_gamma=3, draft_m=2)
    while eng.has_work:
        eng.step()
    oracle = np.asarray(generate(cfg, params, jnp.asarray(prompt0)[None],
                                 max_new=max_new))[0]
    hits = np.nonzero(oracle == eos)[0]
    want_eng = oracle[:hits[0] + 1] if hits.size else oracle
    np.testing.assert_array_equal(
        np.asarray(eng.finished[rid].tokens, np.int32), want_eng)
    assert eng.allocator.in_use == 0


def test_speculative_stats_count_post_truncation(compressed):
    """Regression: draft tokens proposed past a row's remaining budget no
    longer inflate the stats. With max_new=1 every row retires in one
    round, so exactly one draft token per row can land — gamma=5 used to
    count five."""
    cfg, params, ncfg, nparams = compressed
    proc = ZipfMarkov(cfg.vocab_size, seed=4)
    prompts = jnp.asarray(proc.sample(3, 10, seed=13))
    _, stats = speculative_generate(ncfg, nparams, cfg, params, prompts,
                                    max_new=1, gamma=5)
    assert stats["verifier_calls"] == 1
    assert stats["draft_tokens"] == 3
    assert stats["accepted"] <= 3
    assert stats["acceptance_rate"] <= 1.0
    assert stats["row_lengths"] == [1, 1, 1]


def test_engine_spec_parity_and_stats():
    """Engine-native speculative decoding: token-exact against plain
    generate(), zero leaked pages at drain, and the stats surface keeps
    burst/draft/accept accounting consistent."""
    cfg = get_config("tiny-dense")
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, max_len=32, n_slots=2, paged=True,
                 page_size=4, drafts={2: make_nbl_draft(cfg, params, 2)})
    proc = ZipfMarkov(cfg.vocab_size, seed=5)
    prompts = [np.asarray(p, np.int32) for p in proc.sample(3, 6, seed=17)]
    rids = [eng.submit(p, 8, spec_gamma=g, draft_m=2)
            for p, g in zip(prompts, (1, 2, 3))]
    while eng.has_work:
        eng.step()
    for rid, p in zip(rids, prompts):
        want = np.asarray(generate(cfg, params, jnp.asarray(p)[None],
                                   max_new=8))[0]
        np.testing.assert_array_equal(
            np.asarray(eng.finished[rid].tokens, np.int32), want)
    assert eng.allocator.in_use == 0
    st = eng.stats()
    assert st["n_spec_bursts"] > 0
    # in an all-spec workload every token came from a burst EXCEPT each
    # request's first, which the admission prefill emits
    assert st["n_spec_tokens"] == sum(
        len(eng.finished[r].tokens) for r in rids) - len(rids)
    assert st["n_spec_accepted_tokens"] <= st["n_spec_draft_tokens"]
    assert 0.0 <= st["spec_acceptance_rate"] <= 1.0


def test_engine_spec_submit_gates():
    """Every unservable spec submission is rejected-with-error, not
    raised: span overflow, unknown draft_m, and a draftless engine."""
    cfg = get_config("tiny-dense")
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, max_len=32, n_slots=2, paged=True,
                 page_size=4, drafts={2: make_nbl_draft(cfg, params, 2)})
    prompt = np.arange(1, 9, dtype=np.int32)          # plen 8

    rid = eng.submit(prompt, 24, spec_gamma=1, draft_m=2)  # 8+24+1 > 32
    assert "max_len" in eng.finished[rid].error
    rid = eng.submit(prompt, 8, spec_gamma=2, draft_m=7)
    assert "draft_m" in eng.finished[rid].error
    # the same prompt WITHOUT spec still fits: the gate is span-specific
    rid = eng.submit(prompt, 24)
    assert eng.finished.get(rid) is None or not eng.finished[rid].error

    plain = Engine(cfg, params, max_len=32, n_slots=1, paged=True,
                   page_size=4)
    rid = plain.submit(prompt, 4, spec_gamma=2, draft_m=2)
    assert "drafts" in plain.finished[rid].error
