"""LoRA refinement (paper F.2) and speculative decoding (paper Table 6)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import nbl_compress
from repro.core.lora import lora_apply, lora_finetune, lora_init
from repro.data import ZipfMarkov, calib_factory
from repro.eval import perplexity
from repro.launch.speculative import speculative_generate
from repro.launch.train import train
from repro.models import apply, init_params


@pytest.fixture(scope="module")
def compressed():
    cfg = get_config("tiny-dense")
    params = train(cfg, steps=120, global_batch=16, seq=64, peak_lr=3e-3,
                   log_fn=lambda s: None)["params"]
    fac = calib_factory(cfg, batch=4, seq=64, n_batches=4)
    ncfg, nparams, _ = nbl_compress(cfg, params, fac, 2)
    return cfg, params, ncfg, nparams


def test_lora_zero_init_is_identity(compressed):
    _, _, ncfg, nparams = compressed
    lora = lora_init(ncfg, rank=4, key=jax.random.PRNGKey(0))
    assert lora, "nbl layers must produce adapter sites"
    merged = lora_apply(ncfg, nparams, lora)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              ncfg.vocab_size)
    a, _ = apply(ncfg, nparams, toks)
    b, _ = apply(ncfg, merged, toks)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_lora_finetune_marginal_improvement(compressed):
    """Paper F.2: LoRA on NBL layers gives at-most-marginal gains —
    specifically it must not HURT (loss non-increasing on the tuning
    distribution)."""
    _, _, ncfg, nparams = compressed
    fac = calib_factory(ncfg, batch=4, seq=64, n_batches=2)
    before = perplexity(ncfg, nparams, fac)
    tuned = lora_finetune(ncfg, nparams, fac, steps=20, rank=4, lr=5e-4)
    after = perplexity(ncfg, tuned, fac)
    assert after <= before * 1.01, (before, after)


def test_speculative_equals_plain_greedy(compressed):
    """Greedy speculative decoding is exact wrt the verifier."""
    cfg, params, ncfg, nparams = compressed
    proc = ZipfMarkov(cfg.vocab_size, seed=0)
    prompts = jnp.asarray(proc.sample(2, 12, seed=5))
    max_new = 10

    # plain greedy with the verifier (full re-forward per token)
    toks = np.asarray(prompts)
    want = []
    for _ in range(max_new):
        logits, _ = apply(cfg, params, jnp.asarray(toks))
        nxt = np.asarray(jnp.argmax(logits[:, -1], -1).astype(jnp.int32))
        want.append(nxt)
        toks = np.concatenate([toks, nxt[:, None]], axis=1)
    want = np.stack(want, axis=1)

    # NBL model drafts, original model verifies
    got, stats = speculative_generate(ncfg, nparams, cfg, params,
                                      prompts, max_new=max_new, gamma=3)
    np.testing.assert_array_equal(got, want)
    assert stats["verifier_calls"] <= max_new      # never worse than plain
    assert 0.0 <= stats["acceptance_rate"] <= 1.0


def test_speculative_nbl_draft_accepts_often(compressed):
    """NBL's fidelity makes it a good draft: acceptance well above chance."""
    cfg, params, ncfg, nparams = compressed
    proc = ZipfMarkov(cfg.vocab_size, seed=1)
    prompts = jnp.asarray(proc.sample(2, 12, seed=9))
    _, stats = speculative_generate(ncfg, nparams, cfg, params,
                                    prompts, max_new=12, gamma=4)
    assert stats["acceptance_rate"] > 0.3, stats
