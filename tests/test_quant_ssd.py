"""AWQ quantization (paper §4.3/E.6) and the Pallas SSD kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels.ref import ssd_chunk_ref
from repro.kernels.ssd_chunk import ssd_chunk
from repro.quant import awq_scale_search, dequantize, quantize_model, \
    quantize_tensor

KEY = jax.random.PRNGKey(3)


# ----------------------------------------------------------------- quant ---

def test_quantize_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    w = rng.standard_normal((64, 256))
    for bits in (8, 4):
        q, scales = quantize_tensor(w, bits=bits, group=64)
        w_hat = dequantize(q, scales, 256)
        # per-group max-abs scaling bounds error by scale/2 elementwise
        err = np.abs(w_hat - w)
        bound = np.repeat(scales.reshape(64, -1), 64, axis=1) / 2 + 1e-9
        assert (err <= bound).all(), (bits, err.max())


def test_awq_scaling_beats_rtn_on_outlier_channels():
    """The AWQ mechanism: with outlier input channels, activation-aware
    scaling lowers the expected output error vs plain RTN."""
    rng = np.random.default_rng(1)
    d = 256
    w = rng.standard_normal((64, d))
    act_mag = np.ones(d)
    act_mag[:8] = 50.0                        # salient channels
    _, a_star, err_awq = awq_scale_search(w, act_mag, bits=4, group=128)
    _, _, err_rtn = awq_scale_search(w, None, bits=4, group=128)
    # compare on the SAME metric (activation-weighted)
    cxx = act_mag ** 2
    q, s = quantize_tensor(w, 4, 128)
    w_rtn = dequantize(q, s, d)
    err_rtn_w = float((((w_rtn - w) ** 2) * cxx[None, :]).sum())
    assert err_awq < err_rtn_w, (err_awq, err_rtn_w)
    assert a_star > 0


def test_quantize_model_and_nbl_compose():
    """§4.3: NBL applies on top of a quantized model; both orders work and
    perplexity stays finite/close."""
    from repro.core import nbl_compress
    from repro.data import calib_factory
    from repro.eval import perplexity
    from repro.models import init_params

    cfg = get_config("tiny-dense")
    params = init_params(jax.random.PRNGKey(0), cfg)
    qparams, rep = quantize_model(cfg, params, bits=8)
    assert rep.n_quantized >= 5
    assert rep.q_bytes < rep.fp_bytes / 3
    fac = calib_factory(cfg, batch=2, seq=64, n_batches=2)
    p0 = perplexity(cfg, params, fac)
    p1 = perplexity(cfg, qparams, fac)
    assert np.isfinite(p1) and abs(np.log(p1 / p0)) < 0.15, (p0, p1)
    # NBL on the quantized model (the paper's 70B pipeline)
    ncfg, nparams, _ = nbl_compress(cfg, qparams, fac, 1)
    p2 = perplexity(ncfg, nparams, fac)
    assert np.isfinite(p2)


# ------------------------------------------------------------- ssd kernel --

@pytest.mark.parametrize("B,NC,C,H,P,N", [
    (1, 2, 16, 2, 8, 4), (2, 3, 32, 4, 16, 8), (1, 1, 64, 2, 32, 16),
])
def test_ssd_chunk_kernel_matches_oracle(B, NC, C, H, P, N):
    x = jax.random.normal(KEY, (B, NC, C, H, P))
    a = -jnp.abs(jax.random.normal(jax.random.PRNGKey(1),
                                   (B, NC, C, H))) * 0.1
    b = jax.random.normal(jax.random.PRNGKey(2), (B, NC, C, N))
    c = jax.random.normal(jax.random.PRNGKey(4), (B, NC, C, N))
    y, s, at = ssd_chunk(x, a, b, c, interpret=True)
    yr, sr, atr = ssd_chunk_ref(x, a, b, c)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=3e-4, rtol=3e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr),
                               atol=3e-4, rtol=3e-4)
    np.testing.assert_allclose(np.asarray(at), np.asarray(atr), atol=1e-5)


def test_ssd_kernel_consistent_with_model_path():
    """Kernel intra-chunk output + XLA inter-chunk scan == the model's
    _ssd_chunked (same final states and outputs)."""
    from repro.models.ssm import _ssd_chunked

    B, L, H, P, N, C = 1, 64, 2, 8, 4, 16
    xh = jax.random.normal(KEY, (B, L, H, P), jnp.float32)
    a = -jnp.abs(jax.random.normal(jax.random.PRNGKey(7), (B, L, H))) * 0.1
    bb = jax.random.normal(jax.random.PRNGKey(8), (B, L, N), jnp.float32)
    cc = jax.random.normal(jax.random.PRNGKey(9), (B, L, N), jnp.float32)
    y_want, s_want = _ssd_chunked(xh, a, bb, cc, C)

    nc = L // C
    xk = xh.reshape(B, nc, C, H, P)
    ak = a.reshape(B, nc, C, H)
    bk = bb.reshape(B, nc, C, N)
    ck = cc.reshape(B, nc, C, N)
    y_intra, s_chunks, a_tot = ssd_chunk(xk, ak, bk, ck, interpret=True)

    # inter-chunk recurrence (as in models/ssm.py)
    def body(carry, xs):
        s_z, atot_z = xs
        s_new = carry * jnp.exp(atot_z)[..., None, None] + s_z
        return s_new, carry

    s0 = jnp.zeros((B, H, P, N), jnp.float32)
    s_t = s_chunks.transpose(1, 0, 2, 4, 3)          # (NC,B,H,P,N)
    final, s_prevs = jax.lax.scan(body, s0, (s_t, a_tot.transpose(1, 0, 2)))
    s_prevs = s_prevs.transpose(1, 0, 2, 3, 4)
    decay_out = jnp.exp(jnp.cumsum(ak.transpose(0, 1, 3, 2), -1))
    y_inter = jnp.einsum("bzin,bzhpn,bzhi->bzihp", ck, s_prevs, decay_out)
    y = (y_intra + y_inter).reshape(B, L, H, P)

    np.testing.assert_allclose(np.asarray(y), np.asarray(y_want),
                               atol=3e-4, rtol=3e-4)
    np.testing.assert_allclose(np.asarray(final), np.asarray(s_want),
                               atol=3e-4, rtol=3e-4)
