"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests must see the real
single CPU device; multi-device tests spawn subprocesses (run_py)."""
from __future__ import annotations

import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_py(code: str, n_devices: int = 1, timeout: int = 560) -> str:
    """Run ``code`` in a fresh python with n host-platform devices."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    if n_devices > 1:
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                            + f" --xla_force_host_platform_device_count={n_devices}")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    if out.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}")
    return out.stdout


@pytest.fixture(scope="session")
def subproc():
    return run_py
