"""Fault-tolerance: atomic checkpoints, preemption husks, auto-resume."""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.launch.train import train


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (8, 8)),
            "nested": {"b": jnp.arange(5, dtype=jnp.float32)},
            "count": jnp.int32(3)}


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    t = _tree()
    mgr.save(7, t)
    step, got = mgr.restore_latest(t)
    assert step == 7
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(t)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_preempted_save_ignored(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, _tree(0))
    # simulate preemption mid-save: a .tmp husk with partial contents
    husk = os.path.join(str(tmp_path), "step_0000000009.tmp")
    os.makedirs(husk)
    with open(os.path.join(husk, "arrays.npz"), "w") as f:
        f.write("partial garbage")
    assert mgr.latest_step() == 5
    step, _ = mgr.restore_latest(_tree(0))
    assert step == 5
    mgr.save(10, _tree(1))          # next save garbage-collects the husk
    assert not os.path.exists(husk)


def test_keep_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s))
    assert mgr.all_steps() == [3, 4]


def test_train_auto_resume(tmp_path):
    """Kill training at step 6, restart, and reach the same final loss as
    an uninterrupted run (deterministic data + state restore)."""
    cfg = get_config("tiny-dense")
    kw = dict(global_batch=8, seq=32, peak_lr=1e-3, ckpt_every=3,
              log_fn=lambda s: None)
    full = train(cfg, steps=9, ckpt_dir=str(tmp_path / "a"), **kw)

    train(cfg, steps=6, ckpt_dir=str(tmp_path / "b"), **kw)  # "preempted"
    resumed = train(cfg, steps=9, ckpt_dir=str(tmp_path / "b"), **kw)

    lf = dict(full["history"])
    lr = dict(resumed["history"])
    assert abs(lf[8] - lr[8]) < 1e-3, (lf[8], lr[8])
