"""Token-budget edge cases for the fused plan→execute→commit pipeline:
StepPlan / chunk_span arithmetic (sub-page budgets, exact exhaustion,
min-progress), decode-only and prefill-only steps, decode starvation
(decode rows are never displaced by chunk rows), the one-dispatch-per-
step contract, and the silent fused-path gates."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.engine import Engine
from repro.launch.serve import generate
from repro.launch.stepplan import (
    ChunkRow, StepPlan, chunk_span, decode_first_budget, pow2_ceil,
)
from repro.models import init_params


def _setup(arch="tiny-dense", seed=0):
    cfg = get_config(arch)
    params = init_params(jax.random.PRNGKey(seed), cfg)
    return cfg, params


def _ref(cfg, params, prompt, max_new):
    out = generate(cfg, params, jnp.asarray(prompt)[None], max_new=max_new)
    return np.asarray(out)[0]


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
            for n in lens]


# ------------------------------------------------ plan arithmetic ----------

def test_pow2_ceil():
    assert [pow2_ceil(n) for n in (1, 2, 3, 4, 5, 8, 9)] \
        == [1, 2, 4, 4, 8, 8, 16]


def test_stepplan_properties():
    rows = [ChunkRow(0, 0, 4, False), ChunkRow(1, 4, 10, True)]
    assert rows[1].length == 6
    plan = StepPlan(budget=16, decode_slots=[2, 3], chunk_rows=rows)
    assert plan.tokens_planned == 12            # 2 decode + 4 + 6
    assert plan.width == 8                      # pow2_ceil(longest span 6)
    assert plan.utilization == 12 / 16
    assert plan.has_work()
    empty = StepPlan(budget=None)
    assert not empty.has_work()
    assert empty.width == 1                     # decode-only jit variant
    assert empty.utilization == 0.0
    # unbounded budget reports NO pressure even with work planned
    assert StepPlan(budget=None, decode_slots=[0]).utilization == 0.0


def test_decode_first_budget():
    assert decode_first_budget(None, 7) is None     # unbounded passthrough
    assert decode_first_budget(8, 3) == 5
    assert decode_first_budget(2, 2) == 0           # decode eats it all
    assert decode_first_budget(2, 5) == 0           # never negative


def test_chunk_span_edges():
    # unbounded: only the per-row cap and the prompt bound the span
    assert chunk_span(0, 16, 8, None, 4) == 8
    assert chunk_span(12, 14, 8, None, 4) == 14     # final partial tail
    # budget exhausted -> empty span, the row waits
    assert chunk_span(4, 16, 8, 0, 4) == 4
    assert chunk_span(4, 16, 8, -3, 4) == 4
    # a chunk that EXACTLY exhausts the budget passes through untrimmed
    assert chunk_span(0, 8, 8, 8, 4) == 8
    # tighter budget rounds DOWN to a page multiple
    assert chunk_span(0, 16, 8, 7, 4) == 4
    # min-progress: a budget smaller than one page still grants one page
    assert chunk_span(0, 16, 8, 3, 4) == 4
    assert chunk_span(0, 16, 8, 1, 4) == 4
    # ... or the final sub-page tail when that is all that is left
    assert chunk_span(12, 14, 8, 1, 4) == 14


# ------------------------------------------------ engine: budget edges -----

def test_sub_page_budget_drains_one_page_per_step():
    """step_tokens smaller than one page cannot livelock a chunking slot:
    min-progress grants exactly one page per step, so a 16-token prompt
    drains in 4 chunk steps even under a 3-token budget."""
    cfg, params = _setup()
    prompt = _prompts(cfg, [16], seed=7)[0]
    want = _ref(cfg, params, prompt, 2)

    eng = Engine(cfg, params, max_len=24, n_slots=1, paged=True, page_size=4,
                 chunked_prefill=True, prefill_chunk_tokens=8, step_tokens=3)
    assert eng.fused
    rid = eng.submit(prompt, 2)
    out = eng.run(max_steps=50)
    np.testing.assert_array_equal(out[rid], want)
    # one page per step despite the 8-token per-row cap
    assert eng.n_chunks == 4
    s = eng.stats()
    # chunk steps each planned 4 tokens against a 3-token budget
    assert s["step_budget_utilization"] > 1.0
    assert s["step_tokens"] == 3
    eng.allocator.check_invariants()
    assert eng.allocator.in_use == 0


def test_chunk_exactly_exhausts_budget():
    """A prompt whose single chunk equals step_tokens lands in ONE fused
    dispatch at utilization exactly 1.0."""
    cfg, params = _setup()
    prompt = _prompts(cfg, [8], seed=8)[0]
    want = _ref(cfg, params, prompt, 3)

    eng = Engine(cfg, params, max_len=16, n_slots=1, paged=True, page_size=4,
                 chunked_prefill=True, prefill_chunk_tokens=8, step_tokens=8)
    rid = eng.submit(prompt, 3)
    eng.step()                                  # admit + whole-prompt chunk
    assert eng.n_chunks == 1
    assert eng.n_fused_dispatches == 1
    assert eng.stats()["step_budget_utilization"] == 1.0
    out = eng.run(max_steps=20)
    np.testing.assert_array_equal(out[rid], want)


def test_prefill_only_then_decode_only_steps():
    """A lone long prompt produces pure prefill-only steps (no decode
    rows -> n_decode_steps untouched) followed by pure decode-only steps,
    each still exactly one fused dispatch."""
    cfg, params = _setup()
    prompt = _prompts(cfg, [16], seed=9)[0]
    want = _ref(cfg, params, prompt, 3)

    eng = Engine(cfg, params, max_len=24, n_slots=2, paged=True, page_size=4,
                 chunked_prefill=True, prefill_chunk_tokens=4)
    rid = eng.submit(prompt, 3)
    for _ in range(4):                          # 4 prefill-only chunk steps
        eng.step()
    assert eng.n_chunks == 4
    assert eng.n_decode_steps == 0              # never a decode row yet
    out = eng.run(max_steps=20)                 # 2 decode-only steps
    np.testing.assert_array_equal(out[rid], want)
    assert eng.n_decode_steps == 2              # seed rode the final chunk
    assert eng.n_fused_dispatches == 6
    assert eng.n_interleaved_decode_steps == 0
    # unbounded budget: no pressure to report
    assert eng.stats()["step_budget_utilization"] == 0.0


def test_decode_rows_never_displaced_by_chunks():
    """Decode starvation guarantee: with step_tokens equal to the number
    of decoding slots the whole budget is charged to decode first — every
    decoder emits on every step while the chunking row is granted NOTHING
    until a decoder retires and frees budget."""
    cfg, params = _setup()
    shorts = _prompts(cfg, [4, 4], seed=10)
    longp = _prompts(cfg, [16], seed=11)[0]
    refs = [_ref(cfg, params, p, 8) for p in shorts]
    lref = _ref(cfg, params, longp, 4)

    eng = Engine(cfg, params, max_len=32, n_slots=3, paged=True, page_size=4,
                 chunked_prefill=True, prefill_chunk_tokens=4, step_tokens=2)
    sids = [eng.submit(p, 8) for p in shorts]
    eng.step()                                  # admit + seed short 0
    eng.step()                                  # admit + seed short 1
    lid = eng.submit(longp, 4)

    starved, steps = 0, 0
    while eng.has_work and steps < 100:
        both = sum(1 for r in eng.slot_req
                   if r is not None and r.rid in sids) == 2
        lslot = next((i for i, r in enumerate(eng.slot_req)
                      if r is not None and r.rid == lid), None)
        lpos = None if lslot is None else int(eng.slot_chunk_pos[lslot])
        e = eng.step()
        steps += 1
        if both and lpos == 0:
            # budget 2 == 2 decoders: both decode rows ran ...
            assert e == 2
            # ... and the chunk row was displaced, not the decoders
            assert int(eng.slot_chunk_pos[lslot]) == 0
            starved += 1
    assert not eng.has_work
    assert starved >= 4
    for sid, want in zip(sids, refs):
        np.testing.assert_array_equal(eng.finished[sid].tokens, want)
    np.testing.assert_array_equal(eng.finished[lid].tokens, lref)
    eng.allocator.check_invariants()
    assert eng.allocator.in_use == 0


def test_budget_grants_oldest_chunker_first():
    """Two chunking prompts under a one-page budget: the older admission
    drains completely before the younger makes any progress (strict
    oldest-first granting, no round-robin)."""
    cfg, params = _setup()
    p1, p2 = _prompts(cfg, [16, 16], seed=12)

    eng = Engine(cfg, params, max_len=24, n_slots=2, paged=True, page_size=4,
                 chunked_prefill=True, prefill_chunk_tokens=4, step_tokens=4)
    r1, r2 = eng.submit(p1, 2), eng.submit(p2, 2)
    for _ in range(4):
        eng.step()
    s1 = next(i for i, r in enumerate(eng.slot_req)
              if r is not None and r.rid == r1)
    s2 = next(i for i, r in enumerate(eng.slot_req)
              if r is not None and r.rid == r2)
    assert eng.slot_chunk_pos[s1] < 0           # p1 fully chunked, decoding
    assert eng.slot_chunk_pos[s2] == 0          # p2 admitted but untouched
    out = eng.run(max_steps=50)
    np.testing.assert_array_equal(out[r1], _ref(cfg, params, p1, 2))
    np.testing.assert_array_equal(out[r2], _ref(cfg, params, p2, 2))


# ------------------------------------------------ dispatch contract --------

def test_one_fused_dispatch_per_step_mixed_workload():
    """The fused pipeline's core contract: a mixed decode+chunk workload
    executes AT MOST one device dispatch per step() and zero legacy
    dispatches, with token-exact outputs."""
    cfg, params = _setup()
    shorts = _prompts(cfg, [4, 5], seed=3)
    longp = _prompts(cfg, [24], seed=4)[0]
    refs = [_ref(cfg, params, p, 6) for p in shorts]
    lref = _ref(cfg, params, longp, 4)

    eng = Engine(cfg, params, max_len=40, n_slots=3, paged=True, page_size=4,
                 chunked_prefill=True, prefill_chunk_tokens=4, step_tokens=12)
    rids = [eng.submit(p, 6) for p in shorts] + [eng.submit(longp, 4)]
    assert eng.fused
    worked, steps = 0, 0
    while eng.has_work and steps < 200:
        before = eng.n_fused_dispatches
        eng.step()
        d = eng.n_fused_dispatches - before
        assert d in (0, 1)                      # never a second dispatch
        worked += d
        steps += 1
    assert not eng.has_work
    assert eng.n_fused_dispatches == worked
    assert eng.n_legacy_dispatches == 0
    assert eng.n_interleaved_decode_steps >= 1  # decodes rode chunk steps
    for rid, want in zip(rids, refs + [lref]):
        np.testing.assert_array_equal(eng.finished[rid].tokens, want)
    eng.allocator.check_invariants()
    assert eng.allocator.in_use == 0
    s = eng.stats()
    assert s["n_fused_dispatches"] == worked
    assert s["n_legacy_dispatches"] == 0
    assert s["step_tokens"] == 12


def test_legacy_path_is_the_parity_oracle():
    """Engine(fused_step=False) keeps the two-dispatch path: identical
    tokens, zero fused dispatches, legacy dispatch counter live."""
    cfg, params = _setup()
    prompts = _prompts(cfg, [9, 16], seed=5)
    refs = [_ref(cfg, params, p, 5) for p in prompts]

    eng = Engine(cfg, params, max_len=32, n_slots=2, paged=True, page_size=4,
                 chunked_prefill=True, prefill_chunk_tokens=4,
                 fused_step=False, step_tokens=8)
    assert not eng.fused
    rids = [eng.submit(p, 5) for p in prompts]
    out = eng.run(max_steps=300)
    for rid, want in zip(rids, refs):
        np.testing.assert_array_equal(out[rid], want)
    assert eng.n_fused_dispatches == 0
    assert eng.n_legacy_dispatches > 0


def test_fused_gates_and_validation():
    """Fused mode silently falls back to legacy off the paged path and on
    SSM stacks; step_tokens is validated at construction."""
    cfg, params = _setup()
    with pytest.raises(ValueError, match="step_tokens"):
        Engine(cfg, params, max_len=16, n_slots=1, paged=True, page_size=4,
               step_tokens=0)
    ring = Engine(cfg, params, max_len=16, n_slots=1)       # not paged
    assert not ring.fused
    zcfg = get_config("tiny-zamba")
    zparams = init_params(jax.random.PRNGKey(0), zcfg)
    zeng = Engine(zcfg, zparams, max_len=16, n_slots=1, paged=True,
                  page_size=4)
    assert not zeng.fused                                   # SSM gate
