"""Async serving host loop: AsyncEngine streaming/cancel/backpressure/
shutdown, Engine.cancel in every lifecycle state (queued / chunking
mid-prompt / decoding / prefix-referenced), the unified reject-with-error
submit surface, run() partials, and the newline-JSON TCP server."""
from __future__ import annotations

import json
import socket
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.engine import AsyncEngine, Engine
from repro.launch.serve import generate
from repro.models import init_params

MODES = {
    "ring": {},
    "paged": dict(paged=True, page_size=4),
    "prefix": dict(paged=True, page_size=4, prefix_sharing=True),
    "chunked": dict(paged=True, page_size=4, chunked_prefill=True),
    "chunked_shared": dict(paged=True, page_size=4, chunked_prefill=True,
                           prefix_sharing=True),
}


def _setup(arch="tiny-dense", seed=0):
    cfg = get_config(arch)
    return cfg, init_params(jax.random.PRNGKey(seed), cfg)


def _ref(cfg, params, prompt, max_new):
    return np.asarray(generate(cfg, params, jnp.asarray(prompt)[None],
                               max_new=max_new))[0]


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
            for n in lens]


def _assert_drained(eng):
    """No slot holds a request and the pool holds only index references."""
    assert all(r is None for r in eng.slot_req)
    if eng.paged:
        held = eng.prefix_index.n_entries if eng.prefix_sharing else 0
        assert eng.allocator.in_use == held, (eng.allocator.in_use, held)
        eng.allocator.check_invariants()


# ----------------------------------------------------- submit surface -----

def test_submit_rejects_with_error_by_default():
    """Oversize / empty / max_new<1 submissions are RECORDED (rid returned,
    Request.error set) instead of raising — the same surface the
    admission-time guard uses, so a socket handler never sees an
    exception. strict=True restores the raise for direct use."""
    cfg, params = _setup()
    eng = Engine(cfg, params, max_len=16, n_slots=1)
    good = _prompts(cfg, [5])[0]
    ref = _ref(cfg, params, good, 4)

    r_big = eng.submit(np.arange(14, dtype=np.int32), 10)
    r_empty = eng.submit(np.array([], np.int32), 4)
    r_neg = eng.submit(good, 0)
    r_ok = eng.submit(good, 4)
    out = eng.run()
    np.testing.assert_array_equal(out[r_ok], ref)
    assert eng.n_rejected == 3
    for rid, frag in ((r_big, "max_len"), (r_empty, "empty"),
                      (r_neg, "max_new")):
        req = eng.finished[rid]
        assert req.error is not None and frag in req.error, req.error
        assert len(req.tokens) == 0
    for bad_args in ((np.arange(14, dtype=np.int32), 10),
                     (np.array([], np.int32), 4), (good, 0)):
        with pytest.raises(ValueError):
            eng.submit(*bad_args, strict=True)


def test_run_exposes_partials():
    """A max_steps-bounded run leaves work in flight; partials() surfaces
    the generated-so-far tokens (greedy => a prefix of the oracle) plus
    queued requests as empty arrays, instead of silently dropping them."""
    cfg, params = _setup()
    p1, p2 = _prompts(cfg, [5, 7], seed=3)
    ref1 = _ref(cfg, params, p1, 10)

    eng = Engine(cfg, params, max_len=20, n_slots=1)
    r1 = eng.submit(p1, 10)
    r2 = eng.submit(p2, 4)                   # stays queued behind r1
    out = eng.run(max_steps=3)
    assert r1 not in out and r2 not in out   # finished only
    part = eng.partials()
    assert set(part) == {r1, r2}
    assert 1 <= len(part[r1]) < 10
    np.testing.assert_array_equal(part[r1], ref1[:len(part[r1])])
    assert len(part[r2]) == 0
    eng.run()                                # drains; partials now empty
    assert eng.partials() == {}
    np.testing.assert_array_equal(eng.run()[r1], ref1)


# ------------------------------------------------ Engine.cancel states ----

def test_cancel_queued_and_decoding():
    """cancel() retires a never-admitted (queued) request and an in-flight
    decode; the survivor is untouched, pages are all returned, cancelled
    requests keep their partial tokens and are excluded from latency
    percentiles (no garbage TTFT from the 0.0 sentinel)."""
    from repro.launch.scheduler import latency_stats

    cfg, params = _setup()
    pa, pb, pc = _prompts(cfg, [5, 9, 7], seed=5)
    ref_a = _ref(cfg, params, pa, 8)
    ref_b = _ref(cfg, params, pb, 8)

    eng = Engine(cfg, params, max_len=24, n_slots=2, paged=True, page_size=4)
    ra = eng.submit(pa, 8)
    rb = eng.submit(pb, 8)
    rc = eng.submit(pc, 8)                   # queued: only 2 slots
    eng.step()
    assert eng.cancel(rc)                    # queued, never admitted
    assert len(eng.finished[rc].tokens) == 0
    eng.step()
    assert eng.cancel(ra)                    # mid-decode
    got_a = np.asarray(eng.finished[ra].tokens, np.int32)
    assert 1 <= len(got_a) < 8
    np.testing.assert_array_equal(got_a, ref_a[:len(got_a)])
    assert not eng.cancel(ra)                # already terminal: no-op
    out = eng.run()
    np.testing.assert_array_equal(out[rb], ref_b)
    assert eng.n_cancelled == 2
    _assert_drained(eng)
    s = latency_stats(list(eng.finished.values()))
    assert s["n"] == 1 and s["n_cancelled"] == 2
    assert s["p50_ttft_s"] >= 0.0            # no 0.0-sentinel garbage


def test_cancel_mid_chunking_releases_pages():
    """cancel() of a slot SUSPENDED mid-prompt (chunked prefill) drops its
    chunk pages and progress; other in-flight decodes are unaffected and
    the pool ends empty."""
    cfg, params = _setup()
    short = _prompts(cfg, [4], seed=7)[0]
    longp = _prompts(cfg, [24], seed=8)[0]
    ref_s = _ref(cfg, params, short, 10)

    eng = Engine(cfg, params, max_len=40, n_slots=2, paged=True, page_size=4,
                 chunked_prefill=True, prefill_chunk_tokens=4)
    rs = eng.submit(short, 10)
    eng.step()                               # short decoding
    rl = eng.submit(longp, 4)
    eng.step()                               # long admitted, 1st chunk
    slot = next(s for s, r in enumerate(eng.slot_req)
                if r is not None and r.rid == rl)
    assert eng.slot_chunk_pos[slot] >= 0     # genuinely mid-chunking
    assert eng.cancel(rl)
    assert eng.slot_chunk_pos[slot] == -1 and eng.slot_req[slot] is None
    eng.allocator.check_invariants()
    out = eng.run()
    np.testing.assert_array_equal(out[rs], ref_s)
    assert len(eng.finished[rl].tokens) == 0  # never reached decode
    _assert_drained(eng)


def test_cancel_while_prefix_referenced():
    """Cancelling the PUBLISHER of shared prefix pages while another
    request still references them: the pages survive (index + peer refs),
    the peer completes token-exact, and the end state holds exactly the
    index's references."""
    cfg, params = _setup()
    rng = np.random.default_rng(11)
    sys_p = rng.integers(0, cfg.vocab_size, 9).astype(np.int32)  # 2 pages
    pa = np.concatenate([sys_p, rng.integers(0, cfg.vocab_size, 3)]) \
        .astype(np.int32)
    pb = np.concatenate([sys_p, rng.integers(0, cfg.vocab_size, 5)]) \
        .astype(np.int32)
    ref_b = _ref(cfg, params, pb, 6)

    eng = Engine(cfg, params, max_len=32, n_slots=2, paged=True, page_size=4,
                 prefix_sharing=True)
    ra = eng.submit(pa, 12)
    eng.step()                               # A prefills + publishes
    rb = eng.submit(pb, 6)
    eng.step()                               # B admitted via the index
    assert eng.n_prefix_hits == 1
    shared = [int(p) for p in eng.page_tbl[0, :2]]
    assert eng.cancel(ra)                    # publisher goes away
    for pid in shared:                       # …but the pages must not
        assert eng.allocator.refcount(pid) >= 2   # index + B still hold
    eng.allocator.check_invariants()
    out = eng.run()
    np.testing.assert_array_equal(out[rb], ref_b)
    _assert_drained(eng)                     # in_use == index entries


# ------------------------------------------------------- AsyncEngine ------

@pytest.mark.parametrize("mode", list(MODES))
def test_async_stream_parity_all_modes(mode):
    """submit_stream() yields the exact generate() tokens, live, in every
    engine mode; shutdown(drain=True) leaves zero leaked pages."""
    cfg, params = _setup()
    prompts = _prompts(cfg, [5, 9, 7], seed=13)
    refs = [_ref(cfg, params, p, 6) for p in prompts]

    eng = Engine(cfg, params, max_len=32, n_slots=2, **MODES[mode])
    with AsyncEngine(eng) as aeng:
        streams = [aeng.submit_stream(p, 6) for p in prompts]
        outs = [list(s) for s in streams]
    for s, got, want in zip(streams, outs, refs):
        assert s.status == "finished", (s.status, s.error)
        np.testing.assert_array_equal(np.asarray(got, np.int32), want)
        np.testing.assert_array_equal(s.result(), want)
    _assert_drained(eng)


def test_async_cancel_mid_stream():
    """cancel() from the consumer thread ends the stream with its partial
    (greedy-prefix-exact) tokens; the other in-flight request and the
    allocator are unaffected."""
    cfg, params = _setup()
    pa, pb = _prompts(cfg, [5, 9], seed=17)
    ref_a, ref_b = _ref(cfg, params, pa, 26), _ref(cfg, params, pb, 6)

    eng = Engine(cfg, params, max_len=32, n_slots=2, paged=True, page_size=4)
    # throttled steps: the cancel must land before sa's 26 tokens complete
    # even if this (consumer) thread gets descheduled after token 2
    with AsyncEngine(eng,
                     step_cb=lambda _e: time.sleep(0.005)) as aeng:
        sa = aeng.submit_stream(pa, 26)
        sb = aeng.submit_stream(pb, 6)
        it = iter(sa)
        got = [next(it), next(it)]
        aeng.cancel(sa.rid)
        got += list(it)                      # drains to the terminal mark
        np.testing.assert_array_equal(sb.result(timeout=60), ref_b)
    assert sa.status == "cancelled" and 2 <= len(got) < 26
    np.testing.assert_array_equal(np.asarray(got, np.int32),
                                  ref_a[:len(got)])
    _assert_drained(eng)


def test_async_backpressure_rejects_when_full():
    """Past max_pending live requests, submit_stream returns a stream
    already ended status="rejected" (reject-with-error, no exception);
    capacity frees as requests finish."""
    cfg, params = _setup()
    pa, pb, pc = _prompts(cfg, [5, 7, 6], seed=19)
    ref_a = _ref(cfg, params, pa, 12)

    eng = Engine(cfg, params, max_len=24, n_slots=1)
    with AsyncEngine(eng, max_pending=2) as aeng:
        sa = aeng.submit_stream(pa, 12)
        sb = aeng.submit_stream(pb, 4)
        sc = aeng.submit_stream(pc, 4)       # third live: over capacity
        assert sc.status == "rejected" and "capacity" in sc.error
        assert list(sc) == [] and len(sc.result()) == 0
        np.testing.assert_array_equal(sa.result(timeout=60), ref_a)
        sb.result(timeout=60)
        sd = aeng.submit_stream(pc, 4)       # capacity freed: accepted
        assert sd.result(timeout=60).shape == (4,)
    assert eng.n_rejected == 1


def test_async_oversize_submit_streams_rejection():
    """An unservable submission surfaces on the STREAM (status rejected,
    error set) — the host loop and socket handlers never see a raise."""
    cfg, params = _setup()
    eng = Engine(cfg, params, max_len=16, n_slots=1)
    with AsyncEngine(eng) as aeng:
        s = aeng.submit_stream(np.arange(14, dtype=np.int32), 10)
        assert s.status == "rejected" and "max_len" in s.error
        assert list(s) == []
        # overload/reject records never pile up engine- or wrapper-side
        assert s.rid not in eng.finished and aeng._early_end == {}
        # a DIRECT submit on the wrapped engine (no stream) must not
        # stash an early-end entry either — only engine.finished owns it
        rid = eng.submit(np.arange(3, dtype=np.int32), 2)
        deadline = time.monotonic() + 60
        while rid not in eng.finished and time.monotonic() < deadline:
            time.sleep(0.005)
        assert len(eng.finished[rid].tokens) == 2
        assert aeng._early_end == {}


def test_async_direct_submit_wakes_idle_loop():
    """A DIRECT engine.submit() on a wrapped engine must wake the
    event-driven step loop (Engine.on_submit hook): with the loop parked
    idle, the request is served promptly instead of waiting for the next
    unrelated wake (regression: the loop used to learn about direct
    submissions only when submit_stream/cancel/shutdown set the event)."""
    cfg, params = _setup()
    eng = Engine(cfg, params, max_len=16, n_slots=1)
    with AsyncEngine(eng) as aeng:
        time.sleep(0.2)                    # loop is parked in _wake.wait()
        rid = eng.submit(np.arange(3, dtype=np.int32), 2)
        deadline = time.monotonic() + 10
        while rid not in eng.finished and time.monotonic() < deadline:
            time.sleep(0.005)
        assert rid in eng.finished, "idle loop never woke for direct submit"
        assert len(eng.finished[rid].tokens) == 2
        assert aeng._early_end == {}


def test_async_shutdown_abort_cancels_live():
    """shutdown(drain=False) cancels everything still live: streams end
    terminally, pages are returned, nothing leaks."""
    cfg, params = _setup()
    pa, pb = _prompts(cfg, [5, 9], seed=23)

    eng = Engine(cfg, params, max_len=40, n_slots=2, paged=True, page_size=4)
    aeng = AsyncEngine(eng)
    sa = aeng.submit_stream(pa, 30)
    sb = aeng.submit_stream(pb, 30)
    it = iter(sa)
    next(it)                                 # ensure work actually started
    aeng.shutdown(drain=False)
    for s in (sa, sb):
        assert s.done and s.status in ("cancelled", "aborted"), s.status
    _assert_drained(eng)
    with pytest.raises(RuntimeError):
        aeng.submit_stream(pa, 4)            # closed for business


def test_async_step_exception_surfaces():
    """A step-loop exception does not vanish: live requests are cancelled
    (no leaked pages), streams end, and shutdown() re-raises."""
    cfg, params = _setup()
    p = _prompts(cfg, [5], seed=29)[0]

    eng = Engine(cfg, params, max_len=24, n_slots=1, paged=True, page_size=4)
    boom = RuntimeError("injected step failure")

    def bad_step_cb(e):
        raise boom

    aeng = AsyncEngine(eng, step_cb=bad_step_cb)
    s = aeng.submit_stream(p, 8)
    s.result(timeout=60)                     # stream still ends terminally
    assert s.status in ("cancelled", "aborted"), s.status
    with pytest.raises(RuntimeError):
        aeng.shutdown()
    _assert_drained(eng)


def test_async_concurrent_submitters():
    """Many client threads submitting concurrently against a small engine:
    every stream completes token-exact (locked rid allocation + single-
    consumer queue keep the scheduler coherent under contention)."""
    cfg, params = _setup()
    prompts = _prompts(cfg, [4, 5, 6, 7, 8, 9], seed=31)
    refs = [_ref(cfg, params, p, 5) for p in prompts]

    eng = Engine(cfg, params, max_len=16, n_slots=2, paged=True, page_size=4)
    streams = [None] * len(prompts)
    with AsyncEngine(eng) as aeng:
        def worker(i):
            streams[i] = aeng.submit_stream(prompts[i], 5)
            streams[i].result(timeout=120)

        ts = [threading.Thread(target=worker, args=(i,))
              for i in range(len(prompts))]
        for t in ts:
            t.start()
        for t in ts:
            t.join(120)
    for s, want in zip(streams, refs):
        assert s is not None and s.status == "finished"
        np.testing.assert_array_equal(s.result(), want)
    _assert_drained(eng)


# ------------------------------------------------------- TCP frontend -----

def _start_server(eng, **kw):
    from repro.launch.server import NBLServer
    aeng = AsyncEngine(eng, **kw)
    srv = NBLServer(aeng, port=0)
    threading.Thread(target=srv.serve_forever, daemon=True).start()
    return srv


class _Conn:
    def __init__(self, port):
        self.sock = socket.create_connection(("127.0.0.1", port),
                                             timeout=120)
        self.reader = self.sock.makefile("r", encoding="utf-8")

    def send(self, obj):
        self.sock.sendall((json.dumps(obj) + "\n").encode())

    def recv(self):
        return json.loads(self.reader.readline())

    def close(self):
        # the makefile wrapper holds its own reference to the underlying
        # socket — FIN is only sent once BOTH are closed
        self.reader.close()
        self.sock.close()


def test_server_loopback_stream_cancel_stats():
    """Protocol end-to-end on a loopback socket: interleaved streams,
    mid-stream cancel, stats, ping, malformed-line tolerance — survivors
    token-exact, zero pages leaked."""
    cfg, params = _setup()
    pa, pb = _prompts(cfg, [5, 9], seed=37)
    ref_a = _ref(cfg, params, pa, 6)
    ref_b = _ref(cfg, params, pb, 24)

    eng = Engine(cfg, params, max_len=40, n_slots=2, paged=True, page_size=4)
    # throttled steps: the mid-stream cancel below must win its race with
    # the victim's completion even when this process gets descheduled
    srv = _start_server(eng, step_cb=lambda _e: time.sleep(0.01))
    c = _Conn(srv.port)
    try:
        c.send({"op": "ping"})
        assert c.recv()["event"] == "pong"
        c.sock.sendall(b"this is not json\n")
        assert c.recv()["event"] == "error"

        c.send({"op": "submit", "prompt": [int(t) for t in pa],
                "max_new": 6, "tag": "a"})
        c.send({"op": "submit", "prompt": [int(t) for t in pb],
                "max_new": 24, "tag": "b"})
        rids, toks, done = {}, {}, {}
        while len(done) < 2:
            ev = c.recv()
            if ev["event"] == "submitted":
                rids[ev["tag"]] = ev["rid"]
                toks[ev["rid"]] = []
            elif ev["event"] == "token":
                toks[ev["rid"]].append(ev["token"])
                assert ev["index"] == len(toks[ev["rid"]]) - 1
                if ev["rid"] == rids.get("b") and ev["index"] == 1:
                    c.send({"op": "cancel", "rid": rids["b"]})
            elif ev["event"] == "done":
                done[ev["rid"]] = ev
        a, b = done[rids["a"]], done[rids["b"]]
        assert a["status"] == "finished"
        np.testing.assert_array_equal(np.asarray(a["tokens"]), ref_a)
        assert b["status"] == "cancelled"
        np.testing.assert_array_equal(np.asarray(b["tokens"]),
                                      ref_b[:len(b["tokens"])])
        # streamed tokens match the final arrays (live feed == result)
        np.testing.assert_array_equal(toks[rids["a"]], a["tokens"])
        c.send({"op": "stats"})
        st = c.recv()["stats"]
        assert st["pages_in_use"] == 0 and st["n_cancelled"] == 1
    finally:
        c.close()
        srv.shutdown(drain=True)
    _assert_drained(eng)


def test_server_rejection_is_an_event_not_a_crash():
    """An oversize submit comes back as a done/rejected EVENT; the
    connection (and the host loop) survive and serve the next request."""
    cfg, params = _setup()
    good = _prompts(cfg, [5], seed=41)[0]
    ref = _ref(cfg, params, good, 4)

    eng = Engine(cfg, params, max_len=16, n_slots=1)
    srv = _start_server(eng)
    c = _Conn(srv.port)
    try:
        c.send({"op": "submit", "prompt": list(range(14)), "max_new": 10})
        assert c.recv()["event"] == "submitted"
        ev = c.recv()
        assert ev["event"] == "done" and ev["status"] == "rejected"
        assert "max_len" in ev["error"]
        c.send({"op": "submit", "prompt": [int(t) for t in good],
                "max_new": 4})
        assert c.recv()["event"] == "submitted"
        evs = []
        while not evs or evs[-1]["event"] != "done":
            evs.append(c.recv())
        np.testing.assert_array_equal(np.asarray(evs[-1]["tokens"]), ref)
    finally:
        c.close()
        srv.shutdown(drain=True)


def test_async_no_retain_results_bounds_memory():
    """retain_results=False drops each terminal request from
    engine.finished once its stream carries the result — the long-running
    server's memory knob; streams still deliver exact tokens."""
    cfg, params = _setup()
    prompts = _prompts(cfg, [5, 9], seed=47)
    refs = [_ref(cfg, params, p, 5) for p in prompts]

    eng = Engine(cfg, params, max_len=16, n_slots=2)
    with AsyncEngine(eng, retain_results=False) as aeng:
        streams = [aeng.submit_stream(p, 5) for p in prompts]
        # the REJECT path must not linger either (it is the overload path
        # backpressure exists for): oversize submit-time rejection
        sr = aeng.submit_stream(np.arange(14, dtype=np.int32), 10)
        assert sr.status == "rejected"
        for s, want in zip(streams, refs):
            np.testing.assert_array_equal(s.result(timeout=60), want)
    assert eng.finished == {}                # nothing retained, rejects incl.
    assert len(aeng._streams) == 0           # terminal streams dropped too


def test_server_cancel_scoped_to_connection():
    """One client cannot cancel another's request: a foreign rid gets an
    error event and the victim's generation completes untouched."""
    cfg, params = _setup()
    p = _prompts(cfg, [5], seed=53)[0]
    ref = _ref(cfg, params, p, 10)

    eng = Engine(cfg, params, max_len=16, n_slots=1)
    srv = _start_server(eng)
    a, b = _Conn(srv.port), _Conn(srv.port)
    try:
        a.send({"op": "submit", "prompt": [int(t) for t in p],
                "max_new": 10})
        rid = a.recv()["rid"]
        b.send({"op": "cancel", "rid": rid})     # foreign rid
        ev = b.recv()
        assert ev["event"] == "error" and "per-connection" in ev["error"]
        evs = []
        while not evs or evs[-1]["event"] != "done":
            evs.append(a.recv())
        assert evs[-1]["status"] == "finished"
        np.testing.assert_array_equal(np.asarray(evs[-1]["tokens"]), ref)
    finally:
        a.close()
        b.close()
        srv.shutdown(drain=True)


def test_server_submit_after_shutdown_is_an_error_event():
    """A submit that can no longer be served (engine host loop stopped)
    comes back as an "error" EVENT on the still-open connection — the
    protocol's no-dropped-connections promise holds even past shutdown."""
    cfg, params = _setup()
    eng = Engine(cfg, params, max_len=16, n_slots=1)
    srv = _start_server(eng)
    c = _Conn(srv.port)
    try:
        c.send({"op": "ping"})               # handshake: the connection
        assert c.recv()["event"] == "pong"   # must be ACCEPTED before the
        srv.shutdown(drain=True)             # listener closes, or it dies
        c.send({"op": "submit", "prompt": [1, 2, 3], "max_new": 2})
        ev = c.recv()
        assert ev["event"] == "error" and "submit failed" in ev["error"]
        c.send({"op": "ping"})               # connection still serviceable
        assert c.recv()["event"] == "pong"
    finally:
        c.close()


def test_server_disconnect_cancels_inflight():
    """A client that vanishes mid-stream must not leak its pages: the
    connection teardown cancels its in-flight request (the refcounted-
    prefix leak the async PR exists to close)."""
    cfg, params = _setup()
    p = _prompts(cfg, [9], seed=43)[0]

    eng = Engine(cfg, params, max_len=40, n_slots=2, paged=True, page_size=4,
                 prefix_sharing=True)
    srv = _start_server(eng)
    c = _Conn(srv.port)
    c.send({"op": "submit", "prompt": [int(t) for t in p], "max_new": 28})
    assert c.recv()["event"] == "submitted"
    assert c.recv()["event"] == "token"      # generation running
    c.close()                                # vanish mid-stream
    deadline = time.monotonic() + 60
    while time.monotonic() < deadline:
        if eng.n_cancelled == 1 and not eng.has_work:
            break
        time.sleep(0.01)
    assert eng.n_cancelled == 1
    srv.shutdown(drain=True)
    _assert_drained(eng)                     # index refs only, no slot refs
