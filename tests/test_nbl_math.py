"""Property tests for the paper's math: Proposition 3.1 (LMMSE optimality)
and Theorem 3.2 (CCA NMSE bound)."""
from __future__ import annotations

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.cca import (canonical_correlations, inv_sqrt_psd, nmse_bound,
                            cca_bound_from_moments)
from repro.core.lmmse import lmmse_from_moments, lmmse_mse
from repro.core.moments import finalize, init_moments, update_moments


def _moments_for(x: np.ndarray, y: np.ndarray):
    mom = init_moments(x.shape[1], y.shape[1])
    mom = update_moments(mom, x, y)
    return finalize(mom)


def _rand_xy(seed: int, n: int, d_in: int, d_out: int, noise: float,
             nonlin: bool = False):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, d_in)).astype(np.float64)
    a = rng.standard_normal((d_in, d_out)) / np.sqrt(d_in)
    y = x @ a + noise * rng.standard_normal((n, d_out))
    if nonlin:
        y = np.tanh(y) + 0.3 * y
    return x, y


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), d=st.sampled_from([3, 5, 8, 16]),
       noise=st.floats(0.0, 2.0), nonlin=st.booleans())
def test_theorem_3_2_bound_holds(seed, d, noise, nonlin):
    """Achieved NMSE of the LMMSE estimator never exceeds the CCA bound."""
    x, y = _rand_xy(seed, 400 + 20 * d, d, d, noise, nonlin)
    fin = _moments_for(x, y - x)          # treat y as residual output y₊
    w, b = lmmse_from_moments(fin, ridge=1e-9)
    # direct NMSE of ŷ₊ = x + Wx + b against y
    yhat = x + x @ w.T + b
    nmse = float(np.mean(np.sum((y - yhat) ** 2, -1))
                 / np.mean(np.sum((y - y.mean(0)) ** 2, -1)))
    bound, rho = cca_bound_from_moments(fin)
    assert np.all(rho >= 0) and np.all(rho <= 1)
    assert nmse <= bound * (1 + 1e-6) + 1e-8, (nmse, bound)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), d=st.sampled_from([4, 8]),
       noise=st.floats(0.0, 1.0))
def test_lmmse_optimality(seed, d, noise):
    """Prop 3.1: any perturbation of (W, b) increases the empirical MSE."""
    x, y = _rand_xy(seed, 600, d, d, noise)
    fin = _moments_for(x, y)
    w, b = lmmse_from_moments(fin, ridge=1e-10)

    def mse(wm, bm):
        return float(np.mean(np.sum((y - (x @ wm.T + bm)) ** 2, -1)))

    base = mse(w, b)
    rng = np.random.default_rng(seed + 1)
    for scale in (1e-2, 1e-1):
        dw = rng.standard_normal(w.shape) * scale
        db = rng.standard_normal(b.shape) * scale
        assert mse(w + dw, b + db) >= base - 1e-9


def test_exact_linear_recovery():
    """If Y is exactly affine in X, NBL recovers it and the bound ≈ 0."""
    rng = np.random.default_rng(0)
    x = rng.standard_normal((2000, 12))
    a = rng.standard_normal((12, 12))
    c = rng.standard_normal(12)
    y = x @ a + c
    fin = _moments_for(x, y)
    w, b = lmmse_from_moments(fin, ridge=1e-12)
    np.testing.assert_allclose(w, a.T, atol=1e-4)
    np.testing.assert_allclose(b, c, atol=1e-4)
    # y₊ = y + x is also exactly affine -> all canonical correlations 1
    bound, rho = cca_bound_from_moments(fin)
    assert bound < 1e-4, bound


def test_inv_sqrt_psd():
    rng = np.random.default_rng(3)
    m = rng.standard_normal((6, 6))
    c = m @ m.T + 0.1 * np.eye(6)
    s = inv_sqrt_psd(c, eps=1e-12)
    np.testing.assert_allclose(s @ c @ s, np.eye(6), atol=1e-8)


def test_nmse_bound_underdetermined_term():
    # h_out > h_in adds (h_out - r)
    rho = np.array([1.0, 1.0])
    assert nmse_bound(rho, h_out=5, h_in=2) == pytest.approx(3.0)
    assert nmse_bound(rho, h_out=2, h_in=2) == pytest.approx(0.0)


def test_streaming_equals_batch_moments():
    """Accumulating in chunks == one-shot (the distributed-merge property)."""
    rng = np.random.default_rng(5)
    x = rng.standard_normal((512, 8)).astype(np.float32)
    y = rng.standard_normal((512, 8)).astype(np.float32)
    one = init_moments(8, 8)
    one = update_moments(one, x, y)
    two = init_moments(8, 8)
    for i in range(0, 512, 128):
        two = update_moments(two, x[i:i + 128], y[i:i + 128])
    fa, fb = finalize(one), finalize(two)
    for k in ("cxx", "cyx", "cypyp", "ex", "ey"):
        np.testing.assert_allclose(fa[k], fb[k], atol=1e-3)
