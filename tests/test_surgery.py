"""Surgery invariants: regrouping, param re-stacking, cache structure."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import Block, StackGroup
from repro.core.surgery import _regroup, compress, compress_config
from repro.models import apply, init_cache, init_params


def test_regroup_preserves_order_and_prefers_scan():
    blocks = [Block(kind="attn")] * 10 + [Block(kind="nbl")] * 4
    groups = _regroup(blocks)
    flat = [b for g in groups for b in list(g.unit) * g.repeat]
    assert flat == blocks
    assert groups[0].repeat == 10 and groups[1].repeat == 4


def test_regroup_detects_periods():
    a, b = Block(kind="attn", window=32), Block(kind="attn")
    blocks = [a, b] * 6
    groups = _regroup(blocks)
    assert len(groups) == 1 and groups[0].repeat == 6
    assert groups[0].unit == (a, b)


def test_compress_config_marks_layers():
    cfg = get_config("tiny-dense")
    new = compress_config(cfg, [4, 5], "nbl")
    kinds = [b.kind for b in new.blocks()]
    assert kinds == ["attn"] * 4 + ["nbl"] * 2
    assert new.nbl_layers == (4, 5)
    assert new.n_blocks == cfg.n_blocks


@pytest.mark.parametrize("mode", ["nbl", "drop", "nbl_block", "drop_block"])
def test_compressed_forward_matches_manual(mode):
    """Surgery output == running the original blocks with the substitution
    applied by hand (drop: identity mixer; nbl: x + Wx + b)."""
    cfg = get_config("tiny-dense")
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    d = cfg.d_model
    rng = np.random.default_rng(0)
    w = (rng.standard_normal((d, d)) * 0.02)
    b = rng.standard_normal(d) * 0.01
    ids = [3, 5]
    maps = {i: (w, b) for i in ids}
    ncfg, nparams = compress(cfg, params, ids, mode, linear_maps=maps)
    out, _ = apply(ncfg, nparams, toks)
    assert out.shape == (2, 16, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(out)))

    # dropping everything == removing those layers' contribution entirely
    if mode == "drop_block":
        full_ids = list(range(cfg.n_blocks))
        ecfg, eparams = compress(cfg, params, full_ids, mode)
        out2, _ = apply(ecfg, eparams, toks)
        # model reduces to embed -> final_norm -> head
        from repro.models.layers import rmsnorm, embed_tokens
        x = embed_tokens(params["embed"], toks, jnp.float32)
        x = rmsnorm(x, params["final_norm"], ncfg.norm_eps)
        want = x @ params["embed"].T
        np.testing.assert_allclose(np.asarray(out2), np.asarray(want),
                                   atol=1e-4, rtol=1e-4)


def test_nbl_layers_have_no_cache():
    cfg = get_config("tiny-dense")
    ncfg = compress_config(cfg, [4, 5], "nbl")
    cache = init_cache(ncfg, batch=2, max_len=64)
    # the nbl group's cache sub-tree is empty (no K/V storage at all)
    assert all(c is None for c in cache["groups"][-1]["blocks"])
    # byte accounting: exactly (K-m)/K of the attention cache remains
    from repro.models.kv_cache import cache_bytes
    base = cache_bytes(cfg, 2, 64)
    comp = cache_bytes(ncfg, 2, 64)
    kv, hd, w = cfg.n_kv_heads, cfg.head_dim, 64
    per_layer = 2 * 2 * kv * w * hd * 4 + w * 4
    assert base - comp == 2 * per_layer


def test_nbl_equals_manual_linear():
    """A compressed nbl layer computes exactly x + xW + b."""
    cfg = get_config("tiny-dense").replace(
        stack=(StackGroup(unit=(Block(kind="attn"),), repeat=1),))
    params = init_params(jax.random.PRNGKey(0), cfg)
    d = cfg.d_model
    w = np.eye(d) * 0.5
    bvec = np.ones(d) * 0.1
    ncfg, nparams = compress(cfg, params, [0], "nbl",
                             linear_maps={0: (w, bvec)})
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0,
                              cfg.vocab_size)
    from repro.models.layers import embed_tokens, rmsnorm, mlp
    x = embed_tokens(params["embed"], toks, jnp.float32)
    h = x + (x @ w.T + bvec)
    lp, _ = jax.tree.leaves, None
    p0 = jax.tree.map(lambda a: a[0], nparams["groups"][0]["scanned"][0])
    h2 = h + mlp(p0["ffn"], rmsnorm(h, p0["norm2"], cfg.norm_eps),
                 cfg.mlp_act)
    want = rmsnorm(h2, nparams["final_norm"], cfg.norm_eps) \
        @ nparams["embed"].T
    got, _ = apply(ncfg, nparams, toks)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


def test_zamba_shared_params_surgery():
    """Linearizing mamba blocks in a hybrid keeps the shared attn intact."""
    cfg = get_config("tiny-zamba")
    params = init_params(jax.random.PRNGKey(0), cfg)
    mamba_ids = [i for i, b in enumerate(cfg.blocks()) if b.kind == "mamba"]
    d = cfg.d_model
    maps = {i: (np.zeros((d, d)), np.zeros(d)) for i in mamba_ids[:2]}
    ncfg, nparams = compress(cfg, params, mamba_ids[:2], "nbl",
                             linear_maps=maps)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    out, _ = apply(ncfg, nparams, toks)
    assert np.all(np.isfinite(np.asarray(out)))
