"""Paged KV-cache subsystem: kernel parity, engine parity vs the ring
decode path, recycled-page isolation, refcounted allocator invariants,
prefix sharing (copy-on-write pages), chunked prefill (page-aligned
prefill-decode interleaving), page budget, preemption, and prompt-length
bucketing."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests._hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

from repro.configs import get_config
from repro.core.surgery import compress_config, nbl_variant
from repro.launch.engine import Engine
from repro.launch.scheduler import latency_stats, nbl_page_budget, Request
from repro.launch.serve import generate
from repro.models import init_params
from repro.models.paging import (
    DoubleFreeError, PageAllocator, PrefixIndex, n_caching_attn_layers,
    page_bytes, pages_per_seq,
)


def _setup(arch="tiny-dense", seed=0):
    cfg = get_config(arch)
    params = init_params(jax.random.PRNGKey(seed), cfg)
    return cfg, params


def _ref(cfg, params, prompt, max_new):
    out = generate(cfg, params, jnp.asarray(prompt)[None], max_new=max_new)
    return np.asarray(out)[0]


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
            for n in lens]


# ----------------------------------------------------- kernel parity -------

@pytest.mark.parametrize("rep,window,softcap", [
    (1, None, None),       # MHA
    (2, None, None),       # GQA
    (2, 6, None),          # GQA + sliding window
    (2, None, 30.0),       # GQA + logit softcap
    (2, 6, 30.0),
])
def test_paged_kernel_matches_xla_ref(rep, window, softcap):
    """Interpret-mode Pallas kernel == XLA gather reference across
    GQA/window/softcap, with ragged lengths and an inactive slot."""
    from repro.kernels.paged_attention import paged_attention, paged_decode_xla

    rng = np.random.default_rng(0)
    b, kv, hd, ps, npg, pool = 4, 2, 16, 8, 4, 12
    q = jnp.asarray(rng.standard_normal((b, kv, rep, hd)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((pool, kv, ps, hd)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((pool, kv, ps, hd)), jnp.float32)
    tbl = np.full((b, npg), -1, np.int32)
    tbl[0, :3] = [4, 7, 1]          # 18 tokens
    tbl[1, :1] = [2]                # 5 tokens
    tbl[2, :4] = [0, 3, 5, 6]       # page-exact 32 tokens
    lens = jnp.asarray([18, 5, 32, 0], jnp.int32)   # slot 3 inactive

    out = paged_attention(q, kp, vp, jnp.asarray(tbl), lens,
                          window=window, softcap=softcap, interpret=True)
    ref = paged_decode_xla(q, kp, vp, jnp.asarray(tbl), lens,
                           window=window, softcap=softcap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)
    assert np.isfinite(np.asarray(out)).all()


@pytest.mark.parametrize("rep,window,softcap", [
    (1, None, None),
    (2, 6, None),
    (2, None, 30.0),
])
def test_paged_mixed_matches_virtual_rows(rep, window, softcap):
    """The fused step's mixed-row attention (one per-slot gather + dense
    masked softmax) == the same queries run as B*W virtual decode rows
    through the interpret-mode Pallas kernel — the TPU dispatch route —
    across a decode row, a mid-chunk row, an inactive row, and a short
    row with an invalid tail."""
    from repro.kernels.paged_attention import paged_attention, paged_mixed_xla

    rng = np.random.default_rng(1)
    b, kv, hd, ps, npg, pool, w = 4, 2, 16, 8, 4, 12, 4
    q = jnp.asarray(rng.standard_normal((b, kv, rep, w, hd)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((pool, kv, ps, hd)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((pool, kv, ps, hd)), jnp.float32)
    tbl = np.full((b, npg), -1, np.int32)
    tbl[0, :3] = [4, 7, 1]          # decode row at pos 17 (18 tokens)
    tbl[1, :2] = [2, 8]             # chunk row resuming at pos 8
    tbl[2, :1] = [3]                # short row: 2 valid + 2 invalid tail
    row_pos = jnp.asarray([17, 8, 1, 0], jnp.int32)
    row_len = jnp.asarray([1, w, 2, 0], jnp.int32)   # slot 3 inactive

    out = paged_mixed_xla(q, kp, vp, jnp.asarray(tbl), row_pos, row_len,
                          window=window, softcap=softcap)

    qv = jnp.transpose(q, (0, 3, 1, 2, 4)).reshape(b * w, kv, rep, hd)
    tpos = np.asarray(row_pos)[:, None] + np.arange(w)[None, :]
    valid = np.arange(w)[None, :] < np.asarray(row_len)[:, None]
    lens = jnp.asarray(np.where(valid, tpos + 1, 0).reshape(-1), jnp.int32)
    ref = paged_attention(qv, kp, vp,
                          jnp.asarray(np.repeat(tbl, w, axis=0)), lens,
                          window=window, softcap=softcap, interpret=True)
    ref = ref.reshape(b, w, kv, rep, hd).transpose(0, 2, 3, 1, 4)
    vmask = valid[:, None, None, :, None]            # invalid: both finite,
    np.testing.assert_allclose(np.asarray(out) * vmask,     # values differ
                               np.asarray(ref) * vmask, atol=2e-5, rtol=2e-5)
    assert np.isfinite(np.asarray(out)).all()


# ------------------------------------------------ engine decode parity -----

@pytest.mark.parametrize("arch", ["tiny-dense", "tiny-swa", "tiny-gemma",
                                  "tiny-zamba"])
def test_paged_engine_parity_matches_generate(arch):
    """Greedy tokens from the paged engine match the single-request
    generate() loop across dense / sliding-window / softcap / hybrid-SSM
    stacks (the paged analogue of the ring parity test)."""
    cfg, params = _setup(arch)
    prompts = _prompts(cfg, [6, 10, 8])
    refs = [_ref(cfg, params, p, 5) for p in prompts]

    eng = Engine(cfg, params, max_len=24, n_slots=2, paged=True, page_size=8)
    rids = [eng.submit(p, 5) for p in prompts]
    out = eng.run()
    for i, rid in enumerate(rids):
        np.testing.assert_array_equal(out[rid], refs[i], err_msg=f"req {i}")


def test_paged_engine_parity_nbl_compressed():
    """Paged serving of an NBL-compressed stack: linearized layers carry no
    page pool, and decode parity with generate() is exact."""
    cfg, _ = _setup()
    ncfg = compress_config(cfg, cfg.attn_layer_indices()[-2:], "nbl")
    params = init_params(jax.random.PRNGKey(1), ncfg)
    prompts = _prompts(ncfg, [7, 9])
    refs = [_ref(ncfg, params, p, 4) for p in prompts]

    eng = Engine(ncfg, params, max_len=16, n_slots=2, paged=True, page_size=8)
    rids = [eng.submit(p, 4) for p in prompts]
    out = eng.run()
    for i, rid in enumerate(rids):
        np.testing.assert_array_equal(out[rid], refs[i])


def test_paged_ring_same_tokens_under_load():
    """The two engines emit identical per-request tokens for an identical
    ragged stream (bit-comparable decode paths at the token level)."""
    cfg, params = _setup()
    prompts = _prompts(cfg, [4, 12, 6, 9, 5], seed=3)
    outs = {}
    for paged in (False, True):
        eng = Engine(cfg, params, max_len=20, n_slots=2, paged=paged,
                     page_size=8)
        rids = [eng.submit(p, 4) for p in prompts]
        got = eng.run()
        outs[paged] = [got[r] for r in rids]
    for a, b in zip(outs[False], outs[True]):
        np.testing.assert_array_equal(a, b)


# -------------------------------------------- recycled-page isolation ------

def test_recycled_pages_no_stale_kv():
    """Sequential tenancy through ONE slot: the second request reuses the
    first tenant's freed pages (same physical ids), and its tokens must be
    identical to a fresh engine's — any stale KV surviving the position
    mask would corrupt them."""
    cfg, params = _setup()
    long_p, short_p = _prompts(cfg, [14, 4], seed=11)

    eng = Engine(cfg, params, max_len=20, n_slots=1, paged=True, page_size=4)
    rid_a = eng.submit(long_p, 6)
    rid_b = eng.submit(short_p, 6)
    out = eng.run()
    assert len(out[rid_a]) == 6
    assert eng.allocator.in_use == 0            # all pages back on free list

    fresh = Engine(cfg, params, max_len=20, n_slots=1, paged=True,
                   page_size=4)
    rid_f = fresh.submit(short_p, 6)
    np.testing.assert_array_equal(out[rid_b], fresh.run()[rid_f])
    np.testing.assert_array_equal(out[rid_b], _ref(cfg, params, short_p, 6))


def test_freed_pages_not_attendable_by_new_owner():
    """Direct paged-cache check (the paged analogue of reset_slot's
    guarantee): after a request's pages are freed and handed to a new
    request, decode logits depend only on the new owner's tokens — asserted
    by comparing against a pool that never had a previous tenant."""
    from repro.models import decode_step, prefill
    from repro.models.paging import (assign_pages, build_page_table,
                                     init_paged_cache)

    cfg, params = _setup()
    ps, max_len = 4, 16
    old_p, new_p = _prompts(cfg, [12, 5], seed=21)

    def run_once(cache, tbl, prompt, page_ids):
        logits, pc = prefill(cfg, params, jnp.asarray(prompt)[None],
                             cache_len=pages_per_seq(len(prompt), ps) * ps,
                             paged=True)
        tbl = tbl.copy()
        npg = pages_per_seq(len(prompt), ps)
        tbl[0, :npg] = page_ids[:npg]
        cache = assign_pages(cfg, cache, pc, jnp.int32(0),
                             jnp.asarray(tbl[0]), page_size=ps)
        tok = jnp.argmax(logits[0, -1])[None, None].astype(jnp.int32)
        out, _ = decode_step(cfg, params, tok, cache,
                             jnp.asarray([len(prompt)], jnp.int32),
                             page_tbl=jnp.asarray(tbl))
        return np.asarray(out)

    tbl0 = build_page_table(1, max_len, ps)
    # tenancy 1: old_p occupies pages [0,1,2]; then "freed" (table cleared)
    dirty = init_paged_cache(cfg, 1, max_len, page_size=ps, n_pages=4)
    logits, pc = prefill(cfg, params, jnp.asarray(old_p)[None],
                         cache_len=12, paged=True)
    dirty = assign_pages(cfg, dirty, pc, jnp.int32(0),
                         jnp.asarray([0, 1, 2], jnp.int32), page_size=ps)
    # tenancy 2 on the DIRTY pool reuses pages [0,1] for the new prompt
    got = run_once(dirty, tbl0, new_p, [0, 1])
    clean = init_paged_cache(cfg, 1, max_len, page_size=ps, n_pages=4)
    want = run_once(clean, tbl0, new_p, [0, 1])
    np.testing.assert_allclose(got, want, atol=1e-6)


# ------------------------------------------------------ prefix sharing -----

def _shared_prompts(cfg, sys_len, tails, seed=0):
    rng = np.random.default_rng(seed)
    sys_p = rng.integers(0, cfg.vocab_size, sys_len).astype(np.int32)
    return [np.concatenate([sys_p, rng.integers(0, cfg.vocab_size, t)
                            .astype(np.int32)]) for t in tails]


@pytest.mark.parametrize("arch", ["tiny-dense", "tiny-swa", "tiny-gemma"])
def test_prefix_sharing_engine_parity(arch):
    """Shared-prefix batch served with prefix_sharing=True emits EXACTLY
    the single-request generate() tokens across dense-GQA / sliding-window
    / softcap stacks, and later admissions reuse the cached prefix (the
    suffix-only prefill path)."""
    cfg, params = _setup(arch)
    prompts = _shared_prompts(cfg, 17, [4, 7, 3, 5], seed=2)
    refs = [_ref(cfg, params, p, 5) for p in prompts]

    eng = Engine(cfg, params, max_len=48, n_slots=2, paged=True, page_size=8,
                 prefix_sharing=True)
    rids = [eng.submit(p, 5) for p in prompts]
    out = eng.run()
    for i, rid in enumerate(rids):
        np.testing.assert_array_equal(out[rid], refs[i], err_msg=f"req {i}")
    s = eng.stats()
    assert s["n_prefix_hits"] >= 3         # every follower hit the index
    assert s["n_shared_prompt_tokens"] >= 3 * 16
    assert s["n_prefill_tokens"] < sum(len(p) for p in prompts)
    eng.allocator.check_invariants()


@pytest.mark.parametrize("m", [1, 2])
def test_prefix_sharing_parity_nbl_compressed(m):
    """Prefix sharing over NBL-compressed stacks: linearized layers carry
    no pool (nothing to share there) and token parity stays exact — the
    m/K page-bill reduction applies to the shared pool too."""
    cfg, _ = _setup()
    ncfg = compress_config(cfg, cfg.attn_layer_indices()[-m:], "nbl")
    params = init_params(jax.random.PRNGKey(1), ncfg)
    prompts = _shared_prompts(ncfg, 18, [3, 6, 4], seed=4)
    refs = [_ref(ncfg, params, p, 4) for p in prompts]

    eng = Engine(ncfg, params, max_len=40, n_slots=2, paged=True,
                 page_size=8, prefix_sharing=True)
    rids = [eng.submit(p, 4) for p in prompts]
    out = eng.run()
    for i, rid in enumerate(rids):
        np.testing.assert_array_equal(out[rid], refs[i])
    assert eng.stats()["n_prefix_hits"] >= 2


def test_ring_vs_paged_sharing_same_tokens():
    """The ring engine and the paged engine WITH sharing emit identical
    per-request tokens on an identical shared-prefix stream."""
    cfg, params = _setup()
    prompts = _shared_prompts(cfg, 17, [4, 9, 2, 6, 5], seed=7)
    outs = {}
    for mode in ("ring", "shared"):
        kw = {} if mode == "ring" else dict(paged=True, page_size=8,
                                            prefix_sharing=True)
        eng = Engine(cfg, params, max_len=40, n_slots=2, **kw)
        rids = [eng.submit(p, 4) for p in prompts]
        got = eng.run()
        outs[mode] = [got[r] for r in rids]
    for a, b in zip(outs["ring"], outs["shared"]):
        np.testing.assert_array_equal(a, b)


def test_retiring_owner_keeps_shared_pages_alive():
    """A slot retiring while its prefix pages are still referenced (by the
    index, and transitively by a follower slot) must NOT free them: the
    refcount holds, the follower's decode stays exact, and the pages leave
    the pool only after every reference is dropped."""
    cfg, params = _setup()
    prompts = _shared_prompts(cfg, 17, [2, 6], seed=9)
    refs = [_ref(cfg, params, p, n) for p, n in zip(prompts, (2, 8))]

    # one slot: the publisher retires (short generation) while the index
    # still references its prefix pages; the follower then shares them.
    eng = Engine(cfg, params, max_len=40, n_slots=1, paged=True, page_size=8,
                 prefix_sharing=True)
    rid_a = eng.submit(prompts[0], 2)
    rid_b = eng.submit(prompts[1], 8)
    out = eng.run()
    np.testing.assert_array_equal(out[rid_a], refs[0])
    np.testing.assert_array_equal(out[rid_b], refs[1])
    s = eng.stats()
    assert s["n_prefix_hits"] == 1          # B reused A's published prefix
    # retirement dropped only the slots' references; the index still pins
    # its entries — nothing was freed that something still referenced.
    assert eng.allocator.in_use == eng.prefix_index.n_entries > 0
    eng.allocator.check_invariants()


def test_index_eviction_then_realloc_no_leakage():
    """Pages released at refcount 0 (after LRU index eviction under pool
    pressure) and REALLOCATED to a different prompt show no token-level
    leakage: the new tenant's output equals a fresh engine's."""
    cfg, params = _setup()
    a = _shared_prompts(cfg, 16, [3], seed=11)[0]
    b = _shared_prompts(cfg, 16, [4], seed=99)[0]   # disjoint prompt
    ref_b = _ref(cfg, params, b, 6)

    # pool too small to keep A's prefix cached while B runs: admitting B
    # must evict A's unreferenced index entries and reuse those pages.
    eng = Engine(cfg, params, max_len=32, n_slots=1, paged=True, page_size=8,
                 prefix_sharing=True)
    from repro.models.paging import PageAllocator as PA
    eng.allocator = PA(4)                   # = pages_per_seq(32, 8): 1 req
    eng.n_pages = 4
    rid_a = eng.submit(a, 4)
    rid_b = eng.submit(b, 6)
    out = eng.run(max_steps=200)
    assert len(out[rid_a]) == 4
    np.testing.assert_array_equal(out[rid_b], ref_b)
    assert eng.prefix_index.n_entries <= 2  # A's entries were evicted
    eng.allocator.check_invariants()


def test_prefix_index_lookup_insert_evict():
    """Index unit semantics: longest page-aligned PROPER prefix, last
    (partial or final) page never indexed/shared, leaf-first LRU eviction
    restricted to refcount-1 pages."""
    idx = PrefixIndex(4)
    alloc = PageAllocator(8)
    prompt = np.arange(10)                  # pages: [0..4) [4..8) | partial
    ids = alloc.alloc(3)
    assert idx.insert(prompt, ids, alloc) == 2     # 10 // 4 full pages
    assert alloc.refcount(ids[0]) == 2 and alloc.refcount(ids[2]) == 1
    # full re-insert of the same prefix adds nothing
    assert idx.insert(prompt, ids, alloc) == 0

    k, hit = idx.lookup(prompt)
    assert (k, hit) == (2, ids[:2])
    k, hit = idx.lookup(np.arange(8))       # aligned: cap at (8-1)//4 = 1
    assert (k, hit) == (1, ids[:1])
    k, hit = idx.lookup(np.arange(100, 110))
    assert (k, hit) == (0, [])

    alloc.unref(ids)                        # publisher retires
    assert alloc.in_use == 2                # index still pins 2 pages
    # blocked subtree (the SWA window-release shape): an rc-1 parent above
    # a still-referenced child frees nothing — the exact count knows it
    alloc.ref(ids[1:2])                     # child pinned by a "slot"
    assert idx.evictable_pages(alloc) == 0
    assert idx.evict_lru(alloc, 2) == 0
    alloc.unref(ids[1:2])
    assert idx.evictable_pages(alloc) == 2
    # deeper node is younger; eviction is LRU leaf-first: depth-2 first
    assert idx.evict_lru(alloc) == 1 and idx.n_entries == 1
    k, _ = idx.lookup(prompt)
    assert k == 1                           # shallow entry still serves
    assert idx.evict_lru(alloc) == 1 and idx.evict_lru(alloc) == 0
    assert alloc.in_use == 0
    alloc.check_invariants()


def test_prefix_sharing_gates_stateful_stacks():
    """Sharing keys the index on prompt TOKENS only, so any stack whose
    prefix KV is not a pure function of those tokens is refused: SSM
    (scanned state cannot resume) and cross-attention (KV downstream of a
    cross_attn block is conditioned on per-request enc embeddings)."""
    for arch in ("tiny-mamba", "tiny-zamba", "tiny-vlm"):
        cfg, params = _setup(arch)
        with pytest.raises(ValueError):
            Engine(cfg, params, max_len=16, n_slots=1, paged=True,
                   page_size=8, prefix_sharing=True)


def test_unadmittable_request_does_not_wipe_index():
    """A queued request that eviction provably cannot satisfy must defer
    WITHOUT evicting anything: wiping every warm prefix to still fail
    would convert other requests' future hits into full prefills."""
    cfg, params = _setup()
    a = _shared_prompts(cfg, 17, [0], seed=3)[0][:17]    # 2 full pages
    eng = Engine(cfg, params, max_len=40, n_slots=2, paged=True, page_size=8,
                 prefix_sharing=True)
    from repro.models.paging import PageAllocator as PA
    eng.allocator = PA(4)
    eng.n_pages = 4
    rid_a = eng.submit(a, 2)
    eng.run()
    assert len(eng.run()[rid_a]) == 2
    assert eng.prefix_index.n_entries == 2      # warm cache, rc 1 each
    big = _prompts(cfg, [33], seed=8)[0]        # 5 pages > 2 free + 2 evict
    eng.submit(big, 1)
    for _ in range(3):
        eng.step()
    assert len(eng.scheduler) == 1              # still deferred...
    assert eng.prefix_index.n_entries == 2      # ...and the cache survived
    eng.allocator.check_invariants()


def test_prefix_index_evicts_deep_chains():
    """Regression: eviction walks the trie iteratively — a prefix deeper
    than the interpreter recursion limit (thousands of full pages) must
    evict cleanly, leaf-first, without RecursionError."""
    import sys
    depth = sys.getrecursionlimit() + 200
    idx = PrefixIndex(1)                    # 1 token per page: deep chain
    alloc = PageAllocator(depth)
    ids = alloc.alloc(depth)
    idx.insert(np.arange(depth) % 7, ids, alloc)
    assert idx.n_entries == depth
    alloc.unref(ids)                        # publisher gone: all rc 1
    for _ in range(3):
        assert idx.evict_lru(alloc) == 1
    assert idx.n_entries == depth - 3
    alloc.check_invariants()


def test_nbl_page_budget_bills_shared_prefix_once():
    """Shared-prefix billing: the common prompt pages count once against
    the pool, not once per request — admitted concurrency rises, and stays
    monotone in NBL-m."""
    cfg, _ = _setup()
    budget = 12 * n_caching_attn_layers(cfg) * page_bytes(cfg, 8)
    plain = nbl_page_budget(cfg, budget, page_size=8, expected_len=48)
    shared = nbl_page_budget(cfg, budget, page_size=8, expected_len=48,
                             shared_prefix_len=32)
    assert plain == 2                       # 12 pages / 6 per request
    assert shared == 4                      # (12-4) / (6-4)
    seq = [nbl_page_budget(nbl_variant(cfg, m), budget, page_size=8,
                           expected_len=48, shared_prefix_len=32)
           for m in range(4)]
    assert seq == sorted(seq)


# ----------------------------------------------------- allocator -----------

def test_allocator_basic():
    a = PageAllocator(4)
    ids = a.alloc(3)
    assert sorted(ids) == sorted(set(ids)) and len(ids) == 3
    assert a.alloc(2) is None                  # all-or-nothing
    assert a.free_pages == 1
    a.free(ids[:1])
    assert a.free_pages == 2
    with pytest.raises(DoubleFreeError):
        a.free(ids[:1])
    with pytest.raises(DoubleFreeError):
        a.free([99])                           # foreign id
    a.check_invariants()


def test_allocator_refcounts():
    """ref pins a page across its allocator's release; unref at refcount 0
    — and only then — returns it to the free list."""
    a = PageAllocator(4)
    ids = a.alloc(2)
    a.ref(ids)                                 # rc 2 each
    a.unref(ids)
    assert a.in_use == 2 and a.free_pages == 2   # still pinned at rc 1
    a.unref(ids[:1])
    assert a.in_use == 1 and a.free_pages == 3
    with pytest.raises(DoubleFreeError):
        a.ref([ids[0]])                        # ref of a free page
    a.unref(ids[1:])
    a.check_invariants()
    assert a.in_use == 0


def test_allocator_free_is_atomic():
    """free/unref validates the WHOLE id list before mutating: a call that
    raises must leave every page exactly as it found it — including
    duplicate ids within one call, which count once per occurrence."""
    a = PageAllocator(6)
    ids = a.alloc(3)
    bad = [ids[0], 99]                         # good id first, then foreign
    with pytest.raises(DoubleFreeError):
        a.free(bad)
    assert a.refcount(ids[0]) == 1             # good id NOT freed
    assert a.in_use == 3 and a.free_pages == 3
    a.check_invariants()

    with pytest.raises(DoubleFreeError):       # dup ids exceed refcount 1
        a.free([ids[1], ids[1]])
    assert a.refcount(ids[1]) == 1
    a.check_invariants()

    a.ref([ids[2]])                            # rc 2: dup release is legal
    a.free([ids[2], ids[2]])
    assert a.refcount(ids[2]) == 0 and a.free_pages == 4
    with pytest.raises(DoubleFreeError):       # second free after rc hit 0
        a.free([ids[2], ids[0], ids[1]])
    assert a.in_use == 2                       # ids[0], ids[1] untouched
    a.check_invariants()


@settings(max_examples=50, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 3), st.integers(0, 5)), max_size=40))
def test_allocator_invariants_property(ops):
    """Hypothesis property: under any alloc/ref/unref interleaving — with
    occasional invalid calls (double-unref, duplicate ids beyond the
    refcount) interleaved — no page is ever double-allocated, rejected
    calls mutate NOTHING (atomicity), and the free list + live refcounts
    always partition the pool (free-list conservation)."""
    a = PageAllocator(8)
    held: list[list[int]] = []                 # one entry per reference
    for op, n in ops:
        if op == 0:
            got = a.alloc(n)
            if got is not None:
                flat = [p for grp in held for p in grp]
                assert not (set(got) & set(flat)), "double allocation"
                held.append(got)
        elif op == 1 and held:                 # extra reference
            grp = held[n % len(held)]
            a.ref(grp)
            held.append(list(grp))
        elif op == 2 and held:                 # drop one reference
            a.unref(held.pop(n % len(held)))
        elif op == 3:                          # invalid: over-release
            grp = held[n % len(held)] if held else [n]
            counts = {p: a.refcount(p) for p in grp}
            with pytest.raises(DoubleFreeError):
                a.unref([p for p in grp
                         for _ in range(a.refcount(p) + 1)])
            for p, c in counts.items():        # atomic: nothing changed
                assert a.refcount(p) == c
        a.check_invariants()
    refs = {}
    for grp in held:
        for p in grp:
            refs[p] = refs.get(p, 0) + 1
    assert a.in_use == len(refs)
    assert all(a.refcount(p) == c for p, c in refs.items())


# ------------------------------------------------- page budget / NBL -------

def test_nbl_page_budget_monotone_in_m():
    """Fixed byte budget: linearizing more layers -> more admitted requests
    (linearized layers contribute zero pages)."""
    cfg, _ = _setup()
    budget = 6 * n_caching_attn_layers(cfg) * page_bytes(cfg, 8)  # 6 pages
    got = [nbl_page_budget(nbl_variant(cfg, m), budget, page_size=8,
                           expected_len=16) for m in range(4)]
    assert got[0] == 3                          # 6 pages / 2 per request
    assert got == sorted(got)
    assert got[-1] > got[0]


def test_paged_budget_beats_ring_on_short_prompts():
    """Equal HBM budget, short expected length: page-granular admission
    buys strictly more concurrency than max_len rings."""
    from repro.models.kv_cache import cache_bytes
    cfg, params = _setup()
    max_len = 64
    budget = 2 * cache_bytes(cfg, 1, max_len)
    ring = Engine(cfg, params, max_len=max_len, cache_budget_bytes=budget)
    paged = Engine(cfg, params, max_len=max_len, cache_budget_bytes=budget,
                   paged=True, page_size=8, expected_len=16)
    assert paged.n_slots > ring.n_slots
    assert ring.n_slots == 2


# ------------------------------------------------------- preemption --------

def test_pool_exhaustion_preempts_youngest_and_completes():
    """A pool too small for both in-flight requests to reach max_new: the
    younger request is preempted mid-decode (pages freed, requeued), the
    older finishes, and every request still completes with exactly the
    single-request reference tokens."""
    cfg, params = _setup()
    p1, p2 = _prompts(cfg, [8, 8], seed=5)
    refs = [_ref(cfg, params, p, 10) for p in (p1, p2)]

    # 2 slots x (8 prompt + 10 new = 18 tokens -> 5 pages of 4) but only
    # 7 pages: both admit (prompt needs 2 pages each + headroom), then the
    # pool runs dry as decode crosses page boundaries.
    eng = Engine(cfg, params, max_len=20, n_slots=2, paged=True, page_size=4)
    eng.allocator = PageAllocator(7)
    eng.n_pages = 7
    rids = [eng.submit(p1, 10), eng.submit(p2, 10)]
    out = eng.run(max_steps=200)
    assert eng.n_preemptions >= 1
    for rid, want in zip(rids, refs):
        np.testing.assert_array_equal(out[rid], want)
    eng.allocator.check_invariants()
    assert eng.allocator.in_use == 0
    # preemption metrics split: restarted requests are counted and their
    # (rewound) TTFT surfaces separately, so restart latency can never
    # silently pollute a paged-vs-ring TTFT comparison.
    s = latency_stats([eng.finished[r] for r in rids])
    n_pre = sum(1 for r in (eng.finished[rid] for rid in rids)
                if r.n_preemptions > 0)
    assert s["n_preempted_requests"] == n_pre >= 1
    assert "p99_ttft_preempted_s" in s
    assert n_pre + sum(1 for rid in rids
                       if eng.finished[rid].n_preemptions == 0) == s["n"]


def test_sliding_window_releases_dead_pages_with_parity():
    """Pure-SWA stack: pages wholly below the attention window are freed
    mid-generation (the paged analogue of ring compaction), the pool's peak
    occupancy stays near O(window) instead of O(sequence), and the emitted
    tokens still exactly match generate()."""
    from repro.configs.base import dense_stack
    cfg = get_config("tiny-swa").replace(stack=dense_stack(4, window=8))
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompt = _prompts(cfg, [6], seed=2)[0]
    want = _ref(cfg, params, prompt, 20)       # runs to position 25

    eng = Engine(cfg, params, max_len=32, n_slots=1, paged=True, page_size=4)
    assert eng._page_window == 8
    rid = eng.submit(prompt, 20)
    out = eng.run()
    np.testing.assert_array_equal(out[rid], want)
    # 26 positions = 7 pages if nothing were freed; a W=8 window needs at
    # most 3 live 4-token pages (+1 write fault in flight)
    assert eng.allocator.peak_in_use <= 4
    assert eng.allocator.in_use == 0
    eng.allocator.check_invariants()

    # one global-attention layer pins everything: no release horizon
    dcfg, dparams = _setup()
    dense_eng = Engine(dcfg, dparams, max_len=16, n_slots=1, paged=True,
                       page_size=4)
    assert dense_eng._page_window is None


# -------------------------------------------------- chunked prefill --------

@pytest.mark.parametrize("arch", ["tiny-dense", "tiny-swa", "tiny-gemma"])
def test_chunked_prefill_parity_matrix(arch):
    """Chunked prefill emits EXACTLY the generate() tokens across
    dense-GQA / sliding-window / softcap stacks for chunk sizes of one
    page, an odd page multiple, and >= the whole prompt (single chunk)."""
    cfg, params = _setup(arch)
    prompts = _prompts(cfg, [21, 5, 13], seed=6)
    refs = [_ref(cfg, params, p, 5) for p in prompts]
    for chunk in (8, 24, 999):                  # 1 page | odd multiple | all
        eng = Engine(cfg, params, max_len=32, n_slots=2, paged=True,
                     page_size=8, chunked_prefill=True,
                     prefill_chunk_tokens=chunk)
        rids = [eng.submit(p, 5) for p in prompts]
        out = eng.run(max_steps=300)
        for i, rid in enumerate(rids):
            np.testing.assert_array_equal(out[rid], refs[i],
                                          err_msg=f"chunk={chunk} req {i}")
        eng.allocator.check_invariants()
        assert eng.allocator.in_use == 0
        want_chunks = sum(-(-len(p) // eng.chunk_tokens) for p in prompts)
        assert eng.n_chunks == want_chunks, (chunk, eng.n_chunks)


@pytest.mark.parametrize("m", [1, 2])
def test_chunked_parity_nbl_compressed(m):
    """Chunked prefill over NBL-compressed stacks: linearized layers carry
    no pool (their chunk is a single GEMM, no pages) and parity is exact."""
    cfg, _ = _setup()
    ncfg = compress_config(cfg, cfg.attn_layer_indices()[-m:], "nbl")
    params = init_params(jax.random.PRNGKey(1), ncfg)
    prompts = _prompts(ncfg, [18, 7], seed=12)
    refs = [_ref(ncfg, params, p, 4) for p in prompts]

    eng = Engine(ncfg, params, max_len=32, n_slots=2, paged=True,
                 page_size=4, chunked_prefill=True, prefill_chunk_tokens=8)
    rids = [eng.submit(p, 4) for p in prompts]
    out = eng.run(max_steps=300)
    for i, rid in enumerate(rids):
        np.testing.assert_array_equal(out[rid], refs[i])


def test_chunked_composes_with_prefix_sharing():
    """chunked + prefix_sharing: the follower looks the shared prefix up
    ONCE at admission and chunks only its suffix — prefill tokens cover
    prompt minus the shared pages, parity stays exact."""
    cfg, params = _setup()
    prompts = _shared_prompts(cfg, 17, [4, 6], seed=13)
    refs = [_ref(cfg, params, p, 5) for p in prompts]

    eng = Engine(cfg, params, max_len=48, n_slots=1, paged=True, page_size=8,
                 prefix_sharing=True, chunked_prefill=True,
                 prefill_chunk_tokens=8)
    rids = [eng.submit(p, 5) for p in prompts]
    out = eng.run(max_steps=300)
    for i, rid in enumerate(rids):
        np.testing.assert_array_equal(out[rid], refs[i])
    s = eng.stats()
    assert s["n_prefix_hits"] == 1
    # the follower chunked ONLY the suffix past the 2 shared pages
    assert s["n_prefill_tokens"] == sum(len(p) for p in prompts) - 16
    eng.allocator.check_invariants()


def test_chunked_mid_prompt_preemption_requeue_resume():
    """Pool pressure preempts a mid-prompt chunking request (pages unref'd,
    requeued, progress discarded); it is re-admitted later, re-chunks from
    its prompt and completes with exactly the reference tokens."""
    cfg, params = _setup()
    p1, p2 = _prompts(cfg, [8, 16], seed=15)
    refs = [_ref(cfg, params, p, 10) for p in (p1, p2)]

    # p1 decodes across page boundaries while p2 (younger) chunks; a pool
    # of 8 cannot hold both, so p2 is torn down mid-prompt at least once.
    eng = Engine(cfg, params, max_len=32, n_slots=2, paged=True, page_size=4,
                 chunked_prefill=True, prefill_chunk_tokens=4)
    eng.allocator = PageAllocator(8)
    eng.n_pages = 8
    rids = [eng.submit(p1, 10), eng.submit(p2, 10)]
    out = eng.run(max_steps=300)
    assert eng.n_preemptions >= 1
    for rid, want in zip(rids, refs):
        np.testing.assert_array_equal(out[rid], want)
    eng.allocator.check_invariants()
    assert eng.allocator.in_use == 0


def test_chunked_decodes_between_chunks():
    """The interleaving claim itself: while a long prompt is mid-chunking,
    already-running requests keep emitting tokens (the non-chunked engine
    would stall them for the whole prefill)."""
    cfg, params = _setup()
    shorts = _prompts(cfg, [4, 5], seed=16)
    longp = _prompts(cfg, [24], seed=17)[0]

    eng = Engine(cfg, params, max_len=40, n_slots=3, paged=True, page_size=4,
                 chunked_prefill=True, prefill_chunk_tokens=4)
    sids = [eng.submit(p, 12) for p in shorts]
    eng.step()
    eng.step()
    lid = eng.submit(longp, 4)

    def short_tokens():
        live = [r for r in eng.slot_req if r is not None]
        return sum(len(r.tokens) for r in live + list(eng.finished.values())
                   if r.rid in sids)

    interleaved = 0
    while eng.has_work:
        chunking = bool((eng.slot_chunk_pos >= 0).any())
        before = short_tokens()
        eng.step()
        if chunking and short_tokens() > before:
            interleaved += 1
    assert interleaved >= 3                     # 6 chunks, decode each step
    # the hand-counted steps validate the engine's own statistic (the one
    # ci.sh / benchmarks consume) against an independent measurement
    assert eng.stats()["n_interleaved_decode_steps"] >= 3
    for rid, p, n in [(sids[0], shorts[0], 12), (sids[1], shorts[1], 12),
                      (lid, longp, 4)]:
        np.testing.assert_array_equal(eng.finished[rid].tokens,
                                      _ref(cfg, params, p, n))


def test_chunked_gates_and_rounding():
    """chunked_prefill requires paged=True, refuses SSM stacks, and rounds
    the chunk size up to a page multiple."""
    cfg, params = _setup()
    with pytest.raises(ValueError):
        Engine(cfg, params, max_len=16, n_slots=1, chunked_prefill=True)
    for arch in ("tiny-mamba", "tiny-zamba"):
        c, p = _setup(arch)
        with pytest.raises(ValueError):
            Engine(c, p, max_len=16, n_slots=1, paged=True, page_size=8,
                   chunked_prefill=True)
    eng = Engine(cfg, params, max_len=16, n_slots=1, paged=True, page_size=8,
                 chunked_prefill=True, prefill_chunk_tokens=9)
    assert eng.chunk_tokens == 16               # rounded up to page multiple
    for bad in (0, -3):                         # 0 must not fall back to
        with pytest.raises(ValueError):         # the page-size default
            Engine(cfg, params, max_len=16, n_slots=1, paged=True,
                   page_size=8, chunked_prefill=True,
                   prefill_chunk_tokens=bad)


def test_chunked_age_order_survives_clock_ties(monkeypatch):
    """Regression: two same-step admissions tie on t_admit under a coarse
    monotonic clock; age comparisons key on admit_seq instead, so the
    steal-only-from-younger rule can still tell the slots apart and the
    engine drains rather than mutually suspending."""
    import repro.launch.engine as engine_mod
    cfg, params = _setup()
    monkeypatch.setattr(engine_mod.time, "monotonic", lambda: 12345.0)
    eng = Engine(cfg, params, max_len=32, n_slots=2, paged=True, page_size=4,
                 chunked_prefill=True, prefill_chunk_tokens=4)
    rids = [eng.submit(p, 3) for p in _prompts(cfg, [9, 9], seed=19)]
    eng.step()                          # both admitted in ONE step
    reqs = [r for r in eng.slot_req if r is not None]
    assert len(reqs) == 2
    assert reqs[0].t_admit == reqs[1].t_admit        # the tie
    assert reqs[0].admit_seq != reqs[1].admit_seq    # age still total
    out = eng.run(max_steps=300)
    assert all(len(out[r]) == 3 for r in rids)


def test_span_pages_unit():
    from repro.models.paging import span_pages
    assert span_pages(0, 5, 4) == (0, 2)
    assert span_pages(8, 9, 4) == (2, 3)
    assert span_pages(8, 16, 4) == (2, 4)
    with pytest.raises(AssertionError):
        span_pages(3, 8, 4)                     # unaligned resume point


# ------------------------------------------------------- bucketing ---------

def test_prefill_bucketing_bounds_jits_with_exact_parity():
    """Distinct prompt lengths within one power-of-two bucket share a
    single prefill jit, and emitted tokens still exactly match the
    per-length reference loop."""
    cfg, params = _setup()
    lens = [5, 6, 7, 8, 3]                     # buckets: 8, 8, 8, 8, 4
    prompts = _prompts(cfg, lens, seed=9)
    refs = [_ref(cfg, params, p, 4) for p in prompts]

    eng = Engine(cfg, params, max_len=16, n_slots=2)
    assert eng.bucket_prompts
    rids = [eng.submit(p, 4) for p in prompts]
    out = eng.run()
    assert len(eng._prefill_jits) == 2         # {8, 4}, not 5
    for i, rid in enumerate(rids):
        np.testing.assert_array_equal(out[rid], refs[i], err_msg=f"req {i}")


def test_bucketing_gates_off_for_ssm_and_ring_windows():
    """Exactness gates: SSM stacks never bucket (padding corrupts scanned
    state); windowed attention buckets only under the position-aligned
    paged layout."""
    for arch, paged, want in [("tiny-mamba", False, False),
                              ("tiny-zamba", True, False),
                              ("tiny-swa", False, False),
                              ("tiny-swa", True, True),
                              ("tiny-dense", False, True)]:
        cfg, params = _setup(arch)
        eng = Engine(cfg, params, max_len=16, n_slots=1, paged=paged,
                     page_size=8)
        assert eng.bucket_prompts is want, (arch, paged)


# ------------------------------------------------------- stats -------------

def test_latency_stats_tail_fields():
    reqs = []
    for i in range(10):
        r = Request(rid=i, prompt=np.array([1]), max_new=4,
                    t_submit=0.0, t_admit=0.1, t_first=0.2 + i * 0.01,
                    t_finish=1.0 + i * 0.1)
        r.tokens = [1, 2, 3, 4]
        reqs.append(r)
    s = latency_stats(reqs)
    assert {"p99_ttft_s", "p50_ttft_s", "decode_tok_s_p50",
            "decode_tok_s_min"} <= set(s)
    assert s["p99_ttft_s"] >= s["p50_ttft_s"]
    assert s["decode_tok_s_min"] <= s["decode_tok_s_p50"]


def test_cache_bytes_memoized(monkeypatch):
    """cache_bytes hits its memo on repeat (cfg, batch, max_len) calls —
    it sits in the scheduler/benchmark hot path."""
    from repro.models import kv_cache
    cfg, _ = _setup()
    kv_cache.cache_bytes.cache_clear()
    calls = {"n": 0}
    real = jax.eval_shape

    def counting(*a, **k):
        calls["n"] += 1
        return real(*a, **k)

    monkeypatch.setattr(kv_cache.jax, "eval_shape", counting)
    a = kv_cache.cache_bytes(cfg, 1, 64)
    b = kv_cache.cache_bytes(cfg, 1, 64)
    assert a == b and calls["n"] == 1
    kv_cache.cache_bytes(cfg, 1, 128)
    assert calls["n"] == 2


def test_paged_stats_fields():
    cfg, params = _setup()
    eng = Engine(cfg, params, max_len=16, n_slots=2, paged=True, page_size=8)
    for p in _prompts(cfg, [5, 7], seed=1):
        eng.submit(p, 3)
    eng.run()
    s = eng.stats()
    assert s["n"] == 2 and s["n_pages"] == eng.n_pages
    assert 0.0 < s["pool_utilization"] <= 1.0
    assert s["pages_in_use"] == 0 and s["n_preemptions"] == 0
