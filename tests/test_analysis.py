"""repro.analysis unit + regression coverage.

Per-rule fixture pairs (a known-bad snippet the pass must flag, a
known-good variant it must pass), the suppression grammar (including
jit-discipline's allowlist-with-reason requirement), baseline round-trip,
and the two load-bearing integration claims:

* the tree-wide regression — ``src/repro`` analyzes CLEAN against the
  burned-empty baseline, so any new violation fails this test before it
  fails CI;
* a DYNAMIC cross-check of the jit-discipline rule's premise: building a
  fresh ``jax.jit`` per iteration really does retrace every time, while
  the ``repro.jitcache.shared_jit`` wrapper traces once.
"""
from __future__ import annotations

import pathlib
import textwrap

import pytest

from repro.analysis import (
    ALL_RULES,
    analyze_modules,
    analyze_source,
    collect_modules,
    filter_baselined,
    load_baseline,
    save_baseline,
)

REPO = pathlib.Path(__file__).resolve().parents[1]


def run(src: str, rel: str = "fixture.py", rules=None):
    return analyze_source(textwrap.dedent(src), rel=rel, rules=rules)


def rules_of(findings):
    return {f.rule for f in findings}


# ---------------------------------------------------------------- guarded-by
GUARDED_BAD = """
    import threading

    class C:
        def __init__(self):
            self.n = 0   # guarded-by: _lock
            self._lock = threading.Lock()

        def read(self):
            return self.n
"""

GUARDED_GOOD = """
    import threading

    class C:
        def __init__(self):
            self.n = 0   # guarded-by: _lock
            self._lock = threading.Lock()

        def read(self):
            with self._lock:
                return self.n
"""


def test_guarded_by_flags_unlocked_access():
    found = run(GUARDED_BAD, rules={"guarded-by"})
    assert len(found) == 1
    f = found[0]
    assert f.rule == "guarded-by" and "self.n" in f.message
    assert f.symbol == "C.read"


def test_guarded_by_passes_locked_access():
    assert run(GUARDED_GOOD, rules={"guarded-by"}) == []


def test_guarded_by_init_exempt():
    # __init__ constructs the attrs it annotates; no lock exists yet
    assert all(f.symbol != "C.__init__"
               for f in run(GUARDED_BAD, rules={"guarded-by"}))


# ---------------------------------------------------------------- lock-order
DEADLOCK_BAD = """
    import threading

    class C:
        def __init__(self):
            self._lock = threading.Lock()

        def outer(self):
            with self._lock:
                self.inner()

        def inner(self):
            with self._lock:
                pass
"""

# same shape, RLock: re-entry is the documented AsyncEngine._lock pattern
DEADLOCK_OK_RLOCK = DEADLOCK_BAD.replace("threading.Lock()",
                                         "threading.RLock()")

ORDER_CYCLE = """
    import threading

    class C:
        def __init__(self):
            self._a = threading.Lock()
            self._b = threading.Lock()

        def ab(self):
            with self._a:
                with self._b:
                    pass

        def ba(self):
            with self._b:
                with self._a:
                    pass
"""


def test_lock_order_flags_plain_lock_self_deadlock():
    found = run(DEADLOCK_BAD, rules={"lock-order"})
    assert found and all(f.rule == "lock-order" for f in found)
    assert any("_lock" in f.message for f in found)


def test_lock_order_allows_rlock_reentry():
    assert run(DEADLOCK_OK_RLOCK, rules={"lock-order"}) == []


def test_lock_order_flags_ab_ba_cycle():
    found = run(ORDER_CYCLE, rules={"lock-order"})
    assert found and any("cycle" in f.message for f in found)


# ------------------------------------------------------------ jit-discipline
JIT_BAD = """
    import jax

    def f(x):
        step = jax.jit(lambda t: t + 1)
        return step(x)
"""

JIT_GOOD_SHARED = """
    import jax
    from repro.jitcache import shared_jit

    def f(cfg, x):
        step = shared_jit(("fixture.f", cfg),
                          lambda: jax.jit(lambda t: t + 1))
        return step(x)
"""

JIT_GOOD_MODULE_LEVEL = """
    import jax

    @jax.jit
    def step(t):
        return t + 1
"""


def test_jit_discipline_flags_function_scope_jit():
    found = run(JIT_BAD, rules={"jit-discipline"})
    assert len(found) == 1 and found[0].symbol == "f"


def test_jit_discipline_passes_shared_and_module_level():
    assert run(JIT_GOOD_SHARED, rules={"jit-discipline"}) == []
    assert run(JIT_GOOD_MODULE_LEVEL, rules={"jit-discipline"}) == []


def test_jit_discipline_suppression_requires_reason():
    # bare disable does NOT allowlist a jit site...
    bare = JIT_BAD.replace(
        "jax.jit(lambda t: t + 1)",
        "jax.jit(lambda t: t + 1)  # nbl: disable=jit-discipline")
    assert run(bare, rules={"jit-discipline"}) != []
    # ...a reasoned one does
    reasoned = JIT_BAD.replace(
        "jax.jit(lambda t: t + 1)",
        "jax.jit(lambda t: t + 1)  # nbl: disable=jit-discipline -- why")
    assert run(reasoned, rules={"jit-discipline"}) == []


# --------------------------------------------------------------- jit-retrace
RETRACE_LOOP = """
    import jax

    def f(xs):
        out = []
        for x in xs:
            out.append(jax.jit(lambda t: t + 1)(x))
        return out
"""

RETRACE_UNHASHABLE_STATIC = """
    import jax

    def f(x):
        g = jax.jit(lambda t, names: t, static_argnames=("names",))
        return g(x, names=["a", "b"])
"""


def test_jit_retrace_flags_jit_in_loop():
    assert rules_of(run(RETRACE_LOOP, rules={"jit-retrace"})) == \
        {"jit-retrace"}


def test_jit_retrace_flags_unhashable_static():
    found = run(RETRACE_UNHASHABLE_STATIC, rules={"jit-retrace"})
    assert found and any("static" in f.message for f in found)


# ----------------------------------------------------------------- host-sync
HOSTSYNC_DIRECT = """
    class Engine:
        def _step_impl(self):
            return self.logits.item()
"""

HOSTSYNC_VIA_CALL = """
    class Engine:
        def _step_impl(self):
            return self._helper()

        def _helper(self):
            return float(self.x)
"""

HOSTSYNC_SANCTIONED = """
    import numpy as np

    class Engine:
        def _step_impl(self):
            # host-sync: readback -- the step's one sanctioned logits pull
            v = np.asarray(self.logits)
            return v
"""

HOSTSYNC_UNREACHABLE = """
    import numpy as np

    class Engine:
        def _step_impl(self):
            return 0

    def offline_tool(x):
        return np.asarray(x)     # not reachable from the step: fine
"""


def test_host_sync_flags_direct_item():
    found = run(HOSTSYNC_DIRECT, rules={"host-sync"})
    assert len(found) == 1 and ".item()" in found[0].message


def test_host_sync_follows_call_graph():
    found = run(HOSTSYNC_VIA_CALL, rules={"host-sync"})
    assert found and found[0].symbol == "Engine._helper"


def test_host_sync_sanction_comment():
    assert run(HOSTSYNC_SANCTIONED, rules={"host-sync"}) == []


def test_host_sync_only_flags_reachable_code():
    assert run(HOSTSYNC_UNREACHABLE, rules={"host-sync"}) == []


# -------------------------------------------------------------- perf-counter
PERF = """
    import time

    def f():
        return time.perf_counter()
"""


def test_perf_counter_flagged_outside_obs():
    found = run(PERF, rel="src/repro/launch/fixture.py",
                rules={"perf-counter"})
    assert len(found) == 1 and "perf_counter" in found[0].message


def test_perf_counter_allowed_under_obs():
    assert run(PERF, rel="src/repro/obs/fixture.py",
               rules={"perf-counter"}) == []


# --------------------------------------------------------------- obs-hygiene
OBS_BAD = """
    class Engine:
        def _step_impl(self):
            self.obs.on_token(1)
"""

OBS_GOOD = """
    class Engine:
        def _step_impl(self):
            if self.obs is not None:
                self.obs.on_token(1)
"""


def test_obs_hygiene_flags_unguarded_hook():
    found = run(OBS_BAD, rules={"obs-hygiene"})
    assert len(found) == 1 and "self.obs.on_token" in found[0].message


def test_obs_hygiene_passes_guarded_hook():
    assert run(OBS_GOOD, rules={"obs-hygiene"}) == []


# ------------------------------------------------- suppressions and baseline
def test_inline_suppression_honored():
    sup = GUARDED_BAD.replace("return self.n",
                              "return self.n  # nbl: disable=guarded-by")
    assert run(sup, rules={"guarded-by"}) == []


def test_comment_only_suppression_attaches_to_next_code_line():
    sup = GUARDED_BAD.replace(
        "        def read(self):\n            return self.n",
        "        def read(self):\n"
        "            # nbl: disable=guarded-by\n"
        "            return self.n")
    assert run(sup, rules={"guarded-by"}) == []


def test_unknown_rule_never_suppresses():
    sup = GUARDED_BAD.replace("return self.n",
                              "return self.n  # nbl: disable=other-rule")
    assert run(sup, rules={"guarded-by"}) != []


def test_baseline_round_trip(tmp_path):
    found = run(GUARDED_BAD) + run(JIT_BAD)
    assert found
    path = str(tmp_path / "baseline.json")
    save_baseline(path, found)
    keys = load_baseline(path)
    assert keys == {f.baseline_key for f in found}
    # everything baselined filters to nothing; a fresh finding survives
    assert filter_baselined(found, keys) == []
    fresh = run(OBS_BAD)
    assert filter_baselined(found + fresh, keys) == fresh


def test_baseline_is_line_insensitive(tmp_path):
    found = run(GUARDED_BAD)
    path = str(tmp_path / "baseline.json")
    save_baseline(path, found)
    shifted = run("\n\n\n" + textwrap.dedent(GUARDED_BAD))
    assert shifted and shifted[0].line != found[0].line
    assert filter_baselined(shifted, load_baseline(path)) == []


# ------------------------------------------------------ tree-wide regression
def test_src_tree_is_clean():
    """src/repro analyzes clean against the burned-empty baseline: every
    real finding this PR surfaced was either fixed or allowlisted with a
    reason, and new violations fail here before they fail CI."""
    mods = collect_modules([str(REPO / "src" / "repro")], str(REPO))
    assert len(mods) > 30                    # the walk found the tree
    findings = analyze_modules(mods)
    baseline = load_baseline(str(REPO / "scripts" / "analysis_baseline.json"))
    assert baseline == set()                 # burned empty on purpose
    assert filter_baselined(findings, baseline) == [], \
        "\n".join(f.render() for f in findings)


def test_all_rules_have_fixture_coverage():
    covered = {"guarded-by", "lock-order", "jit-discipline", "jit-retrace",
               "host-sync", "perf-counter", "obs-hygiene"}
    assert covered == set(ALL_RULES)


# --------------------------------------------- dynamic retrace cross-check
def test_unshared_jit_retraces_shared_does_not():
    """The premise behind jit-discipline, checked against the real tracer:
    a fresh ``jax.jit`` per iteration retraces every time; the shared
    wrapper traces once and the registry returns the SAME object after."""
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp

    from repro.jitcache import SHARED_JITS, shared_jit

    traces = []

    def make_f(tag):
        def f(x):
            traces.append(tag)               # runs at TRACE time only
            return x + 1
        return f

    for _ in range(3):                       # the anti-pattern
        jax.jit(make_f("fresh"))(jnp.ones(2)).block_until_ready()
    assert traces.count("fresh") == 3

    key = ("test_analysis.retrace", object())
    try:
        fns = set()
        for _ in range(3):                   # the sanctioned route
            fn = shared_jit(key, lambda: jax.jit(make_f("shared")))
            fns.add(id(fn))
            fn(jnp.ones(2)).block_until_ready()
        assert traces.count("shared") == 1
        assert len(fns) == 1                 # registry hands back one object
    finally:
        SHARED_JITS.pop(key, None)
