"""Roofline machinery: HLO parsing with trip counts, term arithmetic."""
from __future__ import annotations

import numpy as np
import pytest

from repro.roofline.analysis import (HW_V5E, collective_bytes, model_flops,
                                     roofline_terms)
from repro.roofline.hlo import analyze, parse_module

SYNTH = """
HloModule test

%body (p: (s32[], f32[128,128])) -> (s32[], f32[128,128]) {
  %p = (s32[], f32[128,128]{1,0}) parameter(0)
  %gte0 = s32[] get-tuple-element(%p), index=0
  %gte1 = f32[128,128]{1,0} get-tuple-element(%p), index=1
  %c1 = s32[] constant(1)
  %add = s32[] add(%gte0, %c1)
  %ag = f32[128,512]{1,0} all-gather(%gte1), channel_id=1, dimensions={1}
  %dot = f32[128,128]{1,0} dot(%gte1, %gte1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %t = (s32[], f32[128,128]{1,0}) tuple(%add, %dot)
}

%cond (p2: (s32[], f32[128,128])) -> pred[] {
  %p2 = (s32[], f32[128,128]{1,0}) parameter(0)
  %g = s32[] get-tuple-element(%p2), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%g, %n), direction=LT
}

ENTRY %main (x: f32[128,128]) -> f32[128,128] {
  %x = f32[128,128]{1,0} parameter(0)
  %z = s32[] constant(0)
  %tup = (s32[], f32[128,128]{1,0}) tuple(%z, %x)
  %w = (s32[], f32[128,128]{1,0}) while(%tup), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
  %ar = f32[128,128]{1,0} all-reduce(%x), channel_id=2, to_apply=%add_comp
  ROOT %out = f32[128,128]{1,0} get-tuple-element(%w), index=1
}
"""


def test_parse_module_finds_entry():
    comps, entry = parse_module(SYNTH)
    assert entry == "main"
    assert set(comps) >= {"body", "cond", "main"}


def test_trip_count_multiplied():
    c = analyze(SYNTH)
    # 5 iterations x dot(128x128 @ 128x128) = 5 * 2*128^3
    assert c.flops == pytest.approx(5 * 2 * 128 ** 3)
    # all-gather operand 128*128*4 bytes, 5 trips
    assert c.coll["all-gather"] == pytest.approx(5 * 128 * 128 * 4)
    # entry all-reduce operand once
    assert c.coll["all-reduce"] == pytest.approx(128 * 128 * 4)


def test_collective_bytes_legacy_parser():
    out = collective_bytes(SYNTH)
    assert out["all-reduce"] == 128 * 128 * 4
    assert out["all-gather"] == 128 * 128 * 4     # no trip awareness (legacy)


def test_roofline_terms_dominance():
    t = roofline_terms(flops=1e15, bytes_accessed=1e12, coll_bytes=1e9,
                       chips=256)
    assert t["dominant"] == "t_compute"
    assert t["frac_compute"] == 1.0
    t = roofline_terms(flops=1e12, bytes_accessed=1e15, coll_bytes=0,
                       chips=256)
    assert t["dominant"] == "t_memory"


def test_model_flops_moe_uses_active():
    from repro.configs import get_config
    dense = get_config("tiny-dense")
    moe = get_config("tiny-moe")
    assert model_flops(moe, 1000) < 6 * moe.n_params() * 1000
    assert model_flops(dense, 1000, backward=True) == \
        6 * dense.n_params() * 1000


def test_hw_constants():
    assert HW_V5E.peak_flops == 197e12
    assert HW_V5E.hbm_bw == 819e9
    assert HW_V5E.ici_bw == 50e9
