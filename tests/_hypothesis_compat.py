"""Fallback shims for environments without `hypothesis` installed.

Property-based tests import ``given``/``settings``/``st`` through this
module; when the real library is missing the decorated tests are skipped
(instead of failing the whole module at collection time — the tier-1 suite
must stay runnable on a bare CPU image).
"""
from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on bare images
    HAVE_HYPOTHESIS = False

    def settings(*_a, **_k):
        return lambda fn: fn

    def given(*_a, **_k):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)
        return deco

    class _Strategies:
        """Stand-in for hypothesis.strategies: every strategy builder
        returns None (never drawn from — the test is skipped)."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _Strategies()
