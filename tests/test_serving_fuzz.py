"""Serving-oracle fuzz harness: randomized workloads replayed through the
Engine in all four serving modes (ring / paged / prefix-shared / chunked)
plus the chunked+shared composition and a SPECULATIVE mode (per-request
NBL self-drafting: γ-token draft bursts, one-shot verify, rollback —
mixed with plain requests whenever a prompt leaves no room for a
candidate span), asserting TOKEN-EXACT parity against
the single-request generate() oracle and allocator/refcount invariants
after every step. Every mode replays through BOTH step paths — the fused
plan->execute->commit pipeline (the default) and the legacy two-dispatch
path (``Engine(fused_step=False)``, the parity oracle) — and workloads
randomly carry a ``step_tokens`` decode-priority budget (including
sub-page values, exercising the min-progress rule), so fused-vs-legacy
token parity is anchored to one oracle from both sides. An ASYNC variant replays the same workloads through the
AsyncEngine host loop — concurrent submit/stream/cancel from worker
threads (cancel mid-chunking, cancel-while-prefix-referenced, and
cancel-between-spec-bursts fall out
of the seeded cancel offsets), with the same per-step invariants hung on
the step thread via step_cb.

Workloads are drawn from a seeded numpy RNG, so every example is
deterministic and replayable from its (mode, seed) pair alone: prompt
lengths, shared-prefix structure, max_new, EOS, submission schedule (some
requests join mid-stream), slot counts, page-pool pressure (pools shrunk to
force preemption) and chunk sizes all vary. The deterministic suite runs
``NBL_FUZZ_EXAMPLES`` seeds per mode and variant (default 3; CI raises it
to 50 for 50 x 6 modes x {sync, async} = 600 examples); the hypothesis
property on top draws arbitrary seeds and shrinks failures, and skips
cleanly when hypothesis is absent (tests/_hypothesis_compat.py).

Engines share jitted step functions through launch.engine's module cache,
so the marginal example costs host-loop time, not recompilation.
"""
from __future__ import annotations

import functools
import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests._hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.launch.engine import AsyncEngine, Engine
from repro.launch.serve import generate
from repro.launch.speculative import make_nbl_draft
from repro.models import decode_step, init_params, prefill
from repro.models.paging import PageAllocator, pages_per_seq
from repro.obs import Observability

MAX_LEN = 32
PAGE_SIZE = 4

MODES = {
    "ring": {},
    "paged": dict(paged=True, page_size=PAGE_SIZE),
    "prefix": dict(paged=True, page_size=PAGE_SIZE, prefix_sharing=True),
    "chunked": dict(paged=True, page_size=PAGE_SIZE, chunked_prefill=True),
    # the composed mode the engine advertises: progressive index
    # publication + mid-chunk suspension/preemption under one roof
    "chunked_shared": dict(paged=True, page_size=PAGE_SIZE,
                           chunked_prefill=True, prefix_sharing=True),
    # per-request speculative decoding against a zero-map NBL self-draft
    # ("spec" is a harness flag, not an Engine kwarg: _replay turns it
    # into a drafts={} registration + per-request spec_gamma). Acceptance
    # is near-zero with untrained maps — the point is exercising the
    # draft/verify/rollback machinery, not the speedup.
    "spec": dict(paged=True, page_size=PAGE_SIZE, spec=True),
}

DRAFT_M = 2

ARCHS = ("tiny-dense", "tiny-swa", "tiny-gemma")


@functools.lru_cache(maxsize=None)
def _setup(arch):
    cfg = get_config(arch)
    return cfg, init_params(jax.random.PRNGKey(0), cfg)


@functools.lru_cache(maxsize=None)
def _draft(arch):
    """Zero-map NBL drafter (deepest DRAFT_M attn layers -> identity
    residual) over the target's own params — shared per arch so every
    example reuses one draft-jit family."""
    cfg, params = _setup(arch)
    return make_nbl_draft(cfg, params, DRAFT_M)


def _spec_gamma(prompt, max_new, i: int) -> int:
    """Deterministic per-request draft length: cycles 1..3, clamped so
    prompt + max_new + gamma fits max_len (0 -> the request rides the
    plain decode path, mixing spec and non-spec traffic in one batch)."""
    return max(0, min(1 + i % 3, MAX_LEN - len(prompt) - max_new))


@functools.lru_cache(maxsize=None)
def _ref_fns(cfg):
    """One jitted (prefill, decode) pair per config at a FIXED cache_len:
    jax's trace cache then compiles each distinct prompt length once per
    process instead of once per example."""
    prefill_fn = jax.jit(
        lambda p, t: prefill(cfg, p, t, cache_len=MAX_LEN))
    decode_fn = jax.jit(
        lambda p, t, c, i: decode_step(cfg, p, t, c, i))
    return prefill_fn, decode_fn


def _oracle(cfg, params, prompt, max_new, eos_id):
    """generate() reference, truncated at the first EOS (inclusive) the
    way the engine retires a slot."""
    out = np.asarray(generate(cfg, params, jnp.asarray(prompt)[None],
                              max_new=max_new,
                              use_jit_fns=_ref_fns(cfg)))[0]
    if eos_id is not None:
        hits = np.nonzero(out == eos_id)[0]
        if hits.size:
            out = out[:hits[0] + 1]
    return out


def _draw_workload(seed: int) -> dict:
    """Deterministic randomized workload: ragged prompts (optionally
    behind a shared prefix), per-request max_new, EOS, a mid-stream
    submission schedule, slot count, pool pressure and chunk size."""
    rng = np.random.default_rng(seed)
    cfg, _ = _setup(ARCHS[rng.integers(0, len(ARCHS))])
    n_req = int(rng.integers(2, 7))
    share = rng.random() < 0.5
    sys_len = int(rng.integers(PAGE_SIZE, 3 * PAGE_SIZE + 1)) if share else 0
    sys_p = rng.integers(0, cfg.vocab_size, sys_len)
    reqs = []
    for _ in range(n_req):
        max_new = int(rng.integers(1, 7))
        if share and rng.random() < 0.7:
            tail = int(rng.integers(1, MAX_LEN - max_new - sys_len + 1))
            prompt = np.concatenate([sys_p, rng.integers(
                0, cfg.vocab_size, tail)]).astype(np.int32)
        else:
            plen = int(rng.integers(1, MAX_LEN - max_new + 1))
            prompt = rng.integers(0, cfg.vocab_size, plen).astype(np.int32)
        delay = int(rng.integers(0, 6)) if rng.random() < 0.4 else 0
        reqs.append((prompt, max_new, delay))
    pps = pages_per_seq(MAX_LEN, PAGE_SIZE)
    n_slots = int(rng.integers(1, 4))
    return dict(
        arch=cfg.name,
        reqs=reqs,
        eos_id=int(rng.integers(0, cfg.vocab_size))
        if rng.random() < 0.3 else None,
        n_slots=n_slots,
        # pool SHRUNK within the constructed full-reservation size
        # (n_slots * pps) to force suspension/preemption, never below the
        # lone-request floor pps — and never past the pool arrays: ids
        # beyond them would clip-gather into the wrong page
        n_pages=int(rng.integers(pps, n_slots * pps + 1)),
        chunk_tokens=int(rng.choice([PAGE_SIZE, 3 * PAGE_SIZE, MAX_LEN * 2])),
        shared_prefix_len=sys_len,
        # decode-priority step budget (fused path): None = unbounded,
        # sub-page values hit the min-progress rule, page-scale values
        # throttle chunk rows and admission
        step_tokens=(None if (r := rng.random()) < 0.5
                     else int(rng.integers(1, PAGE_SIZE))
                     if r < 0.7
                     else int(rng.integers(PAGE_SIZE, 4 * PAGE_SIZE + 1))),
    )


def _check_invariants(eng: Engine) -> None:
    if not eng.paged:
        return
    eng.allocator.check_invariants()
    # every allocated page-table entry of an active slot is referenced,
    # and each slot's reference list covers its table row exactly
    for slot in range(eng.n_slots):
        row = set(int(p) for p in eng.page_tbl[slot] if p >= 0)
        held = set(eng.slot_pages[slot])
        assert row <= held, (slot, row, held)
        for pid in held:
            assert eng.allocator.refcount(pid) >= 1, (slot, pid)
        if eng.slot_req[slot] is None:
            assert not held and not row, (slot, held, row)


def _check_obs(eng: Engine, obs: Observability) -> None:
    """Registry counters cross-validated token-exactly against the
    engine's own hand-maintained counters and the terminal request state:
    every emission is counted once, preempted work shows up as discarded
    tokens (kept + discarded == emitted), lifecycle counters match, and
    every request's span tree validates (nested, terminated, no overlap)."""
    assert obs.decode_steps.value == eng.n_decode_steps
    assert obs.prefills.value == eng.n_prefills
    assert obs.chunks.value == eng.n_chunks
    assert obs.preemptions.value == eng.n_preemptions
    assert obs.cancelled.value == eng.n_cancelled
    assert obs.rejected.value == eng.n_rejected
    assert obs.finished.value == eng.n_finished
    assert obs.prefix_hits.value == eng.n_prefix_hits
    assert obs.interleaved.value == eng.n_interleaved_decode_steps
    if eng.prefix_sharing:
        assert obs.evictions.value == eng.prefix_index.n_evictions
    assert obs.spec_bursts.value == eng.n_spec_bursts
    assert obs.spec_draft_tokens.value == eng.n_spec_draft_tokens
    assert obs.spec_accepted.value == eng.n_spec_accepted_tokens
    assert obs.spec_tokens.value == eng.n_spec_tokens
    kept = sum(len(r.tokens) for r in eng.finished.values())
    assert obs.tokens.value == kept + obs.tokens_discarded.value, \
        (obs.tokens.value, kept, obs.tokens_discarded.value)
    obs.tracer.validate_all()


def _replay(mode: str, seed: int, fused: bool = True) -> None:
    w = _draw_workload(seed)
    cfg, params = _setup(w["arch"])
    kw = dict(MODES[mode])
    if kw.get("chunked_prefill"):
        kw["prefill_chunk_tokens"] = w["chunk_tokens"]
    spec = kw.pop("spec", False)
    if spec:
        kw["drafts"] = {DRAFT_M: _draft(w["arch"])}
    obs = Observability()
    eng = Engine(cfg, params, max_len=MAX_LEN, n_slots=w["n_slots"],
                 eos_id=w["eos_id"], obs=obs, fused_step=fused,
                 step_tokens=w["step_tokens"], **kw)
    if not fused:
        assert not eng.fused         # forced onto the legacy parity oracle
        assert eng.n_fused_dispatches == 0
    if eng.paged:
        n_pages = w["n_pages"]
        eng.allocator = PageAllocator(n_pages)
        eng.n_pages = n_pages

    pending = sorted(enumerate(w["reqs"]), key=lambda r: r[1][2])
    rids: dict[int, int] = {}
    t = 0
    hand_emitted = 0                 # Σ step() returns — the oracle count
    while pending or eng.has_work:
        while pending and pending[0][1][2] <= t:
            i, (prompt, max_new, _) = pending.pop(0)
            g = _spec_gamma(prompt, max_new, i) if spec else 0
            rids[i] = eng.submit(prompt, max_new, spec_gamma=g,
                                 draft_m=DRAFT_M if g else None)
        hand_emitted += eng.step()
        _check_invariants(eng)
        t += 1
        assert t < 600, "fuzz workload failed to drain"

    # registry counters == hand counts, token-exact
    assert obs.tokens.value == hand_emitted, (obs.tokens.value, hand_emitted)
    _check_obs(eng, obs)

    # token-exact parity with the generate() oracle, request by request
    for i, (prompt, max_new, _) in enumerate(w["reqs"]):
        want = _oracle(cfg, params, prompt, max_new, w["eos_id"])
        got = np.asarray(eng.finished[rids[i]].tokens, np.int32)
        np.testing.assert_array_equal(
            got, want, err_msg=f"mode={mode} seed={seed} req={i} "
                               f"(arch={w['arch']})")

    # end state: only the prefix index may still hold pages
    if eng.paged:
        held = eng.prefix_index.n_entries if eng.prefix_sharing else 0
        assert eng.allocator.in_use == held, (eng.allocator.in_use, held)
        eng.allocator.check_invariants()


def _replay_async(mode: str, seed: int, fused: bool = True) -> None:
    """Async-mode replay of the same seeded workload: worker threads
    submit/stream/cancel concurrently against the AsyncEngine host loop,
    allocator/refcount/page-table invariants are checked after EVERY step
    (step_cb runs on the step thread), and terminal results are oracled —
    completed requests token-exact, cancelled ones a greedy-exact PREFIX
    with their pages (incl. shared-prefix pins) all returned. Cancels are
    seeded at random token offsets, so chunked workloads get cancelled
    mid-chunking and shared workloads while their pages are referenced."""
    w = _draw_workload(seed)
    cfg, params = _setup(w["arch"])
    kw = dict(MODES[mode])
    if kw.get("chunked_prefill"):
        kw["prefill_chunk_tokens"] = w["chunk_tokens"]
    spec = kw.pop("spec", False)
    if spec:
        kw["drafts"] = {DRAFT_M: _draft(w["arch"])}
    obs = Observability()
    eng = Engine(cfg, params, max_len=MAX_LEN, n_slots=w["n_slots"],
                 eos_id=w["eos_id"], obs=obs, fused_step=fused,
                 step_tokens=w["step_tokens"], **kw)
    if eng.paged:
        eng.allocator = PageAllocator(w["n_pages"])
        eng.n_pages = w["n_pages"]
    aeng = AsyncEngine(eng, step_cb=_check_invariants)

    rng = np.random.default_rng(seed + 977)
    n = len(w["reqs"])
    cancel_after = [int(rng.integers(0, 4)) if rng.random() < 0.4 else None
                    for _ in range(n)]
    streams: list = [None] * n
    errs: list = []
    _done = object()

    def worker(i, prompt, max_new, delay):
        try:
            time.sleep(delay * 0.003)
            g = _spec_gamma(prompt, max_new, i) if spec else 0
            s = aeng.submit_stream(prompt, max_new, spec_gamma=g,
                                   draft_m=DRAFT_M if g else None)
            streams[i] = s
            it = iter(s)
            if cancel_after[i] is not None:
                for _ in range(cancel_after[i]):
                    if next(it, _done) is _done:
                        break
                aeng.cancel(s.rid)
            for _ in it:                     # consume the live feed
                pass
        except BaseException as e:           # surfaced after join
            errs.append(e)

    threads = [threading.Thread(target=worker, args=(i, p, mn, d))
               for i, (p, mn, d) in enumerate(w["reqs"])]
    for t in threads:
        t.start()
    for t in threads:
        t.join(120)
    aeng.shutdown(drain=True, timeout=120)
    assert not errs, errs

    for i, (prompt, max_new, _) in enumerate(w["reqs"]):
        s = streams[i]
        assert s is not None and s.done, (mode, seed, i)
        want = _oracle(cfg, params, prompt, max_new, w["eos_id"])
        got = np.asarray(s.tokens, np.int32)
        ctx = f"mode={mode} seed={seed} req={i} (arch={w['arch']})"
        if eng.finished[s.rid].cancelled:
            assert s.status == "cancelled", (ctx, s.status)
            np.testing.assert_array_equal(got, want[:len(got)],
                                          err_msg=ctx)
        else:
            assert s.status == "finished", (ctx, s.status, s.error)
            np.testing.assert_array_equal(got, want, err_msg=ctx)

    if eng.paged:
        held = eng.prefix_index.n_entries if eng.prefix_sharing else 0
        assert eng.allocator.in_use == held, (eng.allocator.in_use, held)
        eng.allocator.check_invariants()

    # registry cross-validation: streamed tokens (kept) + preempt-discarded
    # must account for every emission, lifecycle counters must match the
    # engine's, and every span tree must validate even for the requests
    # cancelled mid-chunking / mid-decode by the seeded offsets
    streamed = sum(len(s.tokens) for s in streams)
    kept = sum(len(r.tokens) for r in eng.finished.values())
    assert streamed == kept, (streamed, kept)
    _check_obs(eng, obs)


N_EXAMPLES = int(os.environ.get("NBL_FUZZ_EXAMPLES", "3"))


PATHS = {"fused": True, "legacy": False}


@pytest.mark.parametrize("path", list(PATHS))
@pytest.mark.parametrize("mode", list(MODES))
@pytest.mark.parametrize("seed", range(N_EXAMPLES))
def test_serving_oracle_fuzz(mode, seed, path):
    """Deterministic fuzz sweep: NBL_FUZZ_EXAMPLES seeds x 6 engine modes
    x {fused, legacy} step paths (CI runs 50 x 6 x 2 = 600 examples).
    Both paths replay the identical workload against the same oracle, so
    fused-vs-legacy parity is token-exact by transitivity."""
    _replay(mode, seed, fused=PATHS[path])


@pytest.mark.parametrize("path", list(PATHS))
@pytest.mark.parametrize("mode", list(MODES))
@pytest.mark.parametrize("seed", range(N_EXAMPLES))
def test_async_serving_fuzz(mode, seed, path):
    """Async host-loop fuzz: the same seeded workloads submitted from
    concurrent worker threads with streamed consumption and seeded
    mid-stream cancellation, per-step invariants, oracle parity for the
    survivors and prefix parity for the cancelled — through both step
    paths."""
    _replay_async(mode, seed, fused=PATHS[path])


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_serving_oracle_property(seed):
    """Hypothesis-driven variant of the same oracle: arbitrary seeds,
    shrinking on failure; every mode replays the identical workload
    through both step paths."""
    for mode in MODES:
        _replay(mode, seed, fused=True)
        _replay(mode, seed, fused=False)
