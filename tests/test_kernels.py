"""Pallas kernel sweeps: shapes × dtypes × features vs pure-jnp oracles
(interpret mode on CPU; same call sites compile to Mosaic on TPU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.cov_accum import cov_accum
from repro.kernels.flash_attention import flash_attention
from repro.kernels.nbl_linear import nbl_linear

KEY = jax.random.PRNGKey(7)


@pytest.mark.parametrize("b,h,kv,s,t,d", [
    (1, 4, 2, 128, 128, 64),
    (2, 4, 4, 256, 256, 32),
    (1, 8, 1, 128, 256, 64),     # MQA, cross-length
])
@pytest.mark.parametrize("window,cap", [(None, None), (64, None),
                                        (None, 30.0)])
def test_flash_attention_sweep(b, h, kv, s, t, d, window, cap):
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = jax.random.normal(k1, (b, h, s, d), jnp.float32)
    k = jax.random.normal(k2, (b, kv, t, d), jnp.float32)
    v = jax.random.normal(k3, (b, kv, t, d), jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=window, softcap=cap,
                          block_q=128, block_k=128, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window,
                                   softcap=cap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    q = jax.random.normal(KEY, (1, 2, 128, 64)).astype(dtype)
    k = jax.random.normal(KEY, (1, 2, 128, 64)).astype(dtype)
    v = jax.random.normal(KEY, (1, 2, 128, 64)).astype(dtype)
    out = flash_attention(q, k, v, interpret=True)
    want = ref.flash_attention_ref(q, k, v)
    tol = 1e-2 if dtype == jnp.bfloat16 else 3e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_flash_attention_padded_wrapper():
    """ops.attention pads seq/head_dim to block multiples transparently."""
    q = jax.random.normal(KEY, (1, 4, 100, 48))
    k = jax.random.normal(KEY, (1, 2, 100, 48))
    v = jax.random.normal(KEY, (1, 2, 100, 48))
    out = ops.attention(q, k, v, interpret=True)
    want = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("m,k,n,residual", [
    (256, 256, 256, True), (512, 512, 512, True), (256, 512, 256, False),
    (512, 256, 512, False),
])
def test_nbl_linear_sweep(m, k, n, residual):
    x = jax.random.normal(KEY, (m, k))
    w = jax.random.normal(KEY, (k, n)) * 0.05
    b = jax.random.normal(KEY, (n,))
    if residual and k != n:
        pytest.skip("residual needs square W")
    out = nbl_linear(x, w, b, residual=residual, interpret=True)
    want = ref.nbl_linear_ref(x, w, b, residual=residual)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_nbl_linear_dtype(dtype):
    x = jax.random.normal(KEY, (256, 256)).astype(dtype)
    w = (jax.random.normal(KEY, (256, 256)) * 0.05).astype(dtype)
    b = jnp.zeros((256,), dtype)
    out = nbl_linear(x, w, b, interpret=True)
    want = ref.nbl_linear_ref(x, w, b)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("t,dx,dy", [(512, 256, 256), (1024, 256, 512),
                                     (512, 512, 256)])
def test_cov_accum_sweep(t, dx, dy):
    x = jax.random.normal(KEY, (t, dx))
    y = jax.random.normal(jax.random.PRNGKey(1), (t, dy))
    acc = jnp.ones((dy, dx))
    out = cov_accum(acc, x, y, interpret=True)
    want = ref.cov_accum_ref(acc, x, y)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-3, rtol=1e-4)


def test_cov_accum_is_running_sum():
    x = jax.random.normal(KEY, (512, 256))
    acc = jnp.zeros((256, 256))
    a1 = cov_accum(acc, x[:256].copy(), interpret=True)
    a2 = cov_accum(a1, x[256:].copy(), interpret=True)
    want = ref.cov_accum_ref(acc, x)
    np.testing.assert_allclose(np.asarray(a2), np.asarray(want),
                               atol=2e-3, rtol=1e-4)


def test_nbl_wrapper_3d():
    x = jax.random.normal(KEY, (2, 100, 256))
    w = jax.random.normal(KEY, (256, 256)) * 0.05
    b = jnp.zeros((256,))
    out = ops.nbl_apply(x, w, b, interpret=True)
    want = ref.nbl_linear_ref(x.reshape(-1, 256), w, b).reshape(x.shape)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-4, rtol=1e-4)
