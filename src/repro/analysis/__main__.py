"""CLI for ``repro.analysis``.

    python -m repro.analysis [paths...] [--json FILE] [--baseline FILE]
                             [--rule RULE]... [--entry Class.method]...
                             [--write-baseline] [--no-baseline]

Paths default to ``src/repro``. Exit status: 0 when every finding is
inline-suppressed or baselined, 1 otherwise, 2 on usage errors. The JSON
report carries ``schema_version`` + git SHA provenance, matching the
benchmark artifact convention (PR 6).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from . import (
    ALL_RULES,
    SCHEMA_VERSION,
    analyze_paths,
    filter_baselined,
    load_baseline,
    save_baseline,
)
from .core import git_sha
from .host_sync import DEFAULT_ENTRIES

DEFAULT_BASELINE = os.path.join("scripts", "analysis_baseline.json")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="AST-based static checks for the NBL serving stack.",
    )
    ap.add_argument("paths", nargs="*", default=None,
                    help="files or directories (default: src/repro)")
    ap.add_argument("--json", dest="json_out", default=None, metavar="FILE",
                    help="write the full report (pre-baseline) as JSON")
    ap.add_argument("--baseline", default=None, metavar="FILE",
                    help="baseline file (default: scripts/analysis_baseline.json "
                         "when present)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore any baseline file")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write current findings to the baseline file and exit 0")
    ap.add_argument("--rule", action="append", default=None, choices=ALL_RULES,
                    help="restrict to RULE (repeatable)")
    ap.add_argument("--entry", action="append", default=None,
                    metavar="Class.method",
                    help="host-sync root(s) to check reachability from "
                         "(repeatable; default: Engine._step_impl and both "
                         "its fused/legacy variants)")
    args = ap.parse_args(argv)

    root = os.getcwd()
    paths = args.paths or [os.path.join("src", "repro")]
    for p in paths:
        if not os.path.exists(p):
            print("repro.analysis: no such path: %s" % p, file=sys.stderr)
            return 2

    rules = set(args.rule) if args.rule else None
    entry = tuple(args.entry) if args.entry else DEFAULT_ENTRIES
    findings = analyze_paths(paths, root, rules=rules, entry=entry)

    baseline_path = args.baseline or DEFAULT_BASELINE
    if args.write_baseline:
        save_baseline(baseline_path, findings)
        print("repro.analysis: wrote %d finding(s) to %s"
              % (len(findings), baseline_path))
        return 0

    baseline = set() if args.no_baseline else load_baseline(baseline_path)
    fresh = filter_baselined(findings, baseline)
    baselined = len(findings) - len(fresh)

    if args.json_out:
        report = {
            "schema_version": SCHEMA_VERSION,
            "git_sha": git_sha(root),
            "paths": list(paths),
            "counts": {
                "total": len(findings),
                "baselined": baselined,
                "fresh": len(fresh),
            },
            "findings": [f.to_json() for f in findings],
        }
        outdir = os.path.dirname(args.json_out)
        if outdir:
            os.makedirs(outdir, exist_ok=True)
        with open(args.json_out, "w", encoding="utf-8") as fh:
            json.dump(report, fh, indent=2, sort_keys=True)
            fh.write("\n")

    for f in fresh:
        print(f.render())
    if fresh:
        print("repro.analysis: %d finding(s) (%d baselined)"
              % (len(fresh), baselined), file=sys.stderr)
        return 1
    print("repro.analysis: clean (%d finding(s) baselined)" % baselined)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
