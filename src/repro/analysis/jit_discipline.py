"""Pass 2: jit discipline and retrace hazards.

``jit-discipline`` — a ``jax.jit`` construction site is sanctioned when it
is (a) module/class level (built once per import — decorators and
module-level assignments), (b) lexically inside a ``shared_jit`` /
``_shared_jit`` call (the process-wide registry in ``repro.jitcache``),
or (c) carries ``# nbl: disable=jit-discipline -- <reason>`` — the reason
is mandatory; a bare suppression does not count. Anything else builds a
fresh traced wrapper per call of the enclosing function, which is the
silent retrace/recompile tax PR 4 paid before ``_SHARED_JITS`` existed.

``jit-retrace`` — hazards that defeat jax's trace cache even for a
correctly shared wrapper:

- a raw ``jax.jit`` built inside a ``for``/``while`` loop (a fresh cache
  per iteration; ``shared_jit`` in a loop is fine, it's a registry hit);
- a list/dict/set literal passed to a parameter a local jitted function
  declares in ``static_argnames``/``static_argnums`` (statics must hash);
- a loop-variable-dependent slice fed straight into a known-jitted
  callable inside the loop (every iteration is a new shape → a new
  trace; bucket the shape first).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set

from .core import Finding, Project, SourceModule

_SHARED_NAMES = {"shared_jit", "_shared_jit"}


def run(project: Project) -> List[Finding]:
    out: List[Finding] = []
    for mod in project.modules:
        out.extend(_check_module(mod))
    return out


def _check_module(mod: SourceModule) -> List[Finding]:
    out: List[Finding] = []
    jit_sites = [n for n in ast.walk(mod.tree) if _is_jit_ref(mod, n)]
    jitted_names = _jitted_local_names(mod)
    static_params = _static_param_map(mod)

    for node in jit_sites:
        in_shared = _inside_shared_call(mod, node)
        func = _enclosing_runtime_function(mod, node)
        if func is not None and not in_shared:
            out.append(Finding(
                rule="jit-discipline",
                path=mod.rel,
                line=node.lineno,
                symbol=mod.symbol_for(node),
                message="jax.jit built in function scope (fresh wrapper per "
                        "call); route through repro.jitcache.shared_jit or "
                        "allowlist with '# nbl: disable=jit-discipline -- "
                        "<reason>'",
            ))
        if not in_shared and _inside_loop(mod, node):
            out.append(Finding(
                rule="jit-retrace",
                path=mod.rel,
                line=node.lineno,
                symbol=mod.symbol_for(node),
                message="jax.jit built inside a loop: every iteration traces "
                        "from scratch",
            ))

    for call in ast.walk(mod.tree):
        if not isinstance(call, ast.Call):
            continue
        name = _call_name(call)
        if name in static_params:
            statics = static_params[name]
            for kw in call.keywords:
                if kw.arg in statics and isinstance(
                    kw.value, (ast.List, ast.Dict, ast.Set)
                ):
                    out.append(Finding(
                        rule="jit-retrace",
                        path=mod.rel,
                        line=call.lineno,
                        symbol=mod.symbol_for(call),
                        message="unhashable %s literal passed to static arg "
                                "'%s' of jitted '%s'" % (
                                    type(kw.value).__name__.lower(), kw.arg, name,
                                ),
                    ))
        if name in jitted_names:
            loop_var = _enclosing_loop_var(mod, call)
            if loop_var is not None and _has_loopvar_slice_arg(call, loop_var):
                out.append(Finding(
                    rule="jit-retrace",
                    path=mod.rel,
                    line=call.lineno,
                    symbol=mod.symbol_for(call),
                    message="loop-variable-dependent slice shape flows into "
                            "jitted '%s' inside the loop (one trace per "
                            "iteration; bucket the shape)" % name,
                ))
    return out


# -- jit site identification -------------------------------------------------

def _is_jit_ref(mod: SourceModule, node: ast.AST) -> bool:
    # jax.jit as an attribute, or a bare `jit` imported from jax.
    if isinstance(node, ast.Attribute) and node.attr == "jit":
        if isinstance(node.value, ast.Name) and node.value.id == "jax":
            return True
    if isinstance(node, ast.Name) and node.id == "jit":
        return _imports_jax_jit(mod, node.id)
    return False


def _imports_jax_jit(mod: SourceModule, name: str) -> bool:
    for stmt in mod.tree.body:
        if isinstance(stmt, ast.ImportFrom) and stmt.module == "jax":
            for a in stmt.names:
                if (a.asname or a.name) == name and a.name == "jit":
                    return True
    return False


def _enclosing_runtime_function(mod: SourceModule, node: ast.AST):
    """Nearest enclosing function whose BODY contains ``node``.

    A jit reference inside a decorator list runs at class/module definition
    time, not per call — so a decorator position does not count as being
    inside that function (or inside a method's class scope).
    """
    child = node
    for anc in mod.ancestors(node):
        if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef)):
            in_decorator = any(
                child is d or _contains(d, child) for d in anc.decorator_list
            )
            if not in_decorator:
                return anc
        if isinstance(anc, ast.ClassDef):
            # Class body (incl. method decorators) executes once per import.
            pass
        child = anc
    return None


def _contains(tree: ast.AST, target: ast.AST) -> bool:
    return any(n is target for n in ast.walk(tree))


def _inside_shared_call(mod: SourceModule, node: ast.AST) -> bool:
    for anc in mod.ancestors(node):
        if isinstance(anc, ast.Call) and _call_name(anc) in _SHARED_NAMES:
            return True
    return False


def _inside_loop(mod: SourceModule, node: ast.AST) -> bool:
    # Only loops within the same function scope count: a def inside a loop
    # body doesn't re-run per iteration unless called there (the function-
    # scope rule already covers that).
    for anc in mod.ancestors(node):
        if isinstance(anc, (ast.For, ast.AsyncFor, ast.While)):
            return True
        if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
            return False
    return False


def _call_name(call: ast.Call) -> Optional[str]:
    fn = call.func
    if isinstance(fn, ast.Name):
        return fn.id
    if isinstance(fn, ast.Attribute):
        return fn.attr
    return None


# -- local jitted-name / static-param maps ------------------------------------

def _jitted_local_names(mod: SourceModule) -> Set[str]:
    names: Set[str] = set()
    for node in ast.walk(mod.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if any(_decorator_is_jit(mod, d) for d in node.decorator_list):
                names.add(node.name)
        elif isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            vname = _call_name(node.value)
            is_jit = _is_jit_ref(mod, node.value.func) or vname in _SHARED_NAMES
            if is_jit:
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        names.add(tgt.id)
    return names


def _decorator_is_jit(mod: SourceModule, dec: ast.AST) -> bool:
    if _is_jit_ref(mod, dec):
        return True
    if isinstance(dec, ast.Call):
        if _is_jit_ref(mod, dec.func):
            return True
        # functools.partial(jax.jit, ...)
        if _call_name(dec) == "partial" and dec.args:
            return _is_jit_ref(mod, dec.args[0])
    return False


def _static_names_of(call: ast.Call) -> Set[str]:
    statics: Set[str] = set()
    for kw in call.keywords:
        if kw.arg in ("static_argnames", "static_argnums"):
            for sub in ast.walk(kw.value):
                if isinstance(sub, ast.Constant) and isinstance(
                    sub.value, str
                ):
                    statics.add(sub.value)
    return statics


def _static_param_map(mod: SourceModule) -> Dict[str, Set[str]]:
    """name -> declared static_argnames for locally defined jitted functions
    (both the ``@jax.jit(static_argnames=...)`` decorator form and the
    ``g = jax.jit(fn, static_argnames=...)`` assignment form)."""
    out: Dict[str, Set[str]] = {}
    for node in ast.walk(mod.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                if not (isinstance(dec, ast.Call)
                        and _decorator_is_jit(mod, dec)):
                    continue
                statics = _static_names_of(dec)
                if statics:
                    out[node.name] = statics
        elif isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            if not _is_jit_ref(mod, node.value.func):
                continue
            statics = _static_names_of(node.value)
            if statics:
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        out[tgt.id] = statics
    return out


# -- loop-shape hazard --------------------------------------------------------

def _enclosing_loop_var(mod: SourceModule, node: ast.AST) -> Optional[str]:
    for anc in mod.ancestors(node):
        if isinstance(anc, (ast.For, ast.AsyncFor)):
            if isinstance(anc.target, ast.Name):
                return anc.target.id
        if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return None
    return None


def _has_loopvar_slice_arg(call: ast.Call, loop_var: str) -> bool:
    for arg in list(call.args) + [kw.value for kw in call.keywords]:
        for sub in ast.walk(arg):
            if isinstance(sub, ast.Subscript):
                sl = sub.slice
                if isinstance(sl, ast.Slice):
                    for bound in (sl.lower, sl.upper, sl.step):
                        if bound is None:
                            continue
                        for n in ast.walk(bound):
                            if isinstance(n, ast.Name) and n.id == loop_var:
                                return True
    return False
