"""repro.analysis — AST-based multi-pass static checker for the NBL stack.

Dependency-free (stdlib ``ast`` only; importable before jax/numpy). Four
passes over the source tree enforce the conventions the serving engine's
correctness and throughput rest on:

===============  ============================================================
rule             enforces
===============  ============================================================
guarded-by       ``# guarded-by: <lock>`` attrs touched only under the lock
lock-order       no Lock self-deadlock, no cross-lock acquisition cycles
jit-discipline   function-scope ``jax.jit`` routes through ``shared_jit``
jit-retrace      jit-in-loop / unhashable statics / unbucketed loop shapes
host-sync        no device→host syncs reachable from the step entries
                 (both ``Engine._step_impl`` variants; ``--entry``)
perf-counter     ``time.perf_counter`` confined to ``src/repro/obs/``
obs-hygiene      every obs hook call behind an ``is not None`` guard
===============  ============================================================

CLI: ``python -m repro.analysis [paths...] [--json out.json]`` — exits 0
when every finding is suppressed inline or baselined, 1 otherwise. See
``docs/static-analysis.md`` for the rule catalog and workflows.
"""
from __future__ import annotations

from typing import Iterable, List, Optional, Sequence, Set, Tuple, Union

from . import guarded_by, host_sync, jit_discipline, obs_hygiene
from .core import (
    ALL_RULES,
    Finding,
    Project,
    SourceModule,
    SCHEMA_VERSION,
    collect_modules,
    filter_baselined,
    load_baseline,
    save_baseline,
)

__all__ = [
    "ALL_RULES",
    "Finding",
    "Project",
    "SCHEMA_VERSION",
    "analyze_modules",
    "analyze_paths",
    "analyze_source",
    "collect_modules",
    "filter_baselined",
    "load_baseline",
    "save_baseline",
]


def analyze_modules(
    modules: Sequence[SourceModule],
    rules: Optional[Set[str]] = None,
    entry: Union[str, Iterable[str]] = host_sync.DEFAULT_ENTRIES,
) -> List[Finding]:
    """Run every pass over ``modules``; inline suppressions applied."""
    project = Project(modules)
    raw: List[Finding] = []
    raw += guarded_by.run(project)
    raw += jit_discipline.run(project)
    raw += host_sync.run(project, entry=entry)
    raw += obs_hygiene.run(project)
    by_rel = {m.rel: m for m in modules}
    out = []
    for f in raw:
        if rules is not None and f.rule not in rules:
            continue
        mod = by_rel.get(f.path)
        if mod is not None and mod.is_suppressed(f.line, f.rule):
            continue
        out.append(f)
    out.sort(key=lambda f: (f.path, f.line, f.rule, f.message))
    return out


def analyze_paths(
    paths: Sequence[str],
    root: str,
    rules: Optional[Set[str]] = None,
    entry: Union[str, Iterable[str]] = host_sync.DEFAULT_ENTRIES,
) -> List[Finding]:
    return analyze_modules(collect_modules(paths, root), rules=rules,
                           entry=entry)


def analyze_source(
    text: str,
    rel: str = "fixture.py",
    rules: Optional[Set[str]] = None,
    entry: Union[str, Iterable[str]] = host_sync.DEFAULT_ENTRIES,
) -> List[Finding]:
    """Analyze a source string — the test-fixture entry point."""
    return analyze_modules(
        [SourceModule(rel, text, rel)], rules=rules, entry=entry
    )
