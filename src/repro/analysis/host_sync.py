"""Pass 3: hot-path host syncs and the perf-counter confinement rule.

``host-sync`` — build the call graph reachable from the engine step
entries (default: ``Engine._step_impl`` plus both its variants,
``_step_fused`` and ``_step_legacy`` — override with ``--entry``, given
through ``self.m()``, typed-attribute calls like
``self.allocator.free()``, and imported module-level functions) and flag
device→host synchronization points inside it: ``.item()``, ``.block_until_ready()``, ``jax.device_get``
/ ``jax.block_until_ready``, ``np.asarray`` / ``np.array`` (numpy forces a
device fetch on a jax array), and ``float(...)`` on a non-literal. The
engine's deliberate once-per-step logits readbacks are marked in source
with ``# host-sync: readback -- <why>`` and skipped; anything else is a
stall the step timeline (PR 6) would book as host time.

``perf-counter`` — ``time.perf_counter`` may only be referenced under
``src/repro/obs/`` (which exports it as ``repro.obs.clock``). This is the
AST replacement for the grep lint PR 6 put in ``ci.sh``: one timebase,
owned by the observability layer, no ad-hoc timing scattered through the
tree.
"""
from __future__ import annotations

import ast
from typing import Iterable, List, Optional, Set, Tuple, Union

from .core import Finding, Project, SourceModule

# Both _step_impl variants are checked roots: the fused pipeline's
# readback lives in _commit_fused, the legacy one in _step_legacy —
# listing the variants explicitly keeps the sanctioning independent of
# whether the dispatcher's self-calls resolve.
DEFAULT_ENTRIES = ("Engine._step_impl", "Engine._step_fused",
                   "Engine._step_legacy")
DEFAULT_ENTRY = DEFAULT_ENTRIES          # back-compat alias

_SYNC_METHODS = {"item", "block_until_ready"}
_NUMPY_SYNC_FUNCS = {"asarray", "array", "ascontiguousarray"}
_JAX_SYNC_FUNCS = {"device_get", "block_until_ready"}


def run(
    project: Project,
    entry: Union[str, Iterable[str]] = DEFAULT_ENTRIES,
) -> List[Finding]:
    entries = (entry,) if isinstance(entry, str) else tuple(entry)
    out: List[Finding] = []
    seen: Set[Tuple[str, str]] = set()
    for e in entries:
        for (mod, cls_name, func), qual in _reachable_from(project, e):
            key = (mod.rel, qual)
            if key in seen:
                continue
            seen.add(key)
            out.extend(_scan_function(project, mod, func, qual))
    out.extend(_perf_counter_scan(project))
    return out


# -- reachability ------------------------------------------------------------

def _reachable_from(project: Project, entry: str):
    """BFS over the resolvable call graph from ``entry`` ('Class.method')."""
    cls_name, _, meth = entry.partition(".")
    info = project.classes.get(cls_name)
    if info is None or meth not in info.methods:
        return []
    start = (info.module, info, info.methods[meth])
    seen: Set[Tuple[str, str]] = set()
    order = []
    stack = [(start, entry)]
    while stack:
        (mod, cls, func), qual = stack.pop()
        key = (mod.rel, qual)
        if key in seen:
            continue
        seen.add(key)
        order.append(((mod, cls.name if cls else None, func), qual))
        for sub in ast.walk(func):
            if not isinstance(sub, ast.Call):
                continue
            hit = project.resolve_call(mod, cls, sub)
            if hit is None:
                continue
            tmod, tfn, tqual = hit
            tcls = project.class_of_method(tmod, tfn)
            stack.append(((tmod, tcls, tfn), tqual))
    return order


# -- sync detection ----------------------------------------------------------

def _scan_function(
    project: Project, mod: SourceModule, func: ast.FunctionDef, qual: str
) -> List[Finding]:
    out: List[Finding] = []
    imap = project.imports.get(mod.rel, {})

    def _module_of(name: str) -> Optional[str]:
        return imap.get(name)

    for node in ast.walk(func):
        if not isinstance(node, ast.Call):
            continue
        desc = None
        fn = node.func
        if isinstance(fn, ast.Attribute):
            if fn.attr in _SYNC_METHODS and not isinstance(fn.value, ast.Name):
                desc = ".%s()" % fn.attr
            elif fn.attr in _SYNC_METHODS and isinstance(fn.value, ast.Name):
                base = _module_of(fn.value.id)
                if base is None:  # a value, not a module alias
                    desc = ".%s()" % fn.attr
            if desc is None and isinstance(fn.value, ast.Name):
                base = _module_of(fn.value.id)
                if base == "numpy" and fn.attr in _NUMPY_SYNC_FUNCS:
                    desc = "np.%s()" % fn.attr
                elif base == "jax" and fn.attr in _JAX_SYNC_FUNCS:
                    desc = "jax.%s()" % fn.attr
        elif isinstance(fn, ast.Name) and fn.id == "float":
            if node.args and not isinstance(node.args[0], ast.Constant):
                desc = "float() on a non-literal"
        if desc is None:
            continue
        if node.lineno in mod.host_sync_ok:
            continue
        out.append(Finding(
            rule="host-sync",
            path=mod.rel,
            line=node.lineno,
            symbol=qual,
            message="device->host sync %s reachable from the step path; move "
                    "off the hot path or sanction with '# host-sync: "
                    "readback -- <why>'" % desc,
        ))
    return out


# -- perf-counter confinement -------------------------------------------------

def _perf_counter_scan(project: Project) -> List[Finding]:
    out: List[Finding] = []
    for mod in project.modules:
        if "/obs/" in "/" + mod.rel or mod.rel.startswith("obs/"):
            continue
        imap = project.imports.get(mod.rel, {})
        for node in ast.walk(mod.tree):
            hit = False
            if (
                isinstance(node, ast.Attribute)
                and node.attr == "perf_counter"
                and isinstance(node.value, ast.Name)
                and imap.get(node.value.id, "").startswith("time")
            ):
                hit = True
            elif (
                isinstance(node, ast.Name)
                and node.id == "perf_counter"
                and imap.get("perf_counter", "") == "time.perf_counter"
                and isinstance(getattr(node, "ctx", None), ast.Load)
            ):
                hit = True
            if hit:
                out.append(Finding(
                    rule="perf-counter",
                    path=mod.rel,
                    line=node.lineno,
                    symbol=mod.symbol_for(node),
                    message="time.perf_counter referenced outside "
                            "src/repro/obs/; use repro.obs.clock",
                ))
    return out
