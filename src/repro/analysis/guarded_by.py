"""Pass 1: lock discipline.

``guarded-by`` — an attribute annotated ``# guarded-by: <lock>`` on its
``__init__`` assignment must only be touched (read, written, deleted,
subscripted) lexically inside ``with self.<lock>:`` anywhere else in the
class. ``__init__`` itself is exempt: the object is not yet shared.

``lock-order`` — build each method's transitive lock-acquire set (through
``self.m()`` calls, typed-attribute calls like ``self.engine.submit()``,
and imported module-level functions), derive held→acquired edges, and
flag cycles. Re-acquiring an ``RLock`` you already hold is legal (that is
why ``AsyncEngine._lock`` is an RLock); a plain ``Lock`` self-edge is a
guaranteed deadlock and any multi-lock cycle is a potential one.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from .core import ClassInfo, Finding, Project, SourceModule


def run(project: Project) -> List[Finding]:
    findings: List[Finding] = []
    for info in project.classes.values():
        if info.guarded_attrs:
            findings.extend(_check_guarded(info))
    findings.extend(_check_lock_order(project))
    return findings


# -- guarded-by --------------------------------------------------------------

def _check_guarded(info: ClassInfo) -> List[Finding]:
    mod = info.module
    out: List[Finding] = []
    init = info.methods.get("__init__")
    for node in ast.walk(info.node):
        if not (
            isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"
            and node.attr in info.guarded_attrs
        ):
            continue
        func = mod.enclosing_function(node)
        if func is init:
            continue
        # Accessing the attr in a nested class is out of scope for this class.
        if mod.enclosing_class(node) is not info.node:
            continue
        lock = info.guarded_attrs[node.attr]
        if _inside_with_lock(mod, node, lock):
            continue
        out.append(Finding(
            rule="guarded-by",
            path=mod.rel,
            line=node.lineno,
            symbol=mod.symbol_for(node),
            message="self.%s is guarded by self.%s but accessed outside "
                    "'with self.%s:'" % (node.attr, lock, lock),
        ))
    return out


def _inside_with_lock(mod: SourceModule, node: ast.AST, lock: str) -> bool:
    for anc in mod.ancestors(node):
        if isinstance(anc, (ast.With, ast.AsyncWith)):
            for item in anc.items:
                if _is_self_attr(item.context_expr, lock):
                    return True
    return False


def _is_self_attr(expr: ast.AST, attr: str) -> bool:
    return (
        isinstance(expr, ast.Attribute)
        and expr.attr == attr
        and isinstance(expr.value, ast.Name)
        and expr.value.id == "self"
    )


# -- lock-order --------------------------------------------------------------

def _check_lock_order(project: Project) -> List[Finding]:
    # Transitive acquire sets per (class, method) / module function, to a
    # fixpoint over the resolvable call graph. Locks are qualified as
    # 'Class.lockattr' so the order graph spans classes.
    FnKey = Tuple[str, str]  # (module rel, qualname)
    direct: Dict[FnKey, Set[str]] = {}
    calls: Dict[FnKey, List[FnKey]] = {}
    nodes: Dict[FnKey, Tuple[SourceModule, Optional[ClassInfo], ast.FunctionDef]] = {}

    def _locks_of(cls: Optional[ClassInfo], expr: ast.AST) -> Optional[str]:
        if cls is None or not isinstance(expr, ast.Attribute):
            return None
        if not (isinstance(expr.value, ast.Name) and expr.value.id == "self"):
            return None
        if expr.attr in cls.lock_kinds:
            return "%s.%s" % (cls.name, expr.attr)
        return None

    for mod in project.modules:
        for fnode in ast.walk(mod.tree):
            if not isinstance(fnode, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            cls = project.class_of_method(mod, fnode)
            key = (mod.rel, mod.symbol_for(fnode))
            nodes[key] = (mod, cls, fnode)
            acq: Set[str] = set()
            callees: List[FnKey] = []
            for sub in ast.walk(fnode):
                if isinstance(sub, (ast.With, ast.AsyncWith)):
                    for item in sub.items:
                        lk = _locks_of(cls, item.context_expr)
                        if lk is not None:
                            acq.add(lk)
                elif isinstance(sub, ast.Call):
                    hit = project.resolve_call(mod, cls, sub)
                    if hit is not None:
                        tmod, tfn, _ = hit
                        callees.append((tmod.rel, tmod.symbol_for(tfn)))
            direct[key] = acq
            calls[key] = callees

    trans: Dict[FnKey, Set[str]] = {k: set(v) for k, v in direct.items()}
    changed = True
    while changed:
        changed = False
        for key, callees in calls.items():
            before = len(trans[key])
            for c in callees:
                trans[key] |= trans.get(c, set())
            if len(trans[key]) != before:
                changed = True

    # Edges: while lexically holding A, a nested acquire (direct or through
    # a resolvable call) of B gives A -> B. Witness line kept per edge.
    edges: Dict[Tuple[str, str], Tuple[str, int, str]] = {}
    rlocks = {
        "%s.%s" % (info.name, attr)
        for info in project.classes.values()
        for attr, kind in info.lock_kinds.items()
        if kind == "RLock"
    }

    for key, (mod, cls, fnode) in nodes.items():
        for sub in ast.walk(fnode):
            if not isinstance(sub, (ast.With, ast.AsyncWith)):
                continue
            held = [
                lk for item in sub.items
                for lk in [_locks_of(cls, item.context_expr)]
                if lk is not None
            ]
            if not held:
                continue
            for inner in ast.walk(sub):
                if inner is sub:
                    continue
                acquired: Set[str] = set()
                line = getattr(inner, "lineno", sub.lineno)
                if isinstance(inner, (ast.With, ast.AsyncWith)):
                    for item in inner.items:
                        lk = _locks_of(cls, item.context_expr)
                        if lk is not None:
                            acquired.add(lk)
                elif isinstance(inner, ast.Call):
                    hit = project.resolve_call(mod, cls, inner)
                    if hit is not None:
                        tmod, tfn, _ = hit
                        acquired |= trans.get((tmod.rel, tmod.symbol_for(tfn)), set())
                for a in held:
                    for b in acquired:
                        if (a, b) not in edges:
                            edges[(a, b)] = (mod.rel, line, mod.symbol_for(sub))

    out: List[Finding] = []
    for (a, b), (rel, line, symbol) in sorted(edges.items()):
        if a == b:
            if a not in rlocks:
                out.append(Finding(
                    rule="lock-order", path=rel, line=line, symbol=symbol,
                    message="plain Lock %s re-acquired while held "
                            "(self-deadlock; use RLock or drop the lock "
                            "before the call)" % a,
                ))

    # Multi-lock cycles via DFS over distinct-lock edges.
    graph: Dict[str, Set[str]] = {}
    for (a, b) in edges:
        if a != b:
            graph.setdefault(a, set()).add(b)
    for cycle in _find_cycles(graph):
        a = cycle[0]
        rel, line, symbol = edges[(a, cycle[1])]
        out.append(Finding(
            rule="lock-order", path=rel, line=line, symbol=symbol,
            message="lock acquisition cycle: %s" % " -> ".join(cycle + [a]),
        ))
    return out


def _find_cycles(graph: Dict[str, Set[str]]) -> List[List[str]]:
    cycles: List[List[str]] = []
    seen_cycles: Set[Tuple[str, ...]] = set()

    def dfs(node: str, path: List[str], on_path: Set[str]) -> None:
        for nxt in sorted(graph.get(node, ())):
            if nxt in on_path:
                i = path.index(nxt)
                cyc = path[i:]
                # Canonical rotation so each cycle reports once.
                j = cyc.index(min(cyc))
                canon = tuple(cyc[j:] + cyc[:j])
                if canon not in seen_cycles:
                    seen_cycles.add(canon)
                    cycles.append(list(canon))
            else:
                dfs(nxt, path + [nxt], on_path | {nxt})

    for start in sorted(graph):
        dfs(start, [start], {start})
    return cycles
