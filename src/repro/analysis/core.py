"""Core machinery for ``repro.analysis``: findings, suppressions, project model.

Everything here is stdlib-only (``ast`` + ``re`` + ``json``): the analyzer
must be importable in the barest CI container, before jax or numpy.

The pieces:

- :class:`Finding` — one diagnostic. Baseline identity is the tuple
  ``(rule, path, symbol, message)`` — deliberately line-INsensitive so an
  unrelated edit above a baselined finding does not resurrect it.
- :class:`SourceModule` — a parsed file: AST with parent links, physical
  lines, and the structured-comment maps (``# nbl: disable=``,
  ``# guarded-by:``, ``# host-sync:``).
- :class:`Project` — the cross-module view: class registry, per-module
  import maps, attribute typing mined from ``__init__`` bodies, and call
  resolution (``self.m()``, ``self.attr.m()`` via typed attrs, imported
  module-level functions). The guarded-by lock-order check and the
  host-sync call graph both ride on this.
- Baseline IO — load/save/filter against ``scripts/analysis_baseline.json``.

Structured comment grammar (all parsed here, consumed by the passes):

- ``# nbl: disable=<rule>[,<rule>...][ -- <reason>]`` — suppress the named
  rules on this line (or, when the comment stands alone on its own line,
  on the next line). ``jit-discipline`` suppressions REQUIRE a reason —
  that is the "allowlist-with-reason"; a bare one does not suppress.
- ``# guarded-by: <lock>`` — on a ``self.attr = ...`` line in ``__init__``:
  every other read/write of ``self.attr`` in the class must sit lexically
  inside ``with self.<lock>:``.
- ``# host-sync: readback[ -- <reason>]`` — sanctions a device→host sync
  on this line (or the next, when comment-only) as a deliberate per-step
  readback point.
"""
from __future__ import annotations

import ast
import json
import os
import re
import subprocess
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

SCHEMA_VERSION = 1

#: Every rule the four passes can emit, for CLI validation and docs.
ALL_RULES = (
    "guarded-by",
    "lock-order",
    "jit-discipline",
    "jit-retrace",
    "host-sync",
    "perf-counter",
    "obs-hygiene",
)

_SUPPRESS_RE = re.compile(
    r"#\s*nbl:\s*disable=([a-z0-9,\-\s]+?)(?:\s*--\s*(.*?))?\s*$"
)
_GUARDED_RE = re.compile(r"#\s*guarded-by:\s*([A-Za-z_][A-Za-z0-9_]*)\s*$")
_HOSTSYNC_RE = re.compile(r"#\s*host-sync:\s*readback(?:\s*--\s*(.*?))?\s*$")


@dataclass(frozen=True)
class Finding:
    rule: str
    path: str  # repo-relative, '/'-separated
    line: int
    symbol: str  # 'Class.method', 'func', or '<module>'
    message: str

    @property
    def baseline_key(self) -> Tuple[str, str, str, str]:
        return (self.rule, self.path, self.symbol, self.message)

    def to_json(self) -> dict:
        return {
            "rule": self.rule,
            "path": self.path,
            "line": self.line,
            "symbol": self.symbol,
            "message": self.message,
        }

    def render(self) -> str:
        return "%s:%d: [%s] %s (%s)" % (
            self.path, self.line, self.rule, self.message, self.symbol,
        )


@dataclass
class Suppression:
    rules: Tuple[str, ...]
    reason: Optional[str]
    comment_only: bool  # whole line is just the comment → applies to next line


class SourceModule:
    """One parsed source file plus its structured-comment maps."""

    def __init__(self, path: str, text: str, rel: str):
        self.path = path
        self.rel = rel.replace(os.sep, "/")
        self.text = text
        self.lines = text.splitlines()
        self.tree = ast.parse(text, filename=path)
        _link_parents(self.tree)
        self.suppressions: Dict[int, Suppression] = {}
        self.guarded_by: Dict[int, str] = {}  # line -> lock name
        self.host_sync_ok: Dict[int, Optional[str]] = {}  # line -> reason
        self._scan_comments()

    # -- structured comments ------------------------------------------------
    def _next_code_line(self, i: int) -> int:
        """First non-blank, non-comment line after line ``i`` (1-indexed)."""
        j = i + 1
        while j <= len(self.lines):
            stripped = self.lines[j - 1].strip()
            if stripped and not stripped.startswith("#"):
                return j
            j += 1
        return j

    def _scan_comments(self) -> None:
        for i, raw in enumerate(self.lines, start=1):
            if "#" not in raw:
                continue
            comment_only = raw.lstrip().startswith("#")
            m = _SUPPRESS_RE.search(raw)
            if m:
                rules = tuple(
                    r.strip() for r in m.group(1).split(",") if r.strip()
                )
                s = Suppression(
                    rules=rules, reason=m.group(2), comment_only=comment_only
                )
                # a comment-only directive covers the statement it precedes
                at = self._next_code_line(i) if comment_only else i
                self.suppressions.setdefault(at, s)
            m = _GUARDED_RE.search(raw)
            if m:
                self.guarded_by[i] = m.group(1)
            m = _HOSTSYNC_RE.search(raw)
            if m:
                at = self._next_code_line(i) if comment_only else i
                self.host_sync_ok.setdefault(at, m.group(1))

    def suppression_for(self, line: int, rule: str) -> Optional[Suppression]:
        """The suppression covering ``line`` for ``rule``, if any."""
        s = self.suppressions.get(line)
        if s is not None and rule in s.rules:
            return s
        return None

    def is_suppressed(self, line: int, rule: str) -> bool:
        s = self.suppression_for(line, rule)
        if s is None:
            return False
        # The jit allowlist is only an allowlist if it says WHY.
        if rule == "jit-discipline" and not (s.reason and s.reason.strip()):
            return False
        return True

    # -- convenience --------------------------------------------------------
    def symbol_for(self, node: ast.AST) -> str:
        parts: List[str] = []
        cur = getattr(node, "_nbl_parent", None)
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
            parts.append(node.name)
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
                parts.append(cur.name)
            cur = getattr(cur, "_nbl_parent", None)
        return ".".join(reversed(parts)) or "<module>"

    def enclosing_function(self, node: ast.AST):
        cur = getattr(node, "_nbl_parent", None)
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return cur
            cur = getattr(cur, "_nbl_parent", None)
        return None

    def enclosing_class(self, node: ast.AST):
        cur = getattr(node, "_nbl_parent", None)
        while cur is not None:
            if isinstance(cur, ast.ClassDef):
                return cur
            cur = getattr(cur, "_nbl_parent", None)
        return None

    def ancestors(self, node: ast.AST):
        cur = getattr(node, "_nbl_parent", None)
        while cur is not None:
            yield cur
            cur = getattr(cur, "_nbl_parent", None)


def _link_parents(tree: ast.AST) -> None:
    for parent in ast.walk(tree):
        for child in ast.iter_child_nodes(parent):
            child._nbl_parent = parent  # type: ignore[attr-defined]


# -- cross-module project model ---------------------------------------------

@dataclass
class ClassInfo:
    name: str
    module: SourceModule
    node: ast.ClassDef
    methods: Dict[str, ast.FunctionDef] = field(default_factory=dict)
    #: attribute name -> candidate simple class names (mined from __init__;
    #: candidates because 'Optional["Engine"]' yields both names and the
    #: registry may not know either yet — resolve_call picks the first hit)
    attr_types: Dict[str, Tuple[str, ...]] = field(default_factory=dict)
    #: attribute name -> lock attr name (from # guarded-by: annotations)
    guarded_attrs: Dict[str, str] = field(default_factory=dict)
    #: lock attr name -> 'Lock' | 'RLock' (from threading.X() in __init__)
    lock_kinds: Dict[str, str] = field(default_factory=dict)


class Project:
    """Cross-module context: classes, imports, typed attrs, call resolution."""

    def __init__(self, modules: Sequence[SourceModule]):
        self.modules = list(modules)
        self.classes: Dict[str, ClassInfo] = {}
        #: module rel-path -> {local name -> imported dotted target}
        self.imports: Dict[str, Dict[str, str]] = {}
        #: module rel-path -> {name -> FunctionDef} for module-level defs
        self.module_funcs: Dict[str, Dict[str, ast.FunctionDef]] = {}
        self._index()

    # -- indexing ------------------------------------------------------------
    def _index(self) -> None:
        for mod in self.modules:
            imap: Dict[str, str] = {}
            funcs: Dict[str, ast.FunctionDef] = {}
            for node in mod.tree.body:
                if isinstance(node, ast.Import):
                    for a in node.names:
                        imap[a.asname or a.name.split(".")[0]] = a.name
                elif isinstance(node, ast.ImportFrom) and node.module:
                    for a in node.names:
                        imap[a.asname or a.name] = node.module + "." + a.name
                elif isinstance(node, ast.FunctionDef):
                    funcs[node.name] = node
                elif isinstance(node, ast.ClassDef):
                    self._index_class(mod, node)
            self.imports[mod.rel] = imap
            self.module_funcs[mod.rel] = funcs

    def _index_class(self, mod: SourceModule, node: ast.ClassDef) -> None:
        info = ClassInfo(name=node.name, module=mod, node=node)
        for item in node.body:
            if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
                info.methods[item.name] = item  # type: ignore[assignment]
        init = info.methods.get("__init__")
        if init is not None:
            self._mine_init(info, init)
        self.classes.setdefault(node.name, info)

    def _mine_init(self, info: ClassInfo, init: ast.FunctionDef) -> None:
        # Parameter annotations: name -> candidate class names from the
        # annotation's AST (handles Optional["Scheduler"] etc.).
        param_types: Dict[str, Tuple[str, ...]] = {}
        args = list(init.args.args) + list(init.args.kwonlyargs)
        for a in args:
            if a.annotation is not None:
                cands = tuple(_class_names_in(ast.dump(a.annotation)))
                if cands:
                    param_types[a.arg] = cands
        for stmt in ast.walk(init):
            if not isinstance(stmt, (ast.Assign, ast.AnnAssign)):
                continue
            targets = stmt.targets if isinstance(stmt, ast.Assign) else [stmt.target]
            value = stmt.value
            for tgt in targets:
                if not (
                    isinstance(tgt, ast.Attribute)
                    and isinstance(tgt.value, ast.Name)
                    and tgt.value.id == "self"
                ):
                    continue
                attr = tgt.attr
                lock = info.module.guarded_by.get(stmt.lineno)
                if lock is not None:
                    info.guarded_attrs[attr] = lock
                t = self._value_type(value, param_types)
                if t:
                    info.attr_types[attr] = t
                kind = _lock_kind(value)
                if kind is not None:
                    info.lock_kinds[attr] = kind

    def _value_type(
        self, value, param_types: Dict[str, Tuple[str, ...]]
    ) -> Tuple[str, ...]:
        if value is None:
            return ()
        if isinstance(value, ast.Call):
            fn = value.func
            name = fn.id if isinstance(fn, ast.Name) else (
                fn.attr if isinstance(fn, ast.Attribute) else None
            )
            if name is not None and name[:1].isupper():
                return (name,)
        elif isinstance(value, ast.Name) and value.id in param_types:
            return param_types[value.id]
        elif isinstance(value, ast.BoolOp):
            for v in value.values:
                t = self._value_type(v, param_types)
                if t:
                    return t
        elif isinstance(value, ast.IfExp):
            for v in (value.body, value.orelse):
                t = self._value_type(v, param_types)
                if t:
                    return t
        return ()

    # -- call resolution -----------------------------------------------------
    def resolve_call(
        self, mod: SourceModule, cls: Optional[ClassInfo], call: ast.Call
    ) -> Optional[Tuple[SourceModule, ast.FunctionDef, str]]:
        """Resolve ``call`` to (module, funcdef, qualname) when statically possible."""
        fn = call.func
        if isinstance(fn, ast.Attribute):
            base = fn.value
            if isinstance(base, ast.Name) and base.id == "self" and cls is not None:
                target = cls.methods.get(fn.attr)
                if target is not None:
                    return (cls.module, target, "%s.%s" % (cls.name, fn.attr))
            elif (
                isinstance(base, ast.Attribute)
                and isinstance(base.value, ast.Name)
                and base.value.id == "self"
                and cls is not None
            ):
                for tname in cls.attr_types.get(base.attr, ()):
                    tinfo = self.classes.get(tname)
                    if tinfo is None:
                        continue
                    target = tinfo.methods.get(fn.attr)
                    if target is not None:
                        return (
                            tinfo.module,
                            target,
                            "%s.%s" % (tinfo.name, fn.attr),
                        )
        elif isinstance(fn, ast.Name):
            local = self.module_funcs.get(mod.rel, {}).get(fn.id)
            if local is not None:
                return (mod, local, fn.id)
            dotted = self.imports.get(mod.rel, {}).get(fn.id)
            if dotted is not None:
                hit = self._lookup_dotted(dotted)
                if hit is not None:
                    return hit
        return None

    def _lookup_dotted(
        self, dotted: str
    ) -> Optional[Tuple[SourceModule, ast.FunctionDef, str]]:
        # 'repro.models.paging.span_pages' -> module src/repro/models/paging.py
        parts = dotted.split(".")
        name = parts[-1]
        modpath = "/".join(parts[:-1]) + ".py"
        for mod in self.modules:
            if mod.rel.endswith(modpath):
                fd = self.module_funcs.get(mod.rel, {}).get(name)
                if fd is not None:
                    return (mod, fd, name)
        return None

    def class_of_method(self, mod: SourceModule, func: ast.FunctionDef):
        cnode = mod.enclosing_class(func)
        if cnode is None:
            return None
        info = self.classes.get(cnode.name)
        if info is not None and info.node is cnode:
            return info
        return None


def _class_names_in(annotation_dump: str) -> List[str]:
    # Class names referenced in an annotation's ast.dump — quoted forward
    # refs show up as Constant values, plain names as Name ids.
    return re.findall(r"(?:id|value)='([A-Z][A-Za-z0-9_]*)'", annotation_dump)


def _lock_kind(value) -> Optional[str]:
    if isinstance(value, ast.Call):
        fn = value.func
        if isinstance(fn, ast.Attribute) and fn.attr in ("Lock", "RLock"):
            return fn.attr
        if isinstance(fn, ast.Name) and fn.id in ("Lock", "RLock"):
            return fn.id
    return None


# -- file collection ---------------------------------------------------------

def collect_modules(paths: Sequence[str], root: str) -> List[SourceModule]:
    """Parse every .py under ``paths`` (files or directories) into modules."""
    files: List[str] = []
    for p in paths:
        ap = os.path.abspath(p)
        if os.path.isfile(ap) and ap.endswith(".py"):
            files.append(ap)
        elif os.path.isdir(ap):
            for dirpath, dirnames, filenames in os.walk(ap):
                dirnames[:] = sorted(
                    d for d in dirnames
                    if d not in ("__pycache__", ".git", "out", ".venv")
                )
                for fn in sorted(filenames):
                    if fn.endswith(".py"):
                        files.append(os.path.join(dirpath, fn))
    mods = []
    for f in sorted(set(files)):
        rel = os.path.relpath(f, root)
        with open(f, "r", encoding="utf-8") as fh:
            text = fh.read()
        mods.append(SourceModule(f, text, rel))
    return mods


# -- baseline ----------------------------------------------------------------

def load_baseline(path: str) -> Set[Tuple[str, str, str, str]]:
    if not os.path.exists(path):
        return set()
    with open(path, "r", encoding="utf-8") as fh:
        data = json.load(fh)
    keys = set()
    for f in data.get("findings", []):
        keys.add((f["rule"], f["path"], f["symbol"], f["message"]))
    return keys


def save_baseline(path: str, findings: Sequence[Finding]) -> None:
    data = {
        "schema_version": SCHEMA_VERSION,
        "findings": [f.to_json() for f in sorted(
            findings, key=lambda f: (f.path, f.rule, f.line)
        )],
    }
    with open(path, "w", encoding="utf-8") as fh:
        json.dump(data, fh, indent=2, sort_keys=True)
        fh.write("\n")


def filter_baselined(
    findings: Sequence[Finding], baseline: Set[Tuple[str, str, str, str]]
) -> List[Finding]:
    return [f for f in findings if f.baseline_key not in baseline]


def git_sha(root: str) -> Optional[str]:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=root,
            capture_output=True, text=True, timeout=10,
        )
        if out.returncode == 0:
            return out.stdout.strip()
    except Exception:
        pass
    return None
