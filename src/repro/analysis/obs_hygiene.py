"""Pass 4: obs-hook hygiene.

The observability layer's overhead contract (docs/observability.md) is
structural: every hook call site is guarded by an ``is not None`` branch,
so running with ``obs=None`` costs one pointer compare and zero dispatch.
This pass keeps that contract honest — any call through an ``obs``
attribute chain (``self.obs.on_token(...)``, ``eng.obs.tracer.export()``),
through a local alias assigned from one (``o = eng.obs``), or through a
parameter/variable named ``obs``, must sit under a guard:

- ``if <obs> is not None:`` (call in the body), or ``if <obs> is None:``
  with the call in the else branch;
- a conditional expression ``X if <obs> is not None else Y`` (the engine's
  ``annotate(...) if self.obs is not None else _NULLCTX`` pattern);
- short-circuit ``<obs> is not None and <obs>.hook(...)``;
- an early return: a preceding top-of-function ``if <obs> is None:
  return/raise/continue``.

Constructing ``Observability(...)`` locally and calling it is fine — a
fresh instance can't be None; the pass only tracks obs-typed *references*.
"""
from __future__ import annotations

import ast
from typing import List, Optional, Set

from .core import Finding, Project, SourceModule


def run(project: Project) -> List[Finding]:
    out: List[Finding] = []
    for mod in project.modules:
        out.extend(_check_module(mod))
    return out


def _check_module(mod: SourceModule) -> List[Finding]:
    out: List[Finding] = []
    for scope in ast.walk(mod.tree):
        if not isinstance(scope, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        aliases = _obs_aliases(scope)
        if _constructs_obs(scope):
            # Locally constructed instances are never None; aliases of the
            # construction would need flow analysis — skip the scope's bare
            # names and keep checking explicit .obs chains only.
            bare_names: Set[str] = set()
        else:
            bare_names = aliases | ({"obs"} if _has_obs_param(scope) else set())
        for node in ast.walk(scope):
            if not isinstance(node, ast.Call):
                continue
            if mod.enclosing_function(node) is not scope:
                continue
            target = _obs_target(node, bare_names)
            if target is None:
                continue
            if _is_guarded(mod, scope, node, bare_names):
                continue
            out.append(Finding(
                rule="obs-hygiene",
                path=mod.rel,
                line=node.lineno,
                symbol=mod.symbol_for(node),
                message="obs hook call '%s' not guarded by an "
                        "'is not None' branch" % target,
            ))
    return out


# -- what counts as an obs call ----------------------------------------------

def _obs_target(call: ast.Call, bare_names: Set[str]) -> Optional[str]:
    """A dotted rendering of the callee when it goes through obs, else None."""
    fn = call.func
    if not isinstance(fn, ast.Attribute):
        return None
    parts: List[str] = [fn.attr]
    cur = fn.value
    while isinstance(cur, ast.Attribute):
        parts.append(cur.attr)
        cur = cur.value
    if isinstance(cur, ast.Name):
        parts.append(cur.id)
    parts.reverse()
    base = parts[:-1]  # everything but the method name
    if "obs" in base or (parts and parts[0] in bare_names):
        return ".".join(parts) + "()"
    return None


def _obs_aliases(scope: ast.AST) -> Set[str]:
    """Local names assigned from an expression that dereferences ``.obs``."""
    names: Set[str] = set()
    for node in ast.walk(scope):
        if isinstance(node, ast.Assign) and _mentions_obs(node.value, set()):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    names.add(tgt.id)
    return names


def _has_obs_param(scope) -> bool:
    args = scope.args
    every = (
        list(args.posonlyargs) + list(args.args) + list(args.kwonlyargs)
    )
    return any(a.arg == "obs" for a in every)


def _constructs_obs(scope: ast.AST) -> bool:
    for node in ast.walk(scope):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            fn = node.value.func
            name = fn.id if isinstance(fn, ast.Name) else (
                fn.attr if isinstance(fn, ast.Attribute) else None
            )
            if name == "Observability":
                return True
    return False


def _mentions_obs(expr: ast.AST, bare_names: Set[str]) -> bool:
    for node in ast.walk(expr):
        if isinstance(node, ast.Attribute) and node.attr == "obs":
            return True
        if isinstance(node, ast.Name) and (
            node.id == "obs" or node.id in bare_names
        ):
            return True
    return False


# -- guard detection ----------------------------------------------------------

def _is_guarded(
    mod: SourceModule, scope, node: ast.Call, bare_names: Set[str]
) -> bool:
    child: ast.AST = node
    for anc in mod.ancestors(node):
        if anc is scope:
            break
        if isinstance(anc, ast.If):
            kind = _none_check(anc.test, bare_names)
            in_body = any(_contains(s, child) for s in anc.body)
            if kind == "not-none" and in_body:
                return True
            if kind == "none" and not in_body:
                return True
        elif isinstance(anc, ast.IfExp):
            kind = _none_check(anc.test, bare_names)
            if kind == "not-none" and _contains(anc.body, node):
                return True
            if kind == "none" and _contains(anc.orelse, node):
                return True
        elif isinstance(anc, ast.BoolOp) and isinstance(anc.op, ast.And):
            idx = next(
                (i for i, v in enumerate(anc.values) if _contains(v, node)), None
            )
            if idx is not None:
                for earlier in anc.values[:idx]:
                    if _none_check(earlier, bare_names) == "not-none":
                        return True
        child = anc
    return _early_return_guard(scope, node, bare_names)


def _none_check(test: ast.AST, bare_names: Set[str]) -> Optional[str]:
    """'not-none' / 'none' when ``test`` none-checks an obs expression."""
    for sub in ast.walk(test):
        if not isinstance(sub, ast.Compare) or len(sub.ops) != 1:
            continue
        lhs, rhs = sub.left, sub.comparators[0]
        operand = lhs if not _is_none(lhs) else rhs
        if not (_is_none(lhs) or _is_none(rhs)):
            continue
        if not _mentions_obs(operand, bare_names):
            continue
        if isinstance(sub.ops[0], ast.IsNot):
            return "not-none"
        if isinstance(sub.ops[0], ast.Is):
            return "none"
    return None


def _is_none(expr: ast.AST) -> bool:
    return isinstance(expr, ast.Constant) and expr.value is None


def _contains(tree: ast.AST, target: ast.AST) -> bool:
    return tree is target or any(n is target for n in ast.walk(tree))


def _early_return_guard(scope, node: ast.Call, bare_names: Set[str]) -> bool:
    for stmt in scope.body:
        if getattr(stmt, "lineno", 1 << 30) >= node.lineno:
            break
        if not isinstance(stmt, ast.If):
            continue
        if _none_check(stmt.test, bare_names) != "none":
            continue
        if any(
            isinstance(s, (ast.Return, ast.Raise, ast.Continue)) for s in stmt.body
        ):
            return True
    return False
