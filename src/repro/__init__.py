"""NBL reproduction package.

Version shim: jax >= 0.5 defaults to the partitionable threefry PRNG,
making random values invariant to how the generating computation is
sharded (sharded init == single-device init). Older jax defaults it off —
turn it on so the distributed parity tests (and sharded init generally)
are bit-stable across meshes.
"""
import jax

try:
    jax.config.update("jax_threefry_partitionable", True)
except AttributeError:  # flag removed once it became the only behavior
    pass
