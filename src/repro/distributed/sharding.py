"""Per-tensor PartitionSpec rules (DP/TP/EP/FSDP + pod axis).

Logical placement by leaf name (resolved against the ambient mesh, with
divisibility guards — e.g. gemma2's 8 KV heads silently replicate over a
16-way model axis instead of erroring):

  embed (V,d)            ("model", "dp")      vocab-TP + FSDP
  head (d,V)             ("dp", "model")
  wq/wk/wv (d,H·hd)      ("dp", "model")      head-TP, FSDP on d
  wo (H·hd, d)           ("model", "dp")      reduce-scatter pattern
  mlp w_gate/up (d,ff)   ("dp", "model")
  mlp w_down (ff,d)      ("model", "dp")
  moe experts (E,d,ff)   ("model", "dp", -)   EP on expert dim + FSDP
  moe w_down (E,ff,d)    ("model", -, "dp")
  mamba in_proj (d,ch)   ("dp", "model")      channel-TP
  mamba out_proj (di,d)  ("model", "dp")
  nbl w (d,d)            ("dp", "model")      the replacement GEMM is TP'd
  1-D / scalars          replicated

Stacked (scanned) block params carry a leading layer dim that stays
unsharded (scan slices it every step). "dp" means ("pod","data") — weight
sharding over the DP axes is FSDP/ZeRO-3: XLA inserts per-layer all-gathers
inside the scan, overlapping them with compute.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.distributed.api import shaped_spec

# name -> logical axes for the TRAILING dims (None padded on the left)
_RULES: dict[str, tuple] = {
    "embed": ("model", "dp"),
    "head": ("dp", "model"),
    "wq": ("dp", "model"),
    "wk": ("dp", "model"),
    "wv": ("dp", "model"),
    "wo": ("model", "dp"),
    "w_up": ("dp", "model"),
    "w_down": ("model", "dp"),
    "in_proj": ("dp", "model"),
    "out_proj": ("model", "dp"),
    "conv_w": (None, "model"),
    "conv_b": ("model",),
    "router": (None, None),
    "w": ("dp", "model"),          # NBL replacement linear
    "b": (None,),
    "norm_w": (None,),
}
# expert-stacked MoE weights (ndim >= 3 after stripping the layer dim)
_MOE_RULES: dict[str, tuple] = {
    "w_gate": ("model", "dp", None),
    "w_up": ("model", "dp", None),
    "w_down": ("model", None, "dp"),
}
_DENSE_W_GATE = ("dp", "model")


def _leaf_logical(path: tuple, leaf) -> tuple:
    names = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
    name = names[-1] if names else ""
    stacked = "scanned" in names
    ndim = leaf.ndim
    core = ndim - (1 if stacked else 0)

    if name == "w_gate":
        logical = _MOE_RULES["w_gate"] if core == 3 else _DENSE_W_GATE
    elif name in _MOE_RULES and core == 3:
        logical = _MOE_RULES[name]
    elif name in _RULES:
        logical = _RULES[name]
    else:
        logical = ()
    logical = tuple(logical[-core:]) if core else ()
    pad = ndim - len(logical)
    return (None,) * pad + logical


def logical_axes(tree: Any) -> Any:
    """Pytree of logical-axis tuples mirroring ``tree``."""
    paths, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return jax.tree_util.tree_unflatten(
        treedef, [_leaf_logical(p, l) for p, l in paths])


# FSDP (dp-axis weight sharding) is only worth its all-gathers/reduces when
# the tensor-parallel shard alone is big; below this per-shard size the
# leaf stays replicated across DP (saves the gradient/activation reduction
# traffic that dominated the MoE train cells — EXPERIMENTS.md §Perf H2).
FSDP_MIN_SHARD_BYTES = 0   # 0 = always FSDP; raising it was REFUTED for
# MoE (XLA replicates the dispatch compute when experts replicate — 2.3×
# FLOPs, 2.3× collective bytes; see EXPERIMENTS.md §Perf H2 iteration 1).


def param_specs(tree: Any,
                fsdp_min_bytes: int = FSDP_MIN_SHARD_BYTES) -> Any:
    """Pytree of PartitionSpec (resolved + divisibility-guarded) for params
    (or optimizer state / EF error mirroring params). Call under the mesh."""
    from repro.distributed.api import axis_size, dp_axes
    paths, treedef = jax.tree_util.tree_flatten_with_path(tree)
    dp = set(dp_axes())

    def one(p, leaf):
        logical = _leaf_logical(p, leaf)
        spec = shaped_spec(leaf.shape, *logical)
        # estimate per-shard bytes under the non-dp axes only
        denom = 1
        for s in spec:
            for a in (s if isinstance(s, tuple) else (s,) if s else ()):
                if a not in dp:
                    denom *= axis_size(a)
        n = leaf.dtype.itemsize
        for d in leaf.shape:
            n *= d
        if n // max(denom, 1) < fsdp_min_bytes:
            # drop dp axes -> replicated across DP (no FSDP gathers)
            stripped = []
            for s in spec:
                if isinstance(s, tuple):
                    rest = tuple(a for a in s if a not in dp)
                    stripped.append(rest if rest else None)
                else:
                    stripped.append(None if s in dp else s)
            spec = P(*stripped)
        return spec

    return jax.tree_util.tree_unflatten(
        treedef, [one(p, l) for p, l in paths])


def batch_specs(tree: Any) -> Any:
    """Data batches: leading dim over ("pod","data")."""
    def one(leaf):
        return shaped_spec(leaf.shape,
                           *(("dp",) + (None,) * (leaf.ndim - 1)))
    return jax.tree.map(one, tree)


def cache_specs(tree: Any) -> Any:
    """KV/state caches. Layout (stack, batch, heads, time, hd) or
    (stack, batch, ...) for SSM state. Batch → dp; heads → model when
    divisible, else the time/state dim → model (sequence-parallel decode).

    Paged pools (leaf names k_pages/v_pages: (L, n_pages, KV, page_size,
    hd), models/paging.py) shard KV heads over "model" (head_dim fallback,
    like the slot layout) and keep the PAGE dim replicated: the page table
    indexes a global id space, and a dp-sharded pool would turn every
    table-directed gather into a cross-replica collective."""
    def one_paged(leaf):
        s = shaped_spec(leaf.shape, None, None, "model", None, None)
        if s[2] is None:
            s = shaped_spec(leaf.shape, None, None, None, None, "model")
        return s

    def one(leaf):
        if leaf.ndim == 5:        # (L, B, KV, T, hd)
            s = shaped_spec(leaf.shape, None, "dp", "model", None, None)
            if s[2] is None:      # KV heads don't divide -> try head_dim
                # (decode scores psum over the contracted hd is tiny; a
                # time-sharded ring turns every slot write into a
                # full-cache select — EXPERIMENTS.md §Perf H3)
                s = shaped_spec(leaf.shape, None, "dp", None, None, "model")
            if s[4] is None and s[2] is None:   # last resort: time
                s = shaped_spec(leaf.shape, None, "dp", None, "model", None)
            return s
        if leaf.ndim == 4:        # (L, B, H, P)/(L, B, k, ch) mamba-ish
            return shaped_spec(leaf.shape, None, "dp", "model", None)
        if leaf.ndim == 2:        # (L, W) kpos (monolithic cache)
            return shaped_spec(leaf.shape, None, None)
        # fallback covers (L, B, W) per-slot kpos (slot cache) and any
        # other batch-led state: slot/batch dim -> dp, rest replicated
        return shaped_spec(leaf.shape,
                           *((None, "dp") + (None,) * (leaf.ndim - 2)))

    paths, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for p, leaf in paths:
        name = str(getattr(p[-1], "key", ""))
        out.append(one_paged(leaf) if name in ("k_pages", "v_pages")
                   else one(leaf))
    return jax.tree_util.tree_unflatten(treedef, out)


def zero1_specs(shapes_tree: Any, pspecs_tree: Any) -> Any:
    """ZeRO-1 optimizer-moment specs: the weight's own spec plus the DP
    axes on the first still-replicated, divisible dimension. Each DP
    replica then holds 1/|dp| of the Adam state; XLA reshards grads into
    the moment layout and all-gathers only the param delta."""
    from repro.distributed.api import dp_axes, axis_size
    dp = dp_axes()
    dp_n = 1
    for a in dp:
        dp_n *= axis_size(a)

    def one(leaf, spec):
        if not dp or leaf.ndim == 0:
            return spec
        parts = list(spec) + [None] * (leaf.ndim - len(spec))
        used = set()
        for s in parts:
            for a in (s if isinstance(s, tuple) else (s,) if s else ()):
                used.add(a)
        free = tuple(a for a in dp if a not in used)
        if not free:
            return spec
        free_n = 1
        for a in free:
            free_n *= axis_size(a)
        for d in range(leaf.ndim):
            if parts[d] is None and leaf.shape[d] % free_n == 0:
                parts[d] = free if len(free) > 1 else free[0]
                break
        return P(*parts)

    return jax.tree.map(one, shapes_tree, pspecs_tree,
                        is_leaf=lambda x: isinstance(x, P))


def named(tree_specs: Any, mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))


def bytes_per_device(shapes_tree: Any, specs_tree: Any, mesh) -> int:
    """Analytic bytes/device given eval_shape + specs (pre-compile check)."""
    axis = dict(zip(mesh.axis_names, np.asarray(mesh.devices).shape))
    total = 0
    for leaf, spec in zip(jax.tree.leaves(shapes_tree),
                          jax.tree.leaves(specs_tree,
                                          is_leaf=lambda x: isinstance(x, P))):
        n = int(np.prod(leaf.shape)) if leaf.shape else 1
        denom = 1
        for s in spec:
            for a in (s if isinstance(s, tuple) else (s,) if s else ()):
                denom *= axis[a]
        total += n * leaf.dtype.itemsize // max(denom, 1)
    return total
