from repro.distributed.api import (  # noqa: F401
    ambient_mesh, constrain, dp_axes, has_axis, mesh_axes, use_mesh, P,
)
