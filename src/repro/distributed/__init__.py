from repro.distributed.api import (  # noqa: F401
    constrain, dp_axes, has_axis, mesh_axes, P,
)
