"""GPipe pipeline parallelism via shard_map + collective_permute.

The production dry-run mesh has no "pipe" axis (DP×TP covers 512 chips for
every assigned arch), but beyond 2 pods the documented scaling path splits
the layer stack across pods: stage s holds layers [s·L/S, (s+1)·L/S) and
microbatches rotate stage-to-stage with ppermute. This module implements
that schedule in a mesh-shape-agnostic way; tests run it on an 8-device
host-platform mesh and check exactness against the unsharded stack.

Schedule (GPipe, no interleaving): T = n_micro + n_stages − 1 ticks. At
tick t, stage s computes microbatch (t − s) if 0 ≤ t − s < n_micro; the
boundary activations move s → s+1 between ticks. Bubble fraction =
(S − 1)/T, amortized by n_micro ≫ S; with the default schedule the
ppermute overlaps the next microbatch's compute (XLA async collective).
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from repro.distributed.api import shard_map
from jax.sharding import PartitionSpec as P


def pipeline_apply(block_fn: Callable, stacked_params, x, *, mesh,
                   axis: str = "pipe", n_micro: int | None = None):
    """Run ``x`` through L stacked layers split over mesh axis ``axis``.

    block_fn(params_slice, x_micro) -> x_micro — one layer.
    stacked_params: leaves with leading dim L (L % n_stages == 0).
    x: (B, ...) global batch; B % n_micro == 0.
    """
    n_stages = mesh.shape[axis]
    n_micro = n_micro or n_stages
    b = x.shape[0]
    assert b % n_micro == 0, (b, n_micro)
    mb = b // n_micro

    def stage_fn(params_local, x_all):
        # params_local: (L/S, ...) this stage's layers; x_all: full batch
        # (replicated input; only stage 0's reads matter).
        sid = jax.lax.axis_index(axis)
        micro = x_all.reshape(n_micro, mb, *x_all.shape[1:])

        def run_stage(xm):
            def body(carry, p):
                return block_fn(p, carry), None
            out, _ = jax.lax.scan(body, xm, params_local)
            return out

        t_total = n_micro + n_stages - 1
        buf = jnp.zeros((mb, *x_all.shape[1:]), x_all.dtype)
        outs = jnp.zeros_like(micro)

        def tick(t, state):
            buf, outs = state
            mid = t - sid                     # microbatch index at this stage
            active = (mid >= 0) & (mid < n_micro)
            src = jax.lax.dynamic_index_in_dim(
                micro, jnp.clip(t, 0, n_micro - 1), keepdims=False)
            x_in = jnp.where(sid == 0, src, buf)
            y = run_stage(x_in)
            y = jnp.where(active, y, buf)
            # stage S-1's finished microbatch lands in outs[mid]
            out_mid = jnp.clip(mid, 0, n_micro - 1)
            is_last = sid == n_stages - 1
            upd = jnp.where(active & is_last, y,
                            jax.lax.dynamic_index_in_dim(outs, out_mid,
                                                         keepdims=False))
            outs = jax.lax.dynamic_update_index_in_dim(outs, upd, out_mid, 0)
            # rotate boundary activations to the next stage
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            buf = jax.lax.ppermute(y, axis, perm)
            return (buf, outs)

        buf, outs = jax.lax.fori_loop(0, t_total, tick, (buf, outs))
        # only the last stage holds real outputs; broadcast to all stages
        outs = jax.lax.psum(
            jnp.where(sid == n_stages - 1, outs, jnp.zeros_like(outs)), axis)
        return outs.reshape(b, *x_all.shape[1:])

    pp = P(axis, *([None] * (jax.tree.leaves(stacked_params)[0].ndim - 1)))
    pspecs = jax.tree.map(lambda a: P(axis, *([None] * (a.ndim - 1))),
                          stacked_params)
    del pp
    return shard_map(
        stage_fn, mesh=mesh, in_specs=(pspecs, P()), out_specs=P(),
        check_vma=False)(stacked_params, x)
