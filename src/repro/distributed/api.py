"""Mesh-aware sharding-constraint helpers.

All model code calls ``constrain(x, "axis0", "axis1", ...)`` with *logical*
axis names; the helper resolves them against the ambient mesh (set by
``with mesh:`` / ``jax.set_mesh`` around the jit) and silently drops axes the
mesh does not have. This lets the same model run un-meshed on one CPU device
(smoke tests), on the (data, model) single-pod mesh, and on the
(pod, data, model) multi-pod mesh without code changes.

Logical axis conventions:
  "dp"    -> sharded over ("pod", "data") (whichever exist)
  "model" -> sharded over "model" (tensor/expert parallel)
  None    -> replicated
"""
from __future__ import annotations

import contextlib
from typing import Optional, Sequence, Union

import jax
from jax.sharding import PartitionSpec as P  # noqa: F401

AxisName = Union[None, str, tuple]

_DP = ("pod", "data")


def ambient_mesh():
    """The mesh the surrounding code entered, or None. jax >= 0.5 tracks an
    abstract mesh via ``jax.set_mesh``; older jax tracks the physical mesh
    entered with ``with mesh:`` — ``use_mesh`` papers over the difference.
    Checks both trackers so intermediate jax versions (one API present,
    the other not) still resolve whatever the caller entered."""
    get = getattr(jax.sharding, "get_abstract_mesh", None)
    if get is not None:
        m = get()
        if m is not None and getattr(m, "axis_names", ()):
            return m
    try:
        from jax._src.mesh import thread_resources
    except ImportError:  # pragma: no cover - future jax dropping the module
        return None
    m = thread_resources.env.physical_mesh
    return None if m.empty else m


def use_mesh(mesh):
    """Context manager making ``mesh`` ambient (version-portable
    ``jax.set_mesh``)."""
    set_mesh = (getattr(jax, "set_mesh", None)
                or getattr(jax.sharding, "use_mesh", None))
    if set_mesh is not None:
        return set_mesh(mesh)
    # oldest fallback: Mesh is itself a context manager
    return contextlib.nullcontext() if mesh is None else mesh


def shard_map(f, *, mesh=None, in_specs, out_specs, axis_names=None,
              check_vma: bool = True):
    """Version-portable ``jax.shard_map``. jax >= 0.5 takes keyword mesh /
    ``axis_names`` (manual axes) / ``check_vma``; older jax exposes
    ``jax.experimental.shard_map.shard_map(f, mesh, ..., check_rep, auto)``
    — ``auto`` being the complement of ``axis_names``."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        kw = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check_vma)
        if axis_names is not None:
            kw["axis_names"] = axis_names
        return sm(f, **kw)
    from jax.experimental.shard_map import shard_map as esm
    m = mesh if mesh is not None else ambient_mesh()
    if m is None:
        raise ValueError("shard_map needs a mesh (pass mesh= or enter one "
                         "via use_mesh)")
    auto = (frozenset(m.axis_names) - frozenset(axis_names)
            if axis_names is not None else frozenset())
    return esm(f, m, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma and not auto, auto=auto)


def jit_shardings(tree, mesh=None):
    """Make a PartitionSpec pytree acceptable to ``jax.jit``'s
    in/out_shardings. jax >= 0.5 accepts raw specs (resolved against the
    ambient mesh); older jax requires NamedSharding — resolve against
    ``mesh`` or the ambient one. None leaves (= infer) pass through, as
    does everything when the ambient mesh is abstract (new-jax tracker:
    raw specs are accepted there)."""
    if hasattr(jax, "set_mesh"):
        return tree
    m = mesh if mesh is not None else ambient_mesh()
    if m is None or not isinstance(m, jax.sharding.Mesh):
        return tree
    from jax.sharding import NamedSharding
    return jax.tree.map(
        lambda s: NamedSharding(m, s) if isinstance(s, P) else s,
        tree, is_leaf=lambda x: isinstance(x, P))


def _axis_sizes(m) -> dict:
    sizes = getattr(m, "axis_sizes", None)
    if sizes is not None:
        return dict(zip(m.axis_names, sizes))
    return dict(m.shape)


def mesh_axes() -> tuple[str, ...]:
    m = ambient_mesh()
    return tuple(m.axis_names) if m is not None else ()


def has_axis(name: str) -> bool:
    return name in mesh_axes()


def dp_axes() -> tuple[str, ...]:
    """Mesh axes that play the data-parallel role."""
    return tuple(a for a in _DP if has_axis(a))


def _resolve(axis: AxisName, axes: tuple[str, ...]):
    if axis is None:
        return None
    if axis == "dp":
        got = tuple(a for a in _DP if a in axes)
        return got if got else None
    if isinstance(axis, tuple):
        got = tuple(a for sub in axis for a in (_resolve(sub, axes),)
                    if a is not None)
        flat: list[str] = []
        for a in got:
            flat.extend(a if isinstance(a, tuple) else (a,))
        return tuple(flat) if flat else None
    return axis if axis in axes else None


def axis_size(name: str) -> int:
    m = ambient_mesh()
    if m is None or name not in m.axis_names:
        return 1
    return _axis_sizes(m)[name]


def _prod_size(resolved) -> int:
    if resolved is None:
        return 1
    if isinstance(resolved, tuple):
        out = 1
        for a in resolved:
            out *= axis_size(a)
        return out
    return axis_size(resolved)


def spec(*logical: AxisName) -> P:
    axes = mesh_axes()
    return P(*[_resolve(a, axes) for a in logical])


def shaped_spec(shape: Sequence[int], *logical: AxisName) -> P:
    """Like spec() but drops any axis whose mesh-size does not divide the
    corresponding dimension (e.g. 8 KV heads on a 16-way model axis)."""
    axes = mesh_axes()
    out = []
    for dim, a in zip(shape, logical):
        r = _resolve(a, axes)
        if r is not None and dim % _prod_size(r) != 0:
            # try progressively shorter prefixes of a tuple spec
            if isinstance(r, tuple):
                while r and dim % _prod_size(r) != 0:
                    r = r[:-1]
                r = r if r else None
            else:
                r = None
        out.append(r)
    return P(*out)


def constrain(x: jax.Array, *logical: AxisName) -> jax.Array:
    """with_sharding_constraint with logical axes; no-op without a mesh.
    Axes that do not divide the dimension are dropped (replicated)."""
    axes = mesh_axes()
    if not axes:
        return x
    s = shaped_spec(x.shape, *logical)
    return jax.lax.with_sharding_constraint(x, s)
