"""Mesh-aware sharding-constraint helpers.

All model code calls ``constrain(x, "axis0", "axis1", ...)`` with *logical*
axis names; the helper resolves them against the ambient mesh (set by
``with mesh:`` / ``jax.set_mesh`` around the jit) and silently drops axes the
mesh does not have. This lets the same model run un-meshed on one CPU device
(smoke tests), on the (data, model) single-pod mesh, and on the
(pod, data, model) multi-pod mesh without code changes.

Logical axis conventions:
  "dp"    -> sharded over ("pod", "data") (whichever exist)
  "model" -> sharded over "model" (tensor/expert parallel)
  None    -> replicated
"""
from __future__ import annotations

from typing import Optional, Sequence, Union

import jax
from jax.sharding import PartitionSpec as P  # noqa: F401

AxisName = Union[None, str, tuple]

_DP = ("pod", "data")


def mesh_axes() -> tuple[str, ...]:
    m = jax.sharding.get_abstract_mesh()
    return tuple(m.axis_names) if m is not None else ()


def has_axis(name: str) -> bool:
    return name in mesh_axes()


def dp_axes() -> tuple[str, ...]:
    """Mesh axes that play the data-parallel role."""
    return tuple(a for a in _DP if has_axis(a))


def _resolve(axis: AxisName, axes: tuple[str, ...]):
    if axis is None:
        return None
    if axis == "dp":
        got = tuple(a for a in _DP if a in axes)
        return got if got else None
    if isinstance(axis, tuple):
        got = tuple(a for sub in axis for a in (_resolve(sub, axes),)
                    if a is not None)
        flat: list[str] = []
        for a in got:
            flat.extend(a if isinstance(a, tuple) else (a,))
        return tuple(flat) if flat else None
    return axis if axis in axes else None


def axis_size(name: str) -> int:
    m = jax.sharding.get_abstract_mesh()
    if m is None or name not in m.axis_names:
        return 1
    return dict(zip(m.axis_names, m.axis_sizes))[name]


def _prod_size(resolved) -> int:
    if resolved is None:
        return 1
    if isinstance(resolved, tuple):
        out = 1
        for a in resolved:
            out *= axis_size(a)
        return out
    return axis_size(resolved)


def spec(*logical: AxisName) -> P:
    axes = mesh_axes()
    return P(*[_resolve(a, axes) for a in logical])


def shaped_spec(shape: Sequence[int], *logical: AxisName) -> P:
    """Like spec() but drops any axis whose mesh-size does not divide the
    corresponding dimension (e.g. 8 KV heads on a 16-way model axis)."""
    axes = mesh_axes()
    out = []
    for dim, a in zip(shape, logical):
        r = _resolve(a, axes)
        if r is not None and dim % _prod_size(r) != 0:
            # try progressively shorter prefixes of a tuple spec
            if isinstance(r, tuple):
                while r and dim % _prod_size(r) != 0:
                    r = r[:-1]
                r = r if r else None
            else:
                r = None
        out.append(r)
    return P(*out)


def constrain(x: jax.Array, *logical: AxisName) -> jax.Array:
    """with_sharding_constraint with logical axes; no-op without a mesh.
    Axes that do not divide the dimension are dropped (replicated)."""
    axes = mesh_axes()
    if not axes:
        return x
    s = shaped_spec(x.shape, *logical)
    return jax.lax.with_sharding_constraint(x, s)
