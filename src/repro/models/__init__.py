from repro.models.transformer import (  # noqa: F401
    apply, count_params, init_params, loss_fn, prefill, decode_step,
    fused_step,
)
from repro.models.kv_cache import init_cache  # noqa: F401
