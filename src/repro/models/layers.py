"""Primitive layers: RMSNorm, RoPE, gated MLPs, embeddings."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed import constrain


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dt)


# ---------------------------------------------------------------- RoPE -----

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, head_dim); positions: broadcastable to (..., S)."""
    freqs = rope_freqs(x.shape[-1], theta)                     # (half,)
    ang = positions[..., None].astype(jnp.float32) * freqs      # (..., S, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- MLP ------

def init_mlp(key: jax.Array, d: int, ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in, s_out = d ** -0.5, ff ** -0.5
    return {
        "w_gate": (jax.random.normal(k1, (d, ff)) * s_in).astype(dtype),
        "w_up":   (jax.random.normal(k2, (d, ff)) * s_in).astype(dtype),
        "w_down": (jax.random.normal(k3, (ff, d)) * s_out).astype(dtype),
    }


def mlp(p: dict, x: jax.Array, act: str) -> jax.Array:
    """Gated MLP: silu (Llama/SwiGLU) or geglu (Gemma)."""
    g = x @ p["w_gate"]
    u = x @ p["w_up"]
    g = jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g, approximate=True)
    h = constrain(g * u, "dp", None, "model")
    return h @ p["w_down"]


# ---------------------------------------------------------------- misc -----

def softcap(logits: jax.Array, cap) -> jax.Array:
    if cap is None:
        return logits
    return cap * jnp.tanh(logits / cap)


def embed_tokens(table: jax.Array, tokens: jax.Array, dtype) -> jax.Array:
    x = jnp.take(table, tokens, axis=0).astype(dtype)
    return constrain(x, "dp", None, None)
