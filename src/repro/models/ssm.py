"""Mamba2 (SSD, state-space duality) block: chunked training/prefill scan and
O(1)-state decode step.

Training/prefill uses the chunked SSD algorithm (Dao & Gu 2024, §6): within a
chunk the recurrence is computed in quadratic "attention form" (MXU-friendly
(c x c) matmuls), across chunks a short recurrence carries the (h, p, n)
state. Peak memory O(n_chunks * h * p * n) instead of O(seq * h * p * n).

Decode keeps per-layer state (B, h, p, n) plus a (k-1)-deep conv ring.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed import constrain
from repro.models.layers import rmsnorm


def _dims(cfg):
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    h = s.n_heads(cfg.d_model)
    return s, di, h, s.head_dim, s.d_state


def init_mamba(key: jax.Array, cfg) -> dict:
    s, di, h, p_, n = _dims(cfg)
    d = cfg.d_model
    conv_ch = di + 2 * n
    k1, k2, k3, k4 = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.param_dtype)
    # in_proj -> [z(di), x(di), B(n), C(n), dt(h)]
    return {
        "in_proj": (jax.random.normal(k1, (d, 2 * di + 2 * n + h))
                    * d ** -0.5).astype(dt),
        "conv_w": (jax.random.normal(k2, (s.conv_kernel, conv_ch))
                   * 0.1).astype(dt),
        "conv_b": jnp.zeros((conv_ch,), dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.full((h,), -2.0, jnp.float32),  # softplus^-1(~0.12)
        "norm_w": jnp.zeros((di,), dt),
        "out_proj": (jax.random.normal(k3, (di, d)) * di ** -0.5).astype(dt),
    }


def _segsum(a):
    """a: (..., c). Returns (..., c, c) with L[i, j] = sum_{j<k<=i} a_k for
    i >= j, -inf otherwise (lower-triangular cumulative decay)."""
    c = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((c, c), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def _ssd_chunked(xh, a, B, C, chunk, work_dtype=jnp.float32):
    """Chunked SSD scan.

    xh: (b, l, h, p)   dt-weighted inputs (dt * x)
    a:  (b, l, h)      per-step log-decay (dt * A, negative)
    B, C: (b, l, n)    shared across heads (single group)
    Returns y: (b, l, h, p), final_state: (b, h, p, n).

    ``work_dtype`` controls the *materialized* intermediates (the decay
    tensor L (b,nc,h,c,c), the dispatch products, the per-chunk states).
    bf16 halves the dominant HBM traffic of the layer; log-decay sums,
    einsum accumulation and the inter-chunk state stay float32 (decays are
    in [0,1], so bf16's 8 mantissa bits cost ~3 decimal digits on values
    whose gradients are already noise-dominated — validated vs the f32
    path in tests).
    """
    b, l, h, p = xh.shape
    n = B.shape[-1]
    c = min(chunk, l)
    assert l % c == 0, (l, c)
    nc = l // c
    wd = work_dtype

    xc = xh.reshape(b, nc, c, h, p).astype(wd)
    ac = a.reshape(b, nc, c, h).transpose(0, 1, 3, 2)      # (b,nc,h,c) f32
    Bc = B.reshape(b, nc, c, n).astype(wd)
    Cc = C.reshape(b, nc, c, n).astype(wd)

    L = jnp.exp(_segsum(ac)).astype(wd)                    # (b,nc,h,c,c)
    # intra-chunk (attention form): y_intra[i] = sum_j (C_i.B_j) L_ij x_j
    cb = jnp.einsum("bzin,bzjn->bzij", Cc, Bc,
                    preferred_element_type=wd)             # (b,nc,c,c)
    y_intra = jnp.einsum("bzij,bzhij,bzjhp->bzihp", cb, L, xc,
                         preferred_element_type=jnp.float32)

    # chunk states: S_z = sum_j exp(sum_{k>j} a_k) B_j (x) x_j
    a_cum = jnp.cumsum(ac, axis=-1)                        # (b,nc,h,c) f32
    a_tot = a_cum[..., -1]                                 # (b,nc,h)
    decay_state = jnp.exp(a_tot[..., None] - a_cum).astype(wd)
    S = jnp.einsum("bzhj,bzjn,bzjhp->bzhpn", decay_state, Bc, xc,
                   preferred_element_type=jnp.float32)

    # inter-chunk recurrence over nc (sequential scan, nc is small)
    def body(carry, xs):
        s_prev = carry
        s_z, atot_z = xs
        s_new = s_prev * jnp.exp(atot_z)[..., None, None] + s_z
        return s_new, s_prev.astype(wd)

    s0 = jnp.zeros((b, h, p, n), jnp.float32)
    S_t = S.transpose(1, 0, 2, 3, 4).astype(jnp.float32)
    atot_t = a_tot.transpose(1, 0, 2)
    final_state, s_prevs = jax.lax.scan(body, s0, (S_t, atot_t))
    s_prevs = s_prevs.transpose(1, 0, 2, 3, 4)             # (b,nc,h,p,n)

    # inter-chunk output: y_inter[i] = C_i . (exp(cumsum a) * S_prev)
    decay_out = jnp.exp(a_cum).astype(wd)                  # (b,nc,h,c)
    y_inter = jnp.einsum("bzin,bzhpn,bzhi->bzihp",
                         Cc, s_prevs, decay_out,
                         preferred_element_type=jnp.float32)
    y = (y_intra + y_inter).reshape(b, l, h, p)
    return y, final_state


def _in_proj_split(cfg, p, x):
    s, di, h, p_, n = _dims(cfg)
    z, xr, B, C, dt = jnp.split(
        x @ p["in_proj"], [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1)
    return z, xr, B, C, dt


def mamba_block(cfg, p: dict, x: jax.Array) -> tuple[jax.Array, tuple]:
    """Full-sequence Mamba2. x: (B, S, d) -> (y, (ssm_state, conv_state))."""
    s, di, h, hp, n = _dims(cfg)
    b, l, d = x.shape
    z, xr, B, C, dt = _in_proj_split(cfg, p, x)

    conv_in = jnp.concatenate([xr, B, C], axis=-1)         # (b,l,conv_ch)
    k = s.conv_kernel
    pad = jnp.pad(conv_in, ((0, 0), (k - 1, 0), (0, 0)))
    conv = sum(pad[:, i:i + l] * p["conv_w"][i] for i in range(k))
    conv = jax.nn.silu(conv + p["conv_b"])
    xr, B, C = jnp.split(conv, [di, di + n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (b,l,h)
    A = -jnp.exp(p["A_log"])                                      # (h,)
    xh = xr.reshape(b, l, h, hp)
    xdt = xh * dt[..., None].astype(xh.dtype)
    a = dt * A                                                    # (b,l,h)

    # pad the token dim to a chunk multiple: zero input + zero log-decay
    # makes padded steps exact identities on the state.
    padn = (-l) % min(s.chunk, max(l, 1))
    xdt32, a32 = xdt.astype(jnp.float32), a.astype(jnp.float32)
    B32, C32 = B.astype(jnp.float32), C.astype(jnp.float32)
    if padn:
        zpad = ((0, 0), (0, padn), (0, 0), (0, 0))
        xdt32 = jnp.pad(xdt32, zpad)
        a32 = jnp.pad(a32, ((0, 0), (0, padn), (0, 0)))
        B32 = jnp.pad(B32, ((0, 0), (0, padn), (0, 0)))
        C32 = jnp.pad(C32, ((0, 0), (0, padn), (0, 0)))
    y, state = _ssd_chunked(xdt32, a32, B32, C32, s.chunk,
                            work_dtype=jnp.dtype(cfg.compute_dtype))
    y = y[:, :l]
    y = y + xh.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(b, l, di).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm(y, p["norm_w"], cfg.norm_eps)
    y = constrain(y, "dp", None, "model")
    conv_state = pad[:, l:l + k - 1]                       # last k-1 inputs
    return y @ p["out_proj"], (state, conv_state.astype(x.dtype))


def mamba_decode(cfg, p: dict, x: jax.Array, cache: dict
                 ) -> tuple[jax.Array, dict]:
    """Single-token decode. x: (B, 1, d); cache: {ssm: (B,h,p,n) f32,
    conv: (B, k-1, conv_ch)}."""
    s, di, h, hp, n = _dims(cfg)
    b = x.shape[0]
    k = s.conv_kernel
    z, xr, B, C, dt = _in_proj_split(cfg, p, x)
    conv_in = jnp.concatenate([xr, B, C], axis=-1)[:, 0]   # (b, conv_ch)

    hist = jnp.concatenate([cache["conv"], conv_in[:, None]], axis=1)  # (b,k,ch)
    conv = jnp.einsum("bkc,kc->bc", hist, p["conv_w"]) + p["conv_b"]
    conv = jax.nn.silu(conv)
    xr1, B1, C1 = jnp.split(conv, [di, di + n], axis=-1)

    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (b,h)
    A = -jnp.exp(p["A_log"])
    da = jnp.exp(dt1 * A)                                  # (b,h)
    xh = xr1.reshape(b, h, hp).astype(jnp.float32)
    upd = jnp.einsum("bhp,bn->bhpn", xh * dt1[..., None], B1.astype(jnp.float32))
    state = cache["ssm"] * da[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", state, C1.astype(jnp.float32))
    y = y + xh * p["D"][None, :, None]
    y = y.reshape(b, 1, di).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm(y, p["norm_w"], cfg.norm_eps)
    out = y @ p["out_proj"]
    return out, {"ssm": state, "conv": hist[:, 1:].astype(cache["conv"].dtype)}
