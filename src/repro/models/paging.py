"""Paged KV-cache subsystem: page pools, refcounted allocator, prefix index.

Block-paged KV management (the PagedAttention design) replaces the serving
engine's one-ring-per-slot reservation with a *pool* of fixed-size pages per
attention layer. A request REFERENCES only the pages that cover the tokens
it has actually produced, so short requests stop stranding the HBM the
scheduler budgeted for ``max_len`` — and the freed memory converts into
admitted traffic. The saving composes multiplicatively with NBL: linearized
layers carry NO pool at all (paper §4.2), so m of K layers linearized
shrinks the per-request page bill by m/K on top of the page-granular
allocation — and, under prefix sharing, the reduction applies to the shared
pool too (shared pages exist only in caching attention layers).

Reference semantics (copy-on-write prefix sharing)
--------------------------------------------------
Pages are REFCOUNTED, not owned. ``PageAllocator.alloc`` hands out pages at
refcount 1; ``ref`` pins extra holders; ``unref`` (alias ``free``) drops
one reference and a page returns to the free list only at refcount 0. Both
``ref`` and ``unref`` are ATOMIC: the whole id list — including duplicate
ids within one call — is validated against current refcounts before any
mutation, so a rejected call leaves the allocator exactly as it found it.

Sharing is copy-on-write by construction rather than by copying: a shared
page is always a FULL prompt-prefix page, and every writer (suffix prefill,
decode) lands at positions at or beyond its slot's first divergent page, so
shared pages are never written after publication — a "write" to a shared
logical range is simply a fresh page for the writing slot. The last
(partial) page of a prompt is never shared.

``PrefixIndex`` is the host-side radix/trie over prompt-token page-chunks:
each full page of a previously-served prompt prefix maps its ``page_size``
tokens to the physical page that caches them. The index holds one
reference per mapped page, so published prefixes survive the publishing
request's retirement (the retiring slot only ``unref``s). On admission the
engine looks up the longest page-aligned cached prefix, ``ref``s the hit
pages, points the new slot's page-table row at them, and prefills only the
suffix. Under pool pressure, UNREFERENCED index entries (refcount 1 — held
by nothing but the index) are evicted leaf-first in LRU order BEFORE any
request is preempted; billing (launch/scheduler.nbl_page_budget) counts
pages referenced with shared pages billed once.

Layout
------
Every caching attention layer owns one pool pair, stacked over the group's
scan dim exactly like the slot cache:

    k_pages / v_pages : (L, n_pages, KV, page_size, hd)

Pages are POSITION-ALIGNED: logical page ``l`` of a request always holds
absolute positions [l*page_size, (l+1)*page_size). Validity is therefore
derivable from the request's current length — no per-token ``kpos`` array
exists in the paged layout. Sliding-window layers keep full-length pages and
mask in the kernel (they trade the ring's compaction for page sharing).

One page TABLE is shared by all layers (allocation is synchronized: a page
id is valid in every layer's pool simultaneously). It lives on the HOST as
an ``(n_slots, pages_per_seq)`` int32 array owned by the engine, entries -1
= unallocated, and is passed to the decode jit as a regular (tiny) argument
— appending a page mid-decode is a host-side table write, never a cache-tree
surgery.

Non-attention state (SSM, conv, cross-attn KV) is not pageable (it is O(1)
per slot, not O(seq)); those blocks keep the slot-indexed layout from
``kv_cache.init_slot_cache`` inside the same cache tree.

Unit of account: ``page_bytes(cfg, page_size)`` is the byte size of ONE page
in ONE layer — the scheduler's page budget (launch/scheduler.nbl_page_budget)
divides an HBM budget by (caching layers x page_bytes) to size the pool.

Scatter/gather safety: -1 table entries would *wrap* under numpy indexing
semantics, so every device-side consumer sanitizes ids first —
``sanitize_page_ids`` maps negatives to ``n_pages`` (out of bounds, dropped
by scatter mode="drop"); gathers clip to 0 and rely on the position mask.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.kv_cache import _block_cache

DEFAULT_PAGE_SIZE = 64


def pages_per_seq(max_len: int, page_size: int) -> int:
    return -(-max_len // page_size)


def pow2_ceil(n: int) -> int:
    """Smallest power of two >= n (1 for n <= 1): the bucketing unit for
    prompt lengths AND prefix tables — ONE definition so the engine's jit
    keys (launch/engine.py) and the dry-run input shapes (launch/specs.py)
    can never disagree about which widths actually compile."""
    return 1 << max(0, (int(n) - 1).bit_length())


def span_pages(start_tok: int, end_tok: int,
               page_size: int) -> tuple[int, int]:
    """Logical page range [start_pg, end_pg) covering the token span
    [start_tok, end_tok) — the chunk-granular allocation unit of the
    engine's chunked prefill. ``start_tok`` must be page-aligned: a chunk
    resumes only on a page boundary (its prefix table covers whole pages)."""
    assert start_tok % page_size == 0, (start_tok, page_size)
    assert end_tok > start_tok, (start_tok, end_tok)
    return start_tok // page_size, pages_per_seq(end_tok, page_size)


def n_caching_attn_layers(cfg: ModelConfig) -> int:
    """Attention invocations that carry a KV pool (shared blocks count once
    per invocation, like their caches; nbl/drop/mamba/cross contribute 0)."""
    return sum(1 for b in cfg.blocks() if b.kind == "attn")


def page_bytes(cfg: ModelConfig, page_size: int) -> int:
    """Bytes of ONE page in ONE attention layer (K + V)."""
    itemsize = jnp.dtype(cfg.compute_dtype).itemsize
    return 2 * page_size * cfg.n_kv_heads * cfg.head_dim * itemsize


def pool_pages_for_budget(cfg: ModelConfig, budget_bytes: int,
                          page_size: int) -> Optional[int]:
    """Per-layer pool size (pages) a byte budget buys across all caching
    layers. None when the stack has no caching attention layer at all."""
    a = n_caching_attn_layers(cfg)
    if a == 0:
        return None
    return int(budget_bytes // (a * page_bytes(cfg, page_size)))


def sanitize_page_ids(ids: jax.Array, n_pages: int) -> jax.Array:
    """Map unallocated (-1) entries to an out-of-bounds id so scatters with
    mode="drop" skip them instead of wrapping to the last page."""
    return jnp.where(ids >= 0, ids, n_pages).astype(jnp.int32)


# --------------------------------------------------------------- pools ------

def init_paged_cache(cfg: ModelConfig, n_slots: int, max_len: int, *,
                     page_size: int = DEFAULT_PAGE_SIZE,
                     n_pages: Optional[int] = None):
    """Cache tree for the paged serving engine. Attention blocks get page
    pools; SSM/conv/cross-attn blocks keep slot-indexed state rows. The tree
    mirrors the stack plan ({"groups": [{"blocks": [...]}]}), so the stack
    executor scans it unchanged."""
    if n_pages is None:
        n_pages = n_slots * pages_per_seq(max_len, page_size)
    dtype = jnp.dtype(cfg.compute_dtype)
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    groups = []
    for g in cfg.stack:
        blocks = []
        for blk in g.unit:
            stack = g.repeat
            if blk.kind == "attn":
                shp = (stack, n_pages, kv, page_size, hd)
                blocks.append({"k_pages": jnp.zeros(shp, dtype),
                               "v_pages": jnp.zeros(shp, dtype)})
            else:
                blocks.append(_block_cache(cfg, blk, n_slots, max_len, stack,
                                           dtype, per_slot_pos=True))
        groups.append({"blocks": blocks})
    return {"groups": groups}


def assign_pages(cfg: ModelConfig, paged_cache, prefill_cache, slot,
                 page_ids, *, page_size: int):
    """Write a batch=1 POSITION-ALIGNED prefill cache into the page pools.

    ``prefill_cache`` must come from ``prefill(..., paged=True)`` with
    ``cache_len`` a multiple of ``page_size`` (no ring wrap). ``page_ids``
    holds >= cache_len // page_size int32 entries (a full page-table row is
    fine); entry i is the physical page for logical page i, -1 for prompt
    pages that were never allocated (bucket padding) — those tiles are
    dropped. Non-attention
    block state is written into slot row ``slot`` wholesale, so a recycled
    slot's SSM/conv/cross state can never leak across tenancies.
    """
    page_ids = jnp.asarray(page_ids, jnp.int32)

    def row_assign(dst, src):
        if src.ndim == dst.ndim - 1:            # kpos (L, W) -> (L, 1, W)
            src = src[:, None]
        idx = (0, slot) + (0,) * (dst.ndim - 2)
        return jax.lax.dynamic_update_slice(dst, src.astype(dst.dtype), idx)

    def page_assign(dst, src):                  # src: (L, 1, KV, S, hd)
        l, _, kv, s, hd = src.shape
        npg = s // page_size
        assert npg * page_size == s and npg <= page_ids.shape[0], \
            (s, page_size, page_ids.shape)
        ids = page_ids[:npg]
        tiles = src[:, 0].reshape(l, kv, npg, page_size, hd)
        tiles = tiles.transpose(0, 2, 1, 3, 4).astype(dst.dtype)
        ids = sanitize_page_ids(ids, dst.shape[1])
        return dst.at[:, ids].set(tiles, mode="drop")

    new_groups = []
    for gi, g in enumerate(cfg.stack):
        blocks = []
        for u, blk in enumerate(g.unit):
            dst = paged_cache["groups"][gi]["blocks"][u]
            src = prefill_cache["groups"][gi]["blocks"][u]
            if dst is None:
                blocks.append(None)
            elif blk.kind == "attn":
                blocks.append({"k_pages": page_assign(dst["k_pages"], src["k"]),
                               "v_pages": page_assign(dst["v_pages"], src["v"])})
            else:
                blocks.append(jax.tree.map(row_assign, dst, src))
        new_groups.append({"blocks": blocks})
    return {"groups": new_groups}


# ----------------------------------------------------------- allocator ------

class DoubleFreeError(RuntimeError):
    pass


@dataclass
class PageAllocator:
    """Host-side REFCOUNTED free-list allocator over page ids [0, n_pages).

    alloc is all-or-nothing (returns None when the pool cannot satisfy the
    request — the caller reclaims or defers) and hands pages out at
    refcount 1. ``ref`` pins additional holders (prefix sharing: a slot
    pointing its page table at an already-cached prefix, or the prefix
    index publishing a page); ``unref`` — ``free`` is an alias — drops one
    reference, and the page returns to the free list only at refcount 0.

    ref/unref are ATOMIC: the whole id list is validated first (duplicate
    ids in one call count once per occurrence against the refcount), so a
    rejected call never leaves the allocator half-mutated. Retirement stays
    copy-free: a page released at refcount 0 goes back untouched, and
    isolation is positional (a reallocated page's stale tokens sit at
    positions the new holder has not reached, hence masked; they are
    overwritten before ever becoming valid).

    Threading: the allocator (and ``PrefixIndex``) is STEP-THREAD-ONLY —
    every mutation happens inside ``Engine.step()``/``cancel()``, which the
    AsyncEngine serializes on its step loop (client-thread cancels go
    through the inbox, never here directly). That single-owner rule is why
    there are no locks and no ``# guarded-by:`` annotations in this module;
    ``repro.analysis`` checks the annotated engine state that upholds it.
    """
    n_pages: int
    _free: list = field(default_factory=list)
    _refs: dict = field(default_factory=dict)     # pid -> refcount >= 1
    peak_in_use: int = 0

    def __post_init__(self):
        self._free = list(range(self.n_pages - 1, -1, -1))

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return len(self._refs)

    def refcount(self, pid: int) -> int:
        return self._refs.get(pid, 0)

    def refcount_histogram(self) -> dict:
        """``{refcount: n_pages}`` over live pages — how shared the pool is
        (rc 1 = private or index-only, rc >= 2 = actively shared). O(in_use)
        on the host; the obs step timeline records it every step."""
        hist: dict = {}
        for c in self._refs.values():
            hist[c] = hist.get(c, 0) + 1
        return hist

    def alloc(self, n: int) -> Optional[list[int]]:
        if n < 0:
            raise ValueError(f"alloc({n})")
        if n > len(self._free):
            return None
        ids = [self._free.pop() for _ in range(n)]
        for pid in ids:
            self._refs[pid] = 1
        self.peak_in_use = max(self.peak_in_use, len(self._refs))
        return ids

    def ref(self, ids) -> None:
        """Add one reference per occurrence of each id. Atomic: every id
        must be allocated or nothing is referenced."""
        ids = list(ids)
        for pid in ids:                           # validate, then mutate
            if pid not in self._refs:
                raise DoubleFreeError(f"page {pid} is not allocated")
        for pid in ids:
            self._refs[pid] += 1

    def unref(self, ids) -> None:
        """Drop one reference per occurrence of each id; a page returns to
        the free list at refcount 0. Atomic: the whole list — duplicate ids
        counted per occurrence — is validated against current refcounts
        before any mutation, so a raising call changes nothing."""
        ids = list(ids)
        need: dict = {}
        for pid in ids:
            need[pid] = need.get(pid, 0) + 1
        for pid, n in need.items():               # validate, then mutate
            if self._refs.get(pid, 0) < n:
                raise DoubleFreeError(
                    f"page {pid}: {n} release(s) requested but refcount is "
                    f"{self._refs.get(pid, 0)}")
        for pid in ids:
            self._refs[pid] -= 1
            if self._refs[pid] == 0:
                del self._refs[pid]
                self._free.append(pid)

    free = unref                                  # pre-refcount API name

    def check_invariants(self) -> None:
        """Free-list conservation: referenced and free pages partition
        [0, n_pages), and every live refcount is >= 1."""
        free = set(self._free)
        assert len(free) == len(self._free), "duplicate ids on free list"
        assert not (free & self._refs.keys()), "page both free and referenced"
        assert free | self._refs.keys() == set(range(self.n_pages)), \
            "page lost"
        assert all(c >= 1 for c in self._refs.values()), "zombie refcount"


# ---------------------------------------------------------- prefix index ---

class _TrieNode:
    __slots__ = ("children", "page", "last_used")

    def __init__(self, page: int, clock: int):
        self.children: dict = {}                  # chunk tokens -> _TrieNode
        self.page = page                          # physical page id
        self.last_used = clock


class PrefixIndex:
    """Host-side radix/trie over prompt-token page-chunks.

    Each node maps one FULL page of a previously-served prompt prefix —
    keyed by its ``page_size`` token values, position-implicit through its
    trie depth — to the physical page already holding that prefix's KV in
    every caching layer (allocation is layer-synchronized, so one id names
    the page in all pools). The index holds ONE allocator reference per
    mapped page (taken at ``insert``), which is what lets a published
    prefix outlive the request that prefilled it.

    ``lookup`` returns the longest page-aligned cached prefix of a prompt,
    capped at ``(len(prompt) - 1) // page_size`` pages so the admission
    suffix always contains at least the final prompt token (its logits seed
    decoding); the last (partial) page is never indexed at all. ``evict_lru``
    drops the least-recently-used leaf whose page nothing but the index
    references (refcount 1) — leaf-first keeps every surviving node
    reachable from the root, and skipping still-referenced pages means
    eviction only runs when it actually frees pool capacity.
    """

    def __init__(self, page_size: int):
        self.page_size = int(page_size)
        self.root: dict = {}                      # chunk tokens -> _TrieNode
        self._clock = 0
        self.n_entries = 0
        self.n_evictions = 0                      # lifetime LRU pages dropped

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def _chunk(self, prompt, i: int) -> tuple:
        ps = self.page_size
        return tuple(int(t) for t in prompt[i * ps:(i + 1) * ps])

    def lookup(self, prompt) -> tuple[int, list[int]]:
        """Longest cached page-aligned proper prefix of ``prompt``: returns
        (n_pages, physical ids). Touches each hit node's LRU stamp."""
        max_k = max(0, (len(prompt) - 1) // self.page_size)
        node_map, ids = self.root, []
        now = self._tick()
        for i in range(max_k):
            node = node_map.get(self._chunk(prompt, i))
            if node is None:
                break
            node.last_used = now
            ids.append(node.page)
            node_map = node.children
        return len(ids), ids

    def insert(self, prompt, page_ids, allocator: PageAllocator) -> int:
        """Publish every FULL page of ``prompt`` (len // page_size chunks;
        ``page_ids[i]`` is chunk i's physical page). Newly-created nodes
        take one allocator reference; chunks already indexed keep their
        existing mapping (identical tokens at identical positions produce
        identical KV, so either physical page is valid — the incumbent
        stays, avoiding a ref/unref churn). Returns #new entries."""
        n_full = len(prompt) // self.page_size
        node_map, added = self.root, 0
        now = self._tick()
        for i in range(n_full):
            key = self._chunk(prompt, i)
            node = node_map.get(key)
            if node is None:
                pid = int(page_ids[i])
                allocator.ref([pid])
                node = _TrieNode(pid, now)
                node_map[key] = node
                self.n_entries += 1
                added += 1
            else:
                node.last_used = now
            node_map = node.children
        return added

    def evictable_pages(self, allocator: PageAllocator) -> int:
        """EXACT count of pages leaf-first eviction could free: an entry is
        reclaimable iff its page has refcount 1 AND its whole subtree is
        reclaimable — an rc-1 node above a still-referenced descendant
        (possible under SWA window release, where a slot drops a parent
        page but keeps referencing a child's) never becomes a leaf while
        that descendant lives. Exactness is what lets _reclaim_pages keep
        its all-or-nothing promise: a reclaim that would fall short evicts
        nothing."""
        nodes = []                                # parents before children
        stack = [self.root]
        while stack:
            node_map = stack.pop()
            for node in node_map.values():
                nodes.append(node)
                if node.children:
                    stack.append(node.children)
        ok: dict = {}                             # id(node) -> reclaimable
        count = 0
        for node in reversed(nodes):              # children first
            r = allocator.refcount(node.page) == 1 and \
                all(ok[id(c)] for c in node.children.values())
            ok[id(node)] = r
            count += r
        return count

    def evict_lru(self, allocator: PageAllocator, max_pages: int = 1) -> int:
        """Drop up to ``max_pages`` LRU *leaf* entries whose pages only the
        index references (refcount 1), unref'ing their pages back to the
        free list — one trie walk collects every candidate, so reclaiming
        k pages costs one traversal per cascade level (evicting a leaf can
        expose its parent), not one per page. Returns the number of pages
        freed; 0 means no evictable leaf exists and the caller must fall
        back to preemption."""
        cand: list[tuple] = []                    # (last_used, parent, key)
        stack = [self.root]                       # iterative: a prefix can
        while stack:                              # be 1000s of pages deep
            node_map = stack.pop()
            for key, node in node_map.items():
                if node.children:
                    stack.append(node.children)
                elif allocator.refcount(node.page) == 1:
                    cand.append((node.last_used, node_map, key))
        cand.sort(key=lambda c: c[0])
        freed = 0
        for _, parent, key in cand[:max(0, max_pages)]:
            node = parent.pop(key)
            self.n_entries -= 1
            allocator.unref([node.page])
            freed += 1
        self.n_evictions += freed
        return freed


def release_tail_pages(page_tbl_row: np.ndarray, committed_len: int,
                       page_size: int, allocator: PageAllocator) -> list[int]:
    """Speculative-rollback helper: free every allocated logical page of one
    slot's table row STRICTLY beyond the page containing position
    ``committed_len`` (the next position the slot will write). Because pages
    are position-aligned, rejecting draft tokens needs no kpos repair — the
    committed length itself is the rollback, and this just returns the
    surplus candidate-span pages (always private: _ensure_decode_pages
    allocated them fresh, shared prefix pages live at the head of the row)
    to the pool. Mutates ``page_tbl_row`` in place (-1 = unallocated) and
    returns the freed physical ids (possibly empty)."""
    keep = committed_len // page_size             # last page still writable
    freed = [int(page_tbl_row[l])
             for l in range(keep + 1, page_tbl_row.shape[0])
             if page_tbl_row[l] >= 0]
    if freed:
        page_tbl_row[keep + 1:] = -1
        allocator.unref(freed)
    return freed


# --------------------------------------------------------------- stats ------

def build_page_table(n_slots: int, max_len: int,
                     page_size: int) -> np.ndarray:
    return np.full((n_slots, pages_per_seq(max_len, page_size)), -1, np.int32)
