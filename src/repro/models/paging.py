"""Paged KV-cache subsystem: global page pools + host-side page allocator.

Block-paged KV management (the PagedAttention design) replaces the serving
engine's one-ring-per-slot reservation with a *pool* of fixed-size pages per
attention layer. A request owns only the pages that cover the tokens it has
actually produced, so short requests stop stranding the HBM the scheduler
budgeted for ``max_len`` — and the freed memory converts into admitted
traffic. The saving composes multiplicatively with NBL: linearized layers
carry NO pool at all (paper §4.2), so m of K layers linearized shrinks the
per-request page bill by m/K on top of the page-granular allocation.

Layout
------
Every caching attention layer owns one pool pair, stacked over the group's
scan dim exactly like the slot cache:

    k_pages / v_pages : (L, n_pages, KV, page_size, hd)

Pages are POSITION-ALIGNED: logical page ``l`` of a request always holds
absolute positions [l*page_size, (l+1)*page_size). Validity is therefore
derivable from the request's current length — no per-token ``kpos`` array
exists in the paged layout. Sliding-window layers keep full-length pages and
mask in the kernel (they trade the ring's compaction for page sharing).

One page TABLE is shared by all layers (allocation is synchronized: a page
id is valid in every layer's pool simultaneously). It lives on the HOST as
an ``(n_slots, pages_per_seq)`` int32 array owned by the engine, entries -1
= unallocated, and is passed to the decode jit as a regular (tiny) argument
— appending a page mid-decode is a host-side table write, never a cache-tree
surgery.

Non-attention state (SSM, conv, cross-attn KV) is not pageable (it is O(1)
per slot, not O(seq)); those blocks keep the slot-indexed layout from
``kv_cache.init_slot_cache`` inside the same cache tree.

Unit of account: ``page_bytes(cfg, page_size)`` is the byte size of ONE page
in ONE layer — the scheduler's page budget (launch/scheduler.nbl_page_budget)
divides an HBM budget by (caching layers x page_bytes) to size the pool.

Scatter/gather safety: -1 table entries would *wrap* under numpy indexing
semantics, so every device-side consumer sanitizes ids first —
``sanitize_page_ids`` maps negatives to ``n_pages`` (out of bounds, dropped
by scatter mode="drop"); gathers clip to 0 and rely on the position mask.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.kv_cache import _block_cache

DEFAULT_PAGE_SIZE = 64


def pages_per_seq(max_len: int, page_size: int) -> int:
    return -(-max_len // page_size)


def n_caching_attn_layers(cfg: ModelConfig) -> int:
    """Attention invocations that carry a KV pool (shared blocks count once
    per invocation, like their caches; nbl/drop/mamba/cross contribute 0)."""
    return sum(1 for b in cfg.blocks() if b.kind == "attn")


def page_bytes(cfg: ModelConfig, page_size: int) -> int:
    """Bytes of ONE page in ONE attention layer (K + V)."""
    itemsize = jnp.dtype(cfg.compute_dtype).itemsize
    return 2 * page_size * cfg.n_kv_heads * cfg.head_dim * itemsize


def pool_pages_for_budget(cfg: ModelConfig, budget_bytes: int,
                          page_size: int) -> Optional[int]:
    """Per-layer pool size (pages) a byte budget buys across all caching
    layers. None when the stack has no caching attention layer at all."""
    a = n_caching_attn_layers(cfg)
    if a == 0:
        return None
    return int(budget_bytes // (a * page_bytes(cfg, page_size)))


def sanitize_page_ids(ids: jax.Array, n_pages: int) -> jax.Array:
    """Map unallocated (-1) entries to an out-of-bounds id so scatters with
    mode="drop" skip them instead of wrapping to the last page."""
    return jnp.where(ids >= 0, ids, n_pages).astype(jnp.int32)


# --------------------------------------------------------------- pools ------

def init_paged_cache(cfg: ModelConfig, n_slots: int, max_len: int, *,
                     page_size: int = DEFAULT_PAGE_SIZE,
                     n_pages: Optional[int] = None):
    """Cache tree for the paged serving engine. Attention blocks get page
    pools; SSM/conv/cross-attn blocks keep slot-indexed state rows. The tree
    mirrors the stack plan ({"groups": [{"blocks": [...]}]}), so the stack
    executor scans it unchanged."""
    if n_pages is None:
        n_pages = n_slots * pages_per_seq(max_len, page_size)
    dtype = jnp.dtype(cfg.compute_dtype)
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    groups = []
    for g in cfg.stack:
        blocks = []
        for blk in g.unit:
            stack = g.repeat
            if blk.kind == "attn":
                shp = (stack, n_pages, kv, page_size, hd)
                blocks.append({"k_pages": jnp.zeros(shp, dtype),
                               "v_pages": jnp.zeros(shp, dtype)})
            else:
                blocks.append(_block_cache(cfg, blk, n_slots, max_len, stack,
                                           dtype, per_slot_pos=True))
        groups.append({"blocks": blocks})
    return {"groups": groups}


def assign_pages(cfg: ModelConfig, paged_cache, prefill_cache, slot,
                 page_ids, *, page_size: int):
    """Write a batch=1 POSITION-ALIGNED prefill cache into the page pools.

    ``prefill_cache`` must come from ``prefill(..., paged=True)`` with
    ``cache_len`` a multiple of ``page_size`` (no ring wrap). ``page_ids``
    holds >= cache_len // page_size int32 entries (a full page-table row is
    fine); entry i is the physical page for logical page i, -1 for prompt
    pages that were never allocated (bucket padding) — those tiles are
    dropped. Non-attention
    block state is written into slot row ``slot`` wholesale, so a recycled
    slot's SSM/conv/cross state can never leak across tenancies.
    """
    page_ids = jnp.asarray(page_ids, jnp.int32)

    def row_assign(dst, src):
        if src.ndim == dst.ndim - 1:            # kpos (L, W) -> (L, 1, W)
            src = src[:, None]
        idx = (0, slot) + (0,) * (dst.ndim - 2)
        return jax.lax.dynamic_update_slice(dst, src.astype(dst.dtype), idx)

    def page_assign(dst, src):                  # src: (L, 1, KV, S, hd)
        l, _, kv, s, hd = src.shape
        npg = s // page_size
        assert npg * page_size == s and npg <= page_ids.shape[0], \
            (s, page_size, page_ids.shape)
        ids = page_ids[:npg]
        tiles = src[:, 0].reshape(l, kv, npg, page_size, hd)
        tiles = tiles.transpose(0, 2, 1, 3, 4).astype(dst.dtype)
        ids = sanitize_page_ids(ids, dst.shape[1])
        return dst.at[:, ids].set(tiles, mode="drop")

    new_groups = []
    for gi, g in enumerate(cfg.stack):
        blocks = []
        for u, blk in enumerate(g.unit):
            dst = paged_cache["groups"][gi]["blocks"][u]
            src = prefill_cache["groups"][gi]["blocks"][u]
            if dst is None:
                blocks.append(None)
            elif blk.kind == "attn":
                blocks.append({"k_pages": page_assign(dst["k_pages"], src["k"]),
                               "v_pages": page_assign(dst["v_pages"], src["v"])})
            else:
                blocks.append(jax.tree.map(row_assign, dst, src))
        new_groups.append({"blocks": blocks})
    return {"groups": new_groups}


# ----------------------------------------------------------- allocator ------

class DoubleFreeError(RuntimeError):
    pass


@dataclass
class PageAllocator:
    """Host-side free-list allocator over physical page ids [0, n_pages).

    alloc is all-or-nothing (returns None when the pool cannot satisfy the
    request — the caller preempts or defers); free rejects double-frees and
    foreign ids. Slot retirement is copy-free: pages go back on the free
    list untouched, and isolation is guaranteed by position masking (a
    reallocated page's stale tokens sit at positions the new owner has not
    reached, hence masked; they are overwritten before ever becoming valid).
    """
    n_pages: int
    _free: list = field(default_factory=list)
    _used: set = field(default_factory=set)
    peak_in_use: int = 0

    def __post_init__(self):
        self._free = list(range(self.n_pages - 1, -1, -1))

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return len(self._used)

    def alloc(self, n: int) -> Optional[list[int]]:
        if n < 0:
            raise ValueError(f"alloc({n})")
        if n > len(self._free):
            return None
        ids = [self._free.pop() for _ in range(n)]
        self._used.update(ids)
        self.peak_in_use = max(self.peak_in_use, len(self._used))
        return ids

    def free(self, ids) -> None:
        for pid in ids:
            if pid not in self._used:
                raise DoubleFreeError(f"page {pid} is not allocated")
            self._used.discard(pid)
            self._free.append(pid)

    def check_invariants(self) -> None:
        """Free-list conservation: used and free partition [0, n_pages)."""
        free = set(self._free)
        assert len(free) == len(self._free), "duplicate ids on free list"
        assert not (free & self._used), "page both free and used"
        assert free | self._used == set(range(self.n_pages)), "page lost"


# --------------------------------------------------------------- stats ------

def build_page_table(n_slots: int, max_len: int,
                     page_size: int) -> np.ndarray:
    return np.full((n_slots, pages_per_seq(max_len, page_size)), -1, np.int32)
