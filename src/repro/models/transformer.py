"""Model assembly: stack-plan executor, init, loss, prefill, decode.

Execution walks the config's stack plan group-by-group; each group scans
(`lax.scan`) over its `repeat` dimension with stacked per-layer params, so HLO
size is O(#groups), not O(#layers) — essential for 512-way dry-run compiles of
61-126 layer models. Shared blocks (Zamba2) keep a single param copy closed
over by the scan body, with per-invocation caches scanned.

Block kinds (see configs.base.Block):
  attn        pre-norm GQA self-attention + residual
  cross_attn  pre-norm cross-attention over frontend embeddings + residual
  mamba       pre-norm Mamba2 SSD mixer + residual
  nbl         NBL-linearized attention sub-block: x + (x @ W + b). The LMMSE
              map is fit on the residual-stream input (norm folded in), so the
              compressed block is a single GEMM — the paper's replacement.
  drop        attention sub-block removed (Attn DROP baseline): x unchanged
  nbl_block   whole transformer block linearized: x + (x @ W + b); no ffn
  drop_block  whole block removed (SLEB / Block DROP baseline): identity
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import Block, ModelConfig
from repro.distributed import constrain
from repro.models.attention import (
    cross_attention, decode_attention, decode_cross_attention, init_attn,
    decode_paged_attention, fused_paged_attention, self_attention,
)
from repro.models.layers import embed_tokens, init_mlp, mlp, rmsnorm, softcap
from repro.models.moe import init_moe, moe_ffn
from repro.models.ssm import init_mamba, mamba_block, mamba_decode


# --------------------------------------------------------------------------
# Parameter init
# --------------------------------------------------------------------------

def _ffn_dim(cfg: ModelConfig, blk: Block) -> int:
    if blk.ffn == "dense" and cfg.moe is not None and cfg.moe.dense_ff:
        return cfg.moe.dense_ff
    return cfg.d_ff


def init_nbl_linear(key: jax.Array, cfg: ModelConfig) -> dict:
    """Random-init NBL linear (real W, b come from core.lmmse surgery; this
    exists so compressed configs can be dry-run/inited without calibration)."""
    d = cfg.d_model
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "w": (jax.random.normal(key, (d, d)) * d ** -0.5).astype(dt),
        "b": jnp.zeros((d,), dt),
    }


def init_block(key: jax.Array, cfg: ModelConfig, blk: Block) -> dict:
    d = cfg.d_model
    dt = jnp.dtype(cfg.param_dtype)
    k1, k2 = jax.random.split(key)
    p: dict = {}
    if blk.kind in ("attn", "cross_attn"):
        p["norm1"] = jnp.zeros((d,), dt)
        p["mixer"] = init_attn(k1, cfg, cross=(blk.kind == "cross_attn"))
    elif blk.kind == "mamba":
        p["norm1"] = jnp.zeros((d,), dt)
        p["mixer"] = init_mamba(k1, cfg)
    elif blk.kind in ("nbl", "nbl_block"):
        p["mixer"] = init_nbl_linear(k1, cfg)
    elif blk.kind in ("drop", "drop_block"):
        pass
    else:
        raise ValueError(f"unknown block kind {blk.kind!r}")

    if blk.kind in ("nbl_block", "drop_block"):
        return p                                  # whole block replaced
    if blk.ffn == "dense":
        p["norm2"] = jnp.zeros((d,), dt)
        p["ffn"] = init_mlp(k2, d, _ffn_dim(cfg, blk), dt)
    elif blk.ffn == "moe":
        p["norm2"] = jnp.zeros((d,), dt)
        p["ffn"] = init_moe(k2, cfg)
    return p


def init_params(key: jax.Array, cfg: ModelConfig) -> dict:
    d, v = cfg.d_model, cfg.vocab_size
    dt = jnp.dtype(cfg.param_dtype)
    n_groups = len(cfg.stack)
    keys = jax.random.split(key, n_groups + 2)
    params: dict = {
        "embed": (jax.random.normal(keys[0], (v, d)) * d ** -0.5).astype(dt),
        "final_norm": jnp.zeros((d,), dt),
    }
    if not cfg.tie_embeddings:
        params["head"] = (jax.random.normal(keys[1], (d, v))
                          * d ** -0.5).astype(dt)
    groups = []
    for gi, g in enumerate(cfg.stack):
        gkeys = jax.random.split(keys[2 + gi], len(g.unit))
        scanned, shared = [], []
        for u, blk in enumerate(g.unit):
            if blk.shared:
                shared.append(init_block(gkeys[u], cfg, blk))
                scanned.append(None)
            else:
                lk = jax.random.split(gkeys[u], g.repeat)
                scanned.append(
                    jax.vmap(lambda kk: init_block(kk, cfg, blk))(lk))
                shared.append(None)
        groups.append({"scanned": scanned, "shared": shared})
    params["groups"] = groups
    return params


def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    """Analytic (eval_shape) parameter count. With ``active_only`` routed MoE
    expert weights are scaled by top_k/n_experts (6·N_active·D roofline)."""
    shapes = jax.eval_shape(
        lambda: init_params(jax.random.PRNGKey(0), cfg))
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        n = 1
        for s in leaf.shape:
            n *= s
        if active_only and cfg.moe is not None:
            names = [getattr(k, "key", None) for k in path]
            if ("ffn" in names and leaf.ndim >= 3
                    and cfg.moe.n_experts in leaf.shape
                    and names[-1] in ("w_gate", "w_up", "w_down")):
                n = n * cfg.moe.top_k // cfg.moe.n_experts
        total += n
    return total


# --------------------------------------------------------------------------
# Block forward (one residual block, one mode)
# --------------------------------------------------------------------------

def _block_fwd(cfg: ModelConfig, blk: Block, p, x, *, mode: str,
               positions, enc, cache, pos, cache_len: int,
               page_tbl=None, paged: bool = False, valid_len=None,
               prefix_tbl=None, prefix_len=None, row_len=None):
    """Returns (x, new_cache, aux). ``cache`` is this block's slice.

    ``page_tbl``/``paged``/``valid_len`` serve the paged engine: a decode
    cache holding page pools (key "k_pages") dispatches to the paged kernel;
    a paged prefill keeps full-width position-aligned caches (no ring wrap);
    ``valid_len`` masks bucket-padding tokens out of the prefill cache.
    ``prefix_tbl``/``prefix_len`` serve the PARTIAL prefill under prefix
    sharing: in prefill mode ``cache`` is then this layer's page pools and
    the attention gathers the shared-prefix KV through the table.
    mode="fused" is the engine's single-dispatch mixed step (decode rows +
    prefill-chunk rows over the shared page table): ``pos`` is the per-row
    first-token position and ``row_len`` the per-row valid token count.
    """
    aux = jnp.zeros((), jnp.float32)
    new_cache = None

    # ---- mixer -----------------------------------------------------------
    if blk.kind == "attn":
        h = rmsnorm(x, p["norm1"], cfg.norm_eps)
        if mode == "decode":
            if cache is not None and "k_pages" in cache:
                h, new_cache = decode_paged_attention(
                    cfg, p["mixer"], h, cache, pos, page_tbl,
                    window=blk.window)
            else:
                h, new_cache = decode_attention(cfg, p["mixer"], h, cache,
                                                pos, window=blk.window)
        elif mode == "fused":
            h, new_cache = fused_paged_attention(
                cfg, p["mixer"], h, cache, pos, row_len, page_tbl,
                window=blk.window)
        else:
            prefix = None
            if mode == "prefill" and prefix_tbl is not None:
                prefix = _gather_prefix(cache, prefix_tbl, prefix_len)
            h, (k, v) = self_attention(cfg, p["mixer"], h, window=blk.window,
                                       positions=positions, prefix=prefix)
            if mode == "prefill":
                new_cache = _ring_cache(cfg, blk, k, v, cache_len,
                                        paged=paged, valid_len=valid_len)
        x = x + h.astype(x.dtype)
    elif blk.kind == "cross_attn":
        h = rmsnorm(x, p["norm1"], cfg.norm_eps)
        if mode in ("decode", "fused"):
            # decode_cross_attention is query-length agnostic (its queries
            # carry no positions), so fused multi-token rows reuse it as-is
            h, new_cache = decode_cross_attention(cfg, p["mixer"], h, cache)
        else:
            h, (k, v) = cross_attention(cfg, p["mixer"], h, enc=enc)
            if mode == "prefill":
                new_cache = {"k": k.astype(x.dtype), "v": v.astype(x.dtype)}
        x = x + h.astype(x.dtype)
    elif blk.kind == "mamba":
        assert mode != "fused", \
            "fused step cannot resume SSM state mid-sequence (engine gates " \
            "mamba stacks onto the legacy path)"
        h = rmsnorm(x, p["norm1"], cfg.norm_eps)
        if mode == "decode":
            h, new_cache = mamba_decode(cfg, p["mixer"], h, cache)
        else:
            h, (state, conv) = mamba_block(cfg, p["mixer"], h)
            if mode == "prefill":
                new_cache = {"ssm": state, "conv": conv}
        x = x + h.astype(x.dtype)
    elif blk.kind in ("nbl", "nbl_block"):
        # the paper's replacement: one GEMM, residual retained (Alg. 2).
        h = x @ p["mixer"]["w"].astype(x.dtype) + p["mixer"]["b"].astype(x.dtype)
        x = x + h
    elif blk.kind in ("drop", "drop_block"):
        pass

    if blk.kind in ("nbl_block", "drop_block"):
        return x, new_cache, aux

    # ---- ffn --------------------------------------------------------------
    if blk.ffn == "dense":
        h = rmsnorm(x, p["norm2"], cfg.norm_eps)
        x = x + mlp(p["ffn"], h, cfg.mlp_act).astype(x.dtype)
    elif blk.ffn == "moe":
        h = rmsnorm(x, p["norm2"], cfg.norm_eps)
        y, aux = moe_ffn(cfg, p["ffn"], h)
        x = x + y.astype(x.dtype)
    return x, new_cache, aux


def _gather_prefix(pool: dict, prefix_tbl, prefix_len):
    """Gather shared-prefix KV for a partial prefill: ``pool`` is one
    layer's page pools {k_pages, v_pages: (n_pages, KV, ps, hd)};
    ``prefix_tbl`` (Pb,) physical ids (-1 = past the prefix, clip-gathered
    and masked); ``prefix_len`` traced token count. Returns (k, v, kpos)
    with k/v (1, KV, Pb*ps, hd) and kpos -1 beyond prefix_len."""
    assert pool is not None and "k_pages" in pool, \
        "partial prefill needs the paged pools"
    idx = jnp.clip(jnp.asarray(prefix_tbl, jnp.int32), 0)
    kg = pool["k_pages"][idx]                     # (Pb, KV, ps, hd)
    vg = pool["v_pages"][idx]
    pb, kv, ps, hd = kg.shape
    kg = kg.transpose(1, 0, 2, 3).reshape(1, kv, pb * ps, hd)
    vg = vg.transpose(1, 0, 2, 3).reshape(1, kv, pb * ps, hd)
    t = jnp.arange(pb * ps, dtype=jnp.int32)
    kpos = jnp.where(t < jnp.asarray(prefix_len, jnp.int32), t, -1)
    return kg, vg, kpos


def _ring_cache(cfg: ModelConfig, blk: Block, k, v, cache_len: int, *,
                paged: bool = False, valid_len=None) -> dict:
    """Convert full-sequence (roped) K/V (B,KV,S,hd) into the ring-buffer
    cache layout used by decode (width = min(window, cache_len)).

    ``paged`` keeps the cache POSITION-ALIGNED at full ``cache_len`` width
    even for windowed layers (pages must map positions linearly; the window
    is enforced by the decode mask instead of ring compaction). ``valid_len``
    (traced scalar) masks positions >= it to kpos=-1 — bucket-padded prompt
    tokens are written but never attendable.
    """
    s = k.shape[2]
    if paged:
        w = cache_len
        assert w >= s, (w, s)
    else:
        w = min(blk.window, cache_len) if blk.window is not None else cache_len
    if w >= s:
        pad = w - s
        kr = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vr = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        kpos = jnp.concatenate([jnp.arange(s, dtype=jnp.int32),
                                jnp.full((pad,), -1, jnp.int32)])
    else:
        start = s - w
        slots = jnp.arange(w)
        src = (start + ((slots - start) % w)).astype(jnp.int32)
        kr = jnp.take(k, src, axis=2)
        vr = jnp.take(v, src, axis=2)
        kpos = src
    if valid_len is not None:
        kpos = jnp.where((kpos >= 0) & (kpos < valid_len), kpos, -1)
    dt = jnp.dtype(cfg.compute_dtype)
    return {"k": kr.astype(dt), "v": vr.astype(dt), "kpos": kpos}


# --------------------------------------------------------------------------
# Stack executor
# --------------------------------------------------------------------------

def _stack_fwd(cfg: ModelConfig, params: dict, x, *, mode: str,
               positions=None, enc=None, cache=None, pos=None,
               cache_len: int = 0, remat: bool = False,
               page_tbl=None, paged: bool = False, valid_len=None,
               prefix_tbl=None, prefix_len=None, row_len=None):
    """Run the full stack. Returns (x, new_cache_or_None, aux_sum)."""
    aux_total = jnp.zeros((), jnp.float32)
    new_groups = []
    for gi, g in enumerate(cfg.stack):
        gp = params["groups"][gi]
        gcache = cache["groups"][gi]["blocks"] if cache is not None else None

        def body(carry, xs, _g=g, _gp=gp):
            xc, auxc = carry
            ps, cs = xs
            outs = []
            for u, blk in enumerate(_g.unit):
                p_u = _gp["shared"][u] if blk.shared else ps[u]
                c_u = cs[u] if cs is not None else None
                xc, nc, aux_u = _block_fwd(
                    cfg, blk, p_u, xc, mode=mode, positions=positions,
                    enc=enc, cache=c_u, pos=pos, cache_len=cache_len,
                    page_tbl=page_tbl, paged=paged, valid_len=valid_len,
                    prefix_tbl=prefix_tbl, prefix_len=prefix_len,
                    row_len=row_len)
                auxc = auxc + aux_u
                outs.append(nc)
            return (xc, auxc), outs

        fn = body
        if remat and mode == "train":
            fn = jax.checkpoint(
                body,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

        xs = (gp["scanned"], gcache)
        (x, aux_total), caches_out = jax.lax.scan(
            fn, (x, aux_total), xs, length=g.repeat)
        if mode in ("prefill", "decode", "fused"):
            new_groups.append({"blocks": caches_out})
        x = constrain(x, "dp", None, None)

    new_cache = ({"groups": new_groups}
                 if mode in ("prefill", "decode", "fused") else None)
    return x, new_cache, aux_total


# --------------------------------------------------------------------------
# Public API
# --------------------------------------------------------------------------

def _logits(cfg: ModelConfig, params: dict, x) -> jax.Array:
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype),
                        preferred_element_type=jnp.float32)
    logits = softcap(logits, cfg.final_logit_softcap)
    return constrain(logits, "dp", None, "model")


def apply(cfg: ModelConfig, params: dict, tokens, *, enc=None,
          remat: bool = False):
    """Full-sequence forward. Returns (logits_f32 (B,S,V), moe_aux)."""
    dt = jnp.dtype(cfg.compute_dtype)
    x = embed_tokens(params["embed"], tokens, dt)
    positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)
    x, _, aux = _stack_fwd(cfg, params, x, mode="train", positions=positions,
                           enc=enc, remat=remat)
    return _logits(cfg, params, x), aux


def loss_fn(cfg: ModelConfig, params: dict, batch: dict, *,
            remat: bool = True):
    """Causal-LM loss. batch: tokens (B,S), labels (B,S) with -1 = masked,
    optional enc (B,T,d). Returns (loss, metrics)."""
    logits, aux = apply(cfg, params, batch["tokens"], enc=batch.get("enc"),
                        remat=remat)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    # logsumexp − logit_at_label: one fewer full-vocab materialization than
    # log_softmax + gather (the (B,S,V) tensor is the dominant train-time
    # activation at 100k+ vocabs; see EXPERIMENTS.md §Perf).
    lse = jax.nn.logsumexp(logits, axis=-1)
    at = jnp.take_along_axis(
        logits, jnp.clip(labels, 0)[..., None], axis=-1)[..., 0]
    nll = lse - at
    ntok = jnp.maximum(mask.sum(), 1.0)
    ce = (nll * mask).sum() / ntok
    loss = ce + aux
    return loss, {"loss": loss, "ce": ce, "aux": aux, "ntokens": ntok}


def prefill(cfg: ModelConfig, params: dict, tokens, *, enc=None,
            cache_len: Optional[int] = None, paged: bool = False,
            valid_len=None, prefix_cache=None, prefix_tbl=None,
            prefix_len=None, n_logits: int = 1):
    """Process the prompt, build KV/state caches, return last-token logits.
    Logits are computed at the final position only (vocab-size safe at 32k+
    contexts). Returns (logits (B,1,V), cache).

    ``n_logits`` (STATIC) widens the logits window to the last n_logits
    valid positions — (B, n_logits, V), rows ordered oldest-first so row
    ``n_logits - 1`` is the usual last-token row. The speculative verify
    step uses γ+1 rows to score a whole candidate block from one
    cache-extend pass; everything else keeps the default of 1.

    ``paged`` builds POSITION-ALIGNED full-width caches (no ring wrap) for
    page-tiled assignment (models/paging.assign_pages). ``valid_len`` (a
    traced int32 scalar) supports prompt-length bucketing: ``tokens`` may be
    right-padded to a bucket length — logits come from position
    ``valid_len - 1`` and cache entries at positions >= valid_len are
    masked unattendable, so one jit serves every prompt length in the
    bucket. Not valid for SSM stacks (padding corrupts the scanned state).

    PARTIAL prefill (prefix sharing AND chunked prefill): with
    ``prefix_cache`` (the paged cache tree), ``prefix_tbl`` ((Pb,) int32
    physical page per logical prefix page, -1 padding) and ``prefix_len``
    (traced token count, a page multiple), ``tokens`` holds only the
    SUFFIX from the first divergent page — embedded at absolute positions
    prefix_len + i and attending the already-paged prefix KV through the
    table. The engine reuses this ONE code path for two callers that
    differ only in the table's provenance: prefix sharing points it at
    ANOTHER request's published prompt pages (launch/engine._admit),
    chunked prefill points it at the request's OWN earlier chunks
    (launch/engine._chunk_step) — there is no chunk-specific model code
    below the page table. The returned cache covers the suffix only;
    ``valid_len`` then counts valid SUFFIX tokens and logits come from
    suffix position valid_len - 1. Requires a stack with no SSM blocks
    (their scanned state cannot resume mid-sequence).
    """
    cache_len = cache_len or tokens.shape[1]
    dt = jnp.dtype(cfg.compute_dtype)
    x = embed_tokens(params["embed"], tokens, dt)
    positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)
    if prefix_tbl is not None:
        assert paged, "partial prefill is a paged-engine path"
        assert not any(b.kind == "mamba" for b in cfg.blocks()), \
            "partial prefill cannot resume SSM state mid-sequence"
        positions = positions + jnp.asarray(prefix_len, jnp.int32)
    x, cache, _ = _stack_fwd(cfg, params, x, mode="prefill",
                             positions=positions, enc=enc,
                             cache=prefix_cache if prefix_tbl is not None
                             else None,
                             cache_len=cache_len, paged=paged,
                             valid_len=valid_len, prefix_tbl=prefix_tbl,
                             prefix_len=prefix_len)
    assert 1 <= n_logits <= tokens.shape[1], (n_logits, tokens.shape)
    if valid_len is None:
        x_last = x[:, -n_logits:]
    else:
        start = jnp.asarray(valid_len, jnp.int32) - n_logits
        x_last = jax.lax.dynamic_slice_in_dim(x, start, n_logits, axis=1)
    return _logits(cfg, params, x_last), cache


def decode_step(cfg: ModelConfig, params: dict, token, cache, pos,
                page_tbl=None):
    """One autoregressive step. token: (B,1) int32; pos: absolute position
    of this token — () int32 with a monolithic cache (all sequences at one
    position), or (B,) int32 with a slot cache (per-slot positions, the
    continuous-batching engine). With a PAGED cache (models/paging.py),
    ``page_tbl`` (B, n_lpages) int32 maps each slot's logical pages to
    physical pool pages. Returns (logits (B,1,V), new_cache)."""
    dt = jnp.dtype(cfg.compute_dtype)
    x = embed_tokens(params["embed"], token, dt)
    x, new_cache, _ = _stack_fwd(cfg, params, x, mode="decode", cache=cache,
                                 pos=pos, page_tbl=page_tbl)
    return _logits(cfg, params, x), new_cache


def fused_step(cfg: ModelConfig, params: dict, tokens, cache, row_pos,
               row_len, page_tbl):
    """One FUSED engine step: a mixed batch of decode rows (1 new token) and
    page-aligned prefill-chunk rows (up to W new tokens) executed against
    the shared paged cache in a single dispatch (launch/engine's plan →
    execute → commit pipeline; see docs/architecture.md).

    tokens: (B, W) int32, each row right-padded past its valid span;
    row_pos: (B,) absolute position of each row's FIRST token; row_len:
    (B,) valid tokens this step — 1 for a decode row, the chunk span for a
    prefill row, 0 for an inactive row (empty slot / speculative slot
    stepped separately); page_tbl: (B, n_lpages) int32.

    Returns (logits (B,1,V), new_cache): per-row ``n_logits``-style
    extraction at each row's LAST valid token (a decode row's next-token
    logits; a final chunk's seed logits). Inactive rows yield finite
    garbage logits the caller discards. Requires a paged, SSM-free stack.
    """
    dt = jnp.dtype(cfg.compute_dtype)
    x = embed_tokens(params["embed"], tokens, dt)
    x, new_cache, _ = _stack_fwd(cfg, params, x, mode="fused", cache=cache,
                                 pos=row_pos, row_len=row_len,
                                 page_tbl=page_tbl)
    idx = jnp.clip(jnp.asarray(row_len, jnp.int32) - 1, 0)
    x_last = jnp.take_along_axis(x, idx[:, None, None], axis=1)
    return _logits(cfg, params, x_last), new_cache


# --------------------------------------------------------------------------
# Unrolled forward with activation taps (NBL calibration path)
# --------------------------------------------------------------------------

def layer_params(cfg: ModelConfig, params: dict, layer_idx: int):
    """Slice the stacked params of global block ``layer_idx``."""
    i = 0
    for gi, g in enumerate(cfg.stack):
        for r in range(g.repeat):
            for u, blk in enumerate(g.unit):
                if i == layer_idx:
                    gp = params["groups"][gi]
                    if blk.shared:
                        return gp["shared"][u], blk
                    return jax.tree.map(lambda a: a[r], gp["scanned"][u]), blk
                i += 1
    raise IndexError(layer_idx)


def forward_with_taps(cfg: ModelConfig, params: dict, tokens, *, enc=None,
                      tap_layers=(), tap_block: bool = False,
                      need_logits: bool = False):
    """Python-unrolled forward recording (X, Y) per tapped layer.

    X  = residual-stream input to the block,
    Y  = mixer output pre-residual (tap_block=False, Attn NBL) or the whole
         block's delta (tap_block=True, Block NBL).
    Returns (logits, {layer_idx: (X, Y)}). Used by core.calibrate at modest
    batch sizes; the production path streams moments instead of storing taps.
    """
    dt = jnp.dtype(cfg.compute_dtype)
    x = embed_tokens(params["embed"], tokens, dt)
    positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)
    taps = {}
    i = 0
    for gi, g in enumerate(cfg.stack):
        for r in range(g.repeat):
            for u, blk in enumerate(g.unit):
                p_u, _ = layer_params(cfg, params, i)
                want = i in tap_layers
                x_in = x if want else None
                if want and not tap_block and blk.kind in ("attn", "mamba"):
                    # mixer-only tap: run mixer, record, then ffn
                    h = rmsnorm(x, p_u["norm1"], cfg.norm_eps)
                    if blk.kind == "attn":
                        y, _kv = self_attention(cfg, p_u["mixer"], h,
                                                window=blk.window,
                                                positions=positions)
                    else:
                        y, _st = mamba_block(cfg, p_u["mixer"], h)
                    taps[i] = (x_in, y.astype(jnp.float32))
                    x = x + y.astype(x.dtype)
                    if blk.ffn == "dense":
                        h2 = rmsnorm(x, p_u["norm2"], cfg.norm_eps)
                        x = x + mlp(p_u["ffn"], h2, cfg.mlp_act).astype(x.dtype)
                    elif blk.ffn == "moe":
                        h2 = rmsnorm(x, p_u["norm2"], cfg.norm_eps)
                        y2, _ = moe_ffn(cfg, p_u["ffn"], h2)
                        x = x + y2.astype(x.dtype)
                else:
                    x, _, _ = _block_fwd(cfg, blk, p_u, x, mode="train",
                                         positions=positions, enc=enc,
                                         cache=None, pos=None, cache_len=0)
                    if want and tap_block:
                        taps[i] = (x_in, (x - x_in).astype(jnp.float32))
                i += 1
    logits = _logits(cfg, params, x) if need_logits else None
    return logits, taps
