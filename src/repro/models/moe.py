"""Mixture-of-Experts FFN: top-k routing, capacity-based dispatch, shared
experts (DeepSeek-MoE style), expert parallelism over the "model" mesh axis.

Dispatch uses scatter/gather (sort-free): for each (token, slot) we compute
the expert id and the token's position within that expert's capacity buffer
via a cumulative-sum over a one-hot routing matrix; tokens beyond capacity are
dropped (weights renormalized over surviving slots at combine). With tokens
sharded over (pod, data) and the (E, C, d) buffer sharded over "model", the
scatter/gather lower to the MoE all-to-all pattern.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed import constrain
from repro.distributed.api import shard_map
from repro.models.layers import init_mlp, mlp


def init_moe(key: jax.Array, cfg) -> dict:
    m = cfg.moe
    d, ff, e = cfg.d_model, m.d_expert, m.n_experts
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    dt = jnp.dtype(cfg.param_dtype)
    s_in, s_out = d ** -0.5, ff ** -0.5

    def expert_w(k, shape, scale):
        return (jax.random.normal(k, shape) * scale).astype(dt)

    p = {
        "router": (jax.random.normal(k1, (d, e)) * s_in).astype(jnp.float32),
        "w_gate": expert_w(k2, (e, d, ff), s_in),
        "w_up":   expert_w(k3, (e, d, ff), s_in),
        "w_down": expert_w(k4, (e, ff, d), s_out),
    }
    if m.n_shared:
        p["shared"] = init_mlp(k5, d, m.n_shared * ff, dt)
    return p


def _capacity(n_tokens: int, cfg) -> int:
    m = cfg.moe
    c = int(n_tokens * m.top_k * m.capacity_factor / m.n_experts) + 1
    return max(c, m.top_k)


def moe_ffn(cfg, p: dict, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (y, aux_loss). Dispatches to the explicit
    expert-parallel shard_map path when the mesh has a divisible "model"
    axis (the pjit scatter/gather formulation makes XLA materialize and
    all-reduce the full dispatch buffer in the gather backward — 27× the
    necessary combine traffic on deepseek-moe; EXPERIMENTS.md §Perf H2)."""
    from repro.distributed.api import axis_size, dp_axes, has_axis
    n_dp = 1
    for a in dp_axes():
        n_dp *= axis_size(a)
    if has_axis("model") and cfg.moe.n_experts % axis_size("model") == 0 \
            and axis_size("model") > 1 and x.shape[0] % n_dp == 0:
        return _moe_ffn_ep(cfg, p, x)
    return _moe_ffn_dense(cfg, p, x)


def _moe_ffn_dense(cfg, p: dict, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Single-device / fallback path (pjit-auto sharded)."""
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    cap = _capacity(t, cfg)

    logits = xt.astype(jnp.float32) @ p["router"]          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, m.top_k)              # (T, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch): E * mean(f_e * p_e)
    sel = jax.nn.one_hot(idx[:, 0], m.n_experts, dtype=jnp.float32)
    frac = sel.mean(0)
    aux = m.router_aux_weight * m.n_experts * jnp.sum(frac * probs.mean(0))

    # slot-major flattening: slot 0 of every token gets capacity priority
    flat_e = idx.T.reshape(-1)                             # (kT,)
    oh = jax.nn.one_hot(flat_e, m.n_experts, dtype=jnp.int32)
    pos_in_e = (jnp.cumsum(oh, axis=0) * oh).sum(-1) - 1   # (kT,)
    keep = pos_in_e < cap
    pos_safe = jnp.where(keep, pos_in_e, cap)              # OOB -> dropped

    xk = jnp.tile(xt, (m.top_k, 1))                        # (kT, d)
    buf = jnp.zeros((m.n_experts, cap + 1, d), xt.dtype)
    buf = buf.at[flat_e, pos_safe].add(xk, mode="drop")
    buf = constrain(buf[:, :cap], "model", None, None)     # EP

    # expert FFN (einsum over stacked experts)
    g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    h = jax.nn.silu(g) * u
    h = constrain(h, "model", None, None)
    y_buf = jnp.einsum("ecf,efd->ecd", h, p["w_down"])
    y_buf = jnp.pad(y_buf, ((0, 0), (0, 1), (0, 0)))       # slot `cap` = zeros

    yk = y_buf[flat_e, pos_safe]                           # (kT, d)
    yk = jnp.where(keep[:, None], yk, 0)
    gate_k = gate.T.reshape(-1)[:, None].astype(yk.dtype)
    y = (yk * gate_k).reshape(m.top_k, t, d).sum(0)

    if m.n_shared:
        y = y + mlp(p["shared"], xt, cfg.mlp_act)
    return y.reshape(b, s, d), aux


def _moe_ffn_ep(cfg, p: dict, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Explicit expert parallelism over the "model" axis via shard_map.

    Activations arrive model-replicated (the attention output all-reduce
    already paid for that), so *dispatch is entirely local*: each model
    shard scatters only the tokens routed to its own E/M experts into an
    (E/M, cap, d) buffer, runs its experts, and the combine is ONE psum of
    the (T, d) partial outputs — bytes = tokens·d per layer instead of the
    full dispatch buffer. DP axes stay auto (FSDP/ZeRO untouched).
    """
    from jax.sharding import PartitionSpec as P
    from repro.distributed.api import axis_size, dp_axes

    m = cfg.moe
    b, s, d = x.shape
    n_model = axis_size("model")
    e_per = m.n_experts // n_model
    px = {k: p[k] for k in ("router", "w_gate", "w_up", "w_down")}
    dp = dp_axes()
    n_dp = 1
    for a in dp:
        n_dp *= axis_size(a)
    # fully-manual region (partial-auto shard_map inside scan+grad trips a
    # JAX sharding-roundtrip bug); FSDP weight shards are gathered
    # explicitly, which reverse-differentiates into the reduce-scatter of
    # ZeRO-3 — exactly the production schedule.
    fsdp_axis = 1 if (d % n_dp == 0 and dp) else None

    def shard_fn(pxl, xl):
        mi = jax.lax.axis_index("model")
        if fsdp_axis is not None and dp:
            pxl = dict(pxl,
                       w_gate=jax.lax.all_gather(pxl["w_gate"], dp, axis=1,
                                                 tiled=True),
                       w_up=jax.lax.all_gather(pxl["w_up"], dp, axis=1,
                                               tiled=True),
                       w_down=jax.lax.all_gather(pxl["w_down"], dp, axis=2,
                                                 tiled=True))
        bl, sl, _ = xl.shape
        t = bl * sl
        xt = xl.reshape(t, d)
        cap = _capacity(t, cfg)

        logits = xt.astype(jnp.float32) @ pxl["router"]      # (T, E)
        probs = jax.nn.softmax(logits, axis=-1)
        gate, idx = jax.lax.top_k(probs, m.top_k)
        gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
        sel = jax.nn.one_hot(idx[:, 0], m.n_experts, dtype=jnp.float32)
        aux = m.router_aux_weight * m.n_experts * jnp.sum(
            sel.mean(0) * probs.mean(0))

        flat_e = idx.T.reshape(-1)                           # (kT,) global
        oh = jax.nn.one_hot(flat_e, m.n_experts, dtype=jnp.int32)
        pos_in_e = (jnp.cumsum(oh, axis=0) * oh).sum(-1) - 1
        local_e = flat_e - mi * e_per
        keep = (local_e >= 0) & (local_e < e_per) & (pos_in_e < cap)
        le = jnp.where(keep, local_e, 0)
        pos = jnp.where(keep, pos_in_e, cap)

        xk = jnp.tile(xt, (m.top_k, 1))
        xk = jnp.where(keep[:, None], xk, 0)
        buf = jnp.zeros((e_per, cap + 1, d), xt.dtype)
        buf = buf.at[le, pos].add(xk, mode="drop")[:, :cap]

        g = jnp.einsum("ecd,edf->ecf", buf, pxl["w_gate"])
        u = jnp.einsum("ecd,edf->ecf", buf, pxl["w_up"])
        h = jax.nn.silu(g) * u
        y_buf = jnp.einsum("ecf,efd->ecd", h, pxl["w_down"])
        y_buf = jnp.pad(y_buf, ((0, 0), (0, 1), (0, 0)))

        yk = y_buf[le, pos]
        yk = jnp.where(keep[:, None], yk, 0)
        gate_k = gate.T.reshape(-1)[:, None].astype(yk.dtype)
        y = (yk * gate_k).reshape(m.top_k, t, d).sum(0)
        y = jax.lax.psum(y, "model")                        # the combine
        aux = jax.lax.pmean(aux, dp) if dp else aux
        return y.reshape(bl, sl, d), aux

    dps = dp if len(dp) != 1 else dp[0]
    w_in = (P("model", dps, None) if fsdp_axis is not None
            else P("model", None, None))
    w_down_in = (P("model", None, dps) if fsdp_axis is not None
                 else P("model", None, None))
    pspecs = {"router": P(), "w_gate": w_in, "w_up": w_in,
              "w_down": w_down_in}
    x_in = P(dps, None, None) if dp else P(None, None, None)
    y, aux = shard_map(
        shard_fn, in_specs=(pspecs, x_in), out_specs=(x_in, P()),
        axis_names=set(dp) | {"model"}, check_vma=False)(px, x)
    if m.n_shared:
        y = y + mlp(p["shared"], x.reshape(-1, d), cfg.mlp_act
                    ).reshape(b, s, d)
    return y, aux
