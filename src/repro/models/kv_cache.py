"""KV/state cache construction. Cache pytree mirrors the stack plan:
{"groups": [{"blocks": [cache_or_None per block]}]}. Blocks of kind
"nbl"/"drop" carry NO cache — NBL's KV-cache saving (paper §4.2) is
structural, and shows up directly in the dry-run memory analysis.

Three cache layouts share the block shapes:

  init_cache       monolithic per-batch cache: every sequence is at the same
                   decode position, so attention slot-validity (`kpos`) is
                   shared across the batch — shape (L, W).
  init_slot_cache  slot-indexed serving cache: the batch dim is a pool of
                   request *slots*, each at its own position, so `kpos` gains
                   the slot dim — shape (L, n_slots, W). The continuous-
                   batching engine (launch/engine.py) prefills one request at
                   a time and `assign_slot`s its cache into a free slot;
                   assignment overwrites every leaf's slot row wholesale, so
                   a recycled slot can never attend to the previous request's
                   KV. `reset_slot` explicitly scrubs a retired slot without
                   reassigning it.
  paged            (models/paging.py `init_paged_cache`) attention KV lives
                   in per-layer POOLS of fixed-size, position-aligned pages
                   — (L, n_pages, KV, page_size, hd) — addressed through a
                   host-owned per-slot page table; a request occupies only
                   the pages its tokens cover, and there is no `kpos` at all
                   (validity derives from position + the table). Non-attn
                   state keeps the slot layout inside the same tree.

Byte units of the scheduler's NBL-aware admission budgets: per-slot bytes
(`cache_bytes(cfg, 1, max_len)`, memoized — it sits in the scheduler and
benchmark hot paths) for the ring engine, and per-PAGE bytes
(`paging.page_bytes(cfg, page_size)`: one page in one caching layer) for
the paged engine. Linearizing m of K attention layers shrinks both by m/K,
which converts directly into more concurrent requests on the same HBM
(launch/scheduler.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import Block, ModelConfig


def _attn_cache_len(cfg: ModelConfig, blk: Block, max_len: int) -> int:
    if blk.window is not None:
        return min(blk.window, max_len)
    return max_len


def _block_cache(cfg: ModelConfig, blk: Block, batch: int, max_len: int,
                 stack: int, dtype, *, per_slot_pos: bool = False):
    """Returns a cache pytree for one block (leading `stack` dim if > 0).
    With ``per_slot_pos`` the attention `kpos` carries a slot (batch) dim."""
    def shp(*s):
        return (stack, *s) if stack else s

    if blk.kind == "attn":
        w = _attn_cache_len(cfg, blk, max_len)
        kv, hd = cfg.n_kv_heads, cfg.head_dim
        kpos_shape = shp(batch, w) if per_slot_pos else shp(w)
        return {
            "k": jnp.zeros(shp(batch, kv, w, hd), dtype),
            "v": jnp.zeros(shp(batch, kv, w, hd), dtype),
            "kpos": jnp.full(kpos_shape, -1, jnp.int32),
        }
    if blk.kind == "cross_attn":
        kv, hd = cfg.n_kv_heads, cfg.head_dim
        t = cfg.n_frontend_tokens
        return {
            "k": jnp.zeros(shp(batch, kv, t, hd), dtype),
            "v": jnp.zeros(shp(batch, kv, t, hd), dtype),
        }
    if blk.kind == "mamba":
        s = cfg.ssm
        di = s.d_inner(cfg.d_model)
        h = s.n_heads(cfg.d_model)
        return {
            "ssm": jnp.zeros(shp(batch, h, s.head_dim, s.d_state),
                             jnp.float32),
            "conv": jnp.zeros(shp(batch, s.conv_kernel - 1, di + 2 * s.d_state),
                              dtype),
        }
    return None  # nbl / drop: no cache


def _init(cfg: ModelConfig, batch: int, max_len: int, *, per_slot_pos: bool):
    dtype = jnp.dtype(cfg.compute_dtype)
    groups = []
    for g in cfg.stack:
        blocks = []
        for blk in g.unit:
            # shared blocks keep ONE param copy but still need one cache per
            # *invocation* of the group unit, so every block stacks g.repeat
            # caches for the scan.
            stack = g.repeat
            blocks.append(_block_cache(cfg, blk, batch, max_len, stack, dtype,
                                       per_slot_pos=per_slot_pos))
        groups.append({"blocks": blocks})
    return {"groups": groups}


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    """Monolithic cache: all sequences share one decode position."""
    return _init(cfg, batch, max_len, per_slot_pos=False)


def init_slot_cache(cfg: ModelConfig, n_slots: int, max_len: int):
    """Slot-indexed serving cache: batch dim = request slots, each with its
    own `kpos` row. Decode takes a per-slot position vector (B,)."""
    return _init(cfg, n_slots, max_len, per_slot_pos=True)


def assign_slot(slot_cache, prefill_cache, slot):
    """Write a batch=1 prefill cache into row ``slot`` of a slot cache.

    ``slot`` may be traced (the engine jits this with the slot cache
    donated). Prefill `kpos` leaves are (L, W) — shared across the
    prefill batch — and broadcast into the slot cache's (L, B, W) layout.
    """
    def one(dst, src):
        if src.ndim == dst.ndim - 1:            # kpos (L, W) -> (L, 1, W)
            src = src[:, None]
        idx = (0, slot) + (0,) * (dst.ndim - 2)
        return jax.lax.dynamic_update_slice(dst, src.astype(dst.dtype), idx)

    return jax.tree.map(one, slot_cache, prefill_cache)


def reset_slot(slot_cache, slot):
    """Invalidate row ``slot``: `kpos` -> -1 (attention slots masked) and
    every state leaf -> 0 (SSM/conv/cross-attn KV). A recycled slot then
    carries no trace of the retired request even before reassignment."""
    def one(path, leaf):
        name = str(getattr(path[-1], "key", ""))
        fill = -1 if name == "kpos" else 0
        row = jnp.full(leaf.shape[:1] + (1,) + leaf.shape[2:], fill,
                       leaf.dtype)
        idx = (0, slot) + (0,) * (leaf.ndim - 2)
        return jax.lax.dynamic_update_slice(leaf, row, idx)

    return jax.tree_util.tree_map_with_path(one, slot_cache)


@functools.lru_cache(maxsize=512)
def cache_bytes(cfg: ModelConfig, batch: int, max_len: int) -> int:
    """Analytic KV/state cache size (paper Table 21 benchmark). With
    batch=1 this is the per-slot unit of the serving admission budget.

    Memoized on (cfg, batch, max_len) — ModelConfig is a frozen (hashable)
    dataclass — because each miss runs a full `jax.eval_shape` over the
    stack and this sits in the scheduler/benchmark hot path (every Engine
    construction and every admission-budget sweep calls it)."""
    cache = jax.eval_shape(lambda: init_cache(cfg, batch, max_len))
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize
               for x in jax.tree.leaves(cache))
