"""KV/state cache construction. Cache pytree mirrors the stack plan:
{"groups": [{"blocks": [cache_or_None per block]}]}. Blocks of kind
"nbl"/"drop" carry NO cache — NBL's KV-cache saving (paper §4.2) is
structural, and shows up directly in the dry-run memory analysis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import Block, ModelConfig


def _attn_cache_len(cfg: ModelConfig, blk: Block, max_len: int) -> int:
    if blk.window is not None:
        return min(blk.window, max_len)
    return max_len


def _block_cache(cfg: ModelConfig, blk: Block, batch: int, max_len: int,
                 stack: int, dtype):
    """Returns a cache pytree for one block (leading `stack` dim if > 0)."""
    def shp(*s):
        return (stack, *s) if stack else s

    if blk.kind == "attn":
        w = _attn_cache_len(cfg, blk, max_len)
        kv, hd = cfg.n_kv_heads, cfg.head_dim
        return {
            "k": jnp.zeros(shp(batch, kv, w, hd), dtype),
            "v": jnp.zeros(shp(batch, kv, w, hd), dtype),
            "kpos": jnp.full(shp(w), -1, jnp.int32),
        }
    if blk.kind == "cross_attn":
        kv, hd = cfg.n_kv_heads, cfg.head_dim
        t = cfg.n_frontend_tokens
        return {
            "k": jnp.zeros(shp(batch, kv, t, hd), dtype),
            "v": jnp.zeros(shp(batch, kv, t, hd), dtype),
        }
    if blk.kind == "mamba":
        s = cfg.ssm
        di = s.d_inner(cfg.d_model)
        h = s.n_heads(cfg.d_model)
        return {
            "ssm": jnp.zeros(shp(batch, h, s.head_dim, s.d_state),
                             jnp.float32),
            "conv": jnp.zeros(shp(batch, s.conv_kernel - 1, di + 2 * s.d_state),
                              dtype),
        }
    return None  # nbl / drop: no cache


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    dtype = jnp.dtype(cfg.compute_dtype)
    groups = []
    for g in cfg.stack:
        blocks = []
        for blk in g.unit:
            stack = 0 if blk.shared else g.repeat
            # shared blocks still need one cache per *invocation*
            stack = g.repeat if blk.shared else stack
            blocks.append(_block_cache(cfg, blk, batch, max_len, stack, dtype))
        groups.append({"blocks": blocks})
    return {"groups": groups}


def cache_bytes(cfg: ModelConfig, batch: int, max_len: int) -> int:
    """Analytic KV/state cache size (paper Table 21 benchmark)."""
    cache = jax.eval_shape(lambda: init_cache(cfg, batch, max_len))
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize
               for x in jax.tree.leaves(cache))
