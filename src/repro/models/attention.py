"""Self/cross attention: GQA, sliding window, logit softcap, KV cache.

Full-sequence attention (train/prefill) uses an online-softmax scan over key
chunks ("flash attention in XLA"): peak memory is O(S * chunk) per head
instead of O(S^2). On TPU the same tiling is implemented as a Pallas kernel
(repro/kernels/flash_attention), validated against this path.

Decode attends a single query against a (possibly ring-buffered) KV cache.
Sliding-window layers use a ring cache of length min(window, seq): writes go
to slot pos % W and each slot remembers its absolute position (kpos), so
long_500k decodes with bounded memory on SWA archs.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed import constrain
from repro.models.layers import apply_rope, softcap

NEG_INF = -2.0e38  # large-negative for f32 mask fill


# ------------------------------------------------------------- params ------

def init_attn(key: jax.Array, cfg, *, cross: bool = False) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = d ** -0.5
    so = (h * hd) ** -0.5
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "wq": (jax.random.normal(k1, (d, h * hd)) * s).astype(dt),
        "wk": (jax.random.normal(k2, (d, kv * hd)) * s).astype(dt),
        "wv": (jax.random.normal(k3, (d, kv * hd)) * s).astype(dt),
        "wo": (jax.random.normal(k4, (h * hd, d)) * so).astype(dt),
    }


def _split_heads(x, n, hd):
    b, s, _ = x.shape
    return x.reshape(b, s, n, hd).transpose(0, 2, 1, 3)   # (B, n, S, hd)


# -------------------------------------------------- full-seq attention -----

def _chunk_mask(qpos, pb, s, chunk, causal, window):
    mask = jnp.broadcast_to(pb[None, :] >= 0, (s, chunk))  # -1 = padding
    if causal:
        mask &= qpos[:, None] >= pb[None, :]
    if window is not None:
        mask &= (qpos[:, None] - pb[None, :]) < window
    return mask


def _chunked_attention(q, k, v, qpos, kpos, *, window, cap, scale,
                       causal: bool, chunk: int):
    """Online-softmax attention with a flash-style custom backward.

    q: (B, H, S, D); k, v: (B, KV, T, D); qpos: (S,), kpos: (T,).
    Returns (B, H, S, D).

    The forward keeps only the softmax stats (m, l) and the output as
    residuals; the backward re-computes each chunk's scores and probability
    tile on the fly (dq accumulates across the chunk scan; dk/dv emit per
    chunk). Without this, reverse-mode through the chunk scan stashes an
    O(S·T) probability tensor *and* an O(S·T) mask per layer — the dominant
    HBM term of every dense train cell (see EXPERIMENTS.md §Perf).
    """
    b, h, s, d = q.shape
    t = k.shape[2]
    chunk = min(chunk, t)
    assert t % chunk == 0, (t, chunk)
    fn = functools.partial(_flash_xla, window=window, cap=cap, scale=scale,
                           causal=causal, chunk=chunk)
    return fn(q, k, v, qpos, kpos)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8, 9))
def _flash_xla(q, k, v, qpos, kpos, window, cap, scale, causal, chunk):
    out, _, _ = _flash_fwd_inner(q, k, v, qpos, kpos, window, cap, scale,
                                 causal, chunk)
    return out


def _flash_fwd_inner(q, k, v, qpos, kpos, window, cap, scale, causal, chunk):
    b, h, s, d = q.shape
    kvh, t = k.shape[1], k.shape[2]
    rep = h // kvh
    qg = q.reshape(b, kvh, rep, s, d)
    nchunk = t // chunk
    kc = k.reshape(b, kvh, nchunk, chunk, d).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(b, kvh, nchunk, chunk, d).transpose(2, 0, 1, 3, 4)
    pc = kpos.reshape(nchunk, chunk)

    # Score/probability tiles live in the compute dtype (bf16 on production
    # configs); the running max/denominator/accumulator stay float32 —
    # matching what flash-attention kernels keep in VMEM registers.
    wd = q.dtype
    neg = jnp.asarray(NEG_INF, wd)

    def body(carry, xs):
        m, l, acc = carry
        kb, vb, pb = xs
        sc = jnp.einsum("bgrsd,bgcd->bgrsc", qg, kb,
                        preferred_element_type=wd) * jnp.asarray(scale, wd)
        sc = softcap(sc, cap)
        mask = _chunk_mask(qpos, pb, s, chunk, causal, window)
        sc = jnp.where(mask[None, None, None], sc, neg)
        m_new = jnp.maximum(m, sc.max(axis=-1).astype(jnp.float32))
        p = jnp.exp(sc - m_new[..., None].astype(wd))          # wd storage
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1, dtype=jnp.float32)
        acc = acc * corr[..., None] + jnp.einsum(
            "bgrsc,bgcd->bgrsd", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32)
        return (m_new, l, acc), None

    m0 = jnp.full((b, kvh, rep, s), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kvh, rep, s), jnp.float32)
    a0 = jnp.zeros((b, kvh, rep, s, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kc, vc, pc))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(b, h, s, d).astype(q.dtype), m, l


def _flash_fwd(q, k, v, qpos, kpos, window, cap, scale, causal, chunk):
    out, m, l = _flash_fwd_inner(q, k, v, qpos, kpos, window, cap, scale,
                                 causal, chunk)
    return out, (q, k, v, qpos, kpos, out, m, l)


def _flash_bwd(window, cap, scale, causal, chunk, res, dout):
    q, k, v, qpos, kpos, out, m, l = res
    b, h, s, d = q.shape
    kvh, t = k.shape[1], k.shape[2]
    rep = h // kvh
    nchunk = t // chunk
    qg = q.reshape(b, kvh, rep, s, d).astype(jnp.float32)
    do = dout.reshape(b, kvh, rep, s, d).astype(jnp.float32)
    og = out.reshape(b, kvh, rep, s, d).astype(jnp.float32)
    kc = k.reshape(b, kvh, nchunk, chunk, d).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(b, kvh, nchunk, chunk, d).transpose(2, 0, 1, 3, 4)
    pc = kpos.reshape(nchunk, chunk)
    lsafe = jnp.maximum(l, 1e-30)
    delta = (do * og).sum(-1)                               # (b,kv,rep,s)

    def body(dq, xs):
        kb, vb, pb = xs
        sc = jnp.einsum("bgrsd,bgcd->bgrsc", qg,
                        kb.astype(jnp.float32)) * scale
        if cap is not None:
            th = jnp.tanh(sc / cap)
            sc_capped = cap * th
        else:
            th = None
            sc_capped = sc
        mask = _chunk_mask(qpos, pb, s, chunk, causal, window)
        sc_capped = jnp.where(mask[None, None, None], sc_capped, NEG_INF)
        p = jnp.exp(sc_capped - m[..., None]) / lsafe[..., None]
        dv = jnp.einsum("bgrsc,bgrsd->bgcd", p, do)
        dp = jnp.einsum("bgrsd,bgcd->bgrsc", do, vb.astype(jnp.float32))
        ds = p * (dp - delta[..., None])
        if th is not None:
            ds = ds * (1.0 - th * th)                       # through softcap
        dq = dq + jnp.einsum("bgrsc,bgcd->bgrsd", ds,
                             kb.astype(jnp.float32)) * scale
        dk = jnp.einsum("bgrsc,bgrsd->bgcd", ds, qg) * scale
        return dq, (dk, dv)

    dq0 = jnp.zeros((b, kvh, rep, s, d), jnp.float32)
    dq, (dk, dv) = jax.lax.scan(body, dq0, (kc, vc, pc))
    dq = dq.reshape(b, h, s, d).astype(q.dtype)
    dk = dk.transpose(1, 2, 0, 3, 4).reshape(b, kvh, t, d).astype(k.dtype)
    dv = dv.transpose(1, 2, 0, 3, 4).reshape(b, kvh, t, d).astype(v.dtype)
    return dq, dk, dv, None, None


_flash_xla.defvjp(_flash_fwd, _flash_bwd)


def self_attention(cfg, p: dict, x: jax.Array, *, window: Optional[int],
                   positions: jax.Array, chunk: int = 1024,
                   prefix=None) -> jax.Array:
    """Causal self-attention over x: (B, S, d) at absolute ``positions``.

    ``prefix`` serves the engine's partial (suffix-only) prefill under
    prefix sharing: a (k_pre, v_pre, kpos_pre) triple of already-cached
    prefix KV — k/v (B or 1, KV, P, hd), kpos_pre (P,) absolute key
    positions with -1 = invalid. Queries then attend [prefix ++ suffix]
    keys; causality/window stay purely positional, so suffix tokens at
    positions >= prefix length score against the shared prefix exactly as
    a full prefill would. The returned KV cache covers the SUFFIX only
    (the prefix is already paged in and never rewritten)."""
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = _split_heads(x @ p["wq"], h, hd)
    k = _split_heads(x @ p["wk"], kv, hd)
    v = _split_heads(x @ p["wv"], kv, hd)
    q = apply_rope(q, positions[None, None, :], cfg.rope_theta)
    k = apply_rope(k, positions[None, None, :], cfg.rope_theta)
    q = constrain(q, "dp", "model", None, None)
    k = constrain(k, "dp", "model", None, None)
    scale = cfg.attn_scale or hd ** -0.5
    b, s, _ = x.shape
    if prefix is None:
        kk, vv, kpos = k, v, positions
    else:
        k_pre, v_pre, kpos_pre = prefix
        k_pre = jnp.broadcast_to(k_pre, (b,) + k_pre.shape[1:])
        v_pre = jnp.broadcast_to(v_pre, (b,) + v_pre.shape[1:])
        kk = jnp.concatenate([k_pre.astype(k.dtype), k], axis=2)
        vv = jnp.concatenate([v_pre.astype(v.dtype), v], axis=2)
        kpos = jnp.concatenate([kpos_pre.astype(jnp.int32), positions])
        # total key length is a sum of page multiples, not a power of two:
        # one chunk when it fits, else the largest power-of-two divisor so
        # the scan tiles evenly (t & -t alone would degrade to 1-key chunks
        # for odd unbucketed suffixes)
        t = kk.shape[2]
        chunk = t if t <= chunk else min(chunk, t & -t)
    out = _chunked_attention(q, kk, vv, positions, kpos,
                             window=window, cap=cfg.attn_logit_softcap,
                             scale=scale, causal=True, chunk=chunk)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, h * hd)
    return out @ p["wo"], (k, v)


def cross_attention(cfg, p: dict, x: jax.Array, enc_kv=None,
                    enc: Optional[jax.Array] = None) -> jax.Array:
    """Cross-attention over frontend embeddings. x: (B,S,d); enc: (B,T,d)."""
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    b, s, _ = x.shape
    q = _split_heads(x @ p["wq"], h, hd)
    if enc_kv is None:
        k = _split_heads(enc @ p["wk"], kvh, hd)
        v = _split_heads(enc @ p["wv"], kvh, hd)
    else:
        k, v = enc_kv
    t = k.shape[2]
    scale = cfg.attn_scale or hd ** -0.5
    # pad encoder K/V to a chunk multiple; padded slots get kpos=-1 and are
    # masked inside the online-softmax scan. The cache keeps the unpadded
    # K/V (decode re-pads).
    chunk = min(1024, t)
    padn = (-t) % chunk
    kp, vp = k, v
    if padn:
        kp = jnp.pad(k, ((0, 0), (0, 0), (0, padn), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, 0), (0, padn), (0, 0)))
    qpos = jnp.zeros((s,), jnp.int32)
    kpos = jnp.concatenate([jnp.zeros((t,), jnp.int32),
                            jnp.full((padn,), -1, jnp.int32)])
    out = _chunked_attention(q, kp, vp, qpos, kpos, window=None,
                             cap=cfg.attn_logit_softcap, scale=scale,
                             causal=False, chunk=chunk)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, h * hd)
    return out @ p["wo"], (k, v)


# ------------------------------------------------------------- decode ------

def decode_attention(cfg, p: dict, x: jax.Array, cache: dict, pos: jax.Array,
                     *, window: Optional[int]) -> tuple[jax.Array, dict]:
    """Single-token decode. x: (B, 1, d); cache: {k, v: (B,KV,W,hd), kpos}.

    Two position modes share the kernel:
      scalar pos, kpos (W,)    — monolithic batch: every sequence decodes at
                                 the same absolute position (generate()).
      vector pos (B,), kpos (B,W) — slot cache: each batch row is a serving
                                 slot at its own position. Writes go to
                                 slot-local ring index pos[b] % W via a
                                 one-hot select, and validity/window masks
                                 are per-slot, so retired/fresh slots in one
                                 batched step never cross-attend.
    """
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    b = x.shape[0]
    w = cache["k"].shape[2]
    per_slot = cache["kpos"].ndim == 2
    pos = jnp.asarray(pos, jnp.int32)
    if per_slot and pos.ndim == 0:
        pos = jnp.full((b,), 0, jnp.int32) + pos
    assert per_slot == (pos.ndim == 1), (cache["kpos"].shape, pos.shape)

    q = _split_heads(x @ p["wq"], h, hd)
    k_new = _split_heads(x @ p["wk"], kvh, hd)
    v_new = _split_heads(x @ p["wv"], kvh, hd)
    # rope positions: (1,1,1) broadcasts over (B,H,1,hd); per-slot (B,1,1)
    # gives every slot its own rotation.
    ppos = pos[:, None, None] if per_slot else pos[None, None, None]
    q = apply_rope(q, ppos, cfg.rope_theta)
    k_new = apply_rope(k_new, ppos, cfg.rope_theta)

    if per_slot:
        slot = (pos % w).astype(jnp.int32)                      # (B,)
        # batched scatter: touch only each row's written W-index (a one-hot
        # select would read+rewrite the whole cache every step)
        rows = jnp.arange(b)
        k = cache["k"].at[rows, :, slot].set(
            k_new[:, :, 0].astype(cache["k"].dtype))
        v = cache["v"].at[rows, :, slot].set(
            v_new[:, :, 0].astype(cache["v"].dtype))
        kpos = cache["kpos"].at[rows, slot].set(pos)            # (B, W)
        pos_b = pos[:, None]                                    # (B, 1)
    else:
        slot = (pos % w).astype(jnp.int32)
        k = jax.lax.dynamic_update_slice(
            cache["k"], k_new.astype(cache["k"].dtype), (0, 0, slot, 0))
        v = jax.lax.dynamic_update_slice(
            cache["v"], v_new.astype(cache["v"].dtype), (0, 0, slot, 0))
        kpos = jax.lax.dynamic_update_slice(
            cache["kpos"], pos[None], (slot,))
        pos_b = pos

    rep = h // kvh
    qg = q.reshape(b, kvh, rep, hd)
    sc = jnp.einsum("bgrd,bgtd->bgrt", qg, k,
                    preferred_element_type=jnp.float32)
    sc = sc * (cfg.attn_scale or hd ** -0.5)
    sc = softcap(sc, cfg.attn_logit_softcap)
    valid = (kpos >= 0) & (kpos <= pos_b)
    if window is not None:
        valid &= (pos_b - kpos) < window
    mask = valid[:, None, None, :] if per_slot else valid[None, None, None, :]
    sc = jnp.where(mask, sc, NEG_INF)
    pr = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bgrt,bgtd->bgrd", pr.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    out = out.reshape(b, 1, h * hd).astype(x.dtype)
    return out @ p["wo"], {"k": k, "v": v, "kpos": kpos}


def decode_paged_attention(cfg, p: dict, x: jax.Array, cache: dict,
                           pos: jax.Array, page_tbl: jax.Array, *,
                           window: Optional[int]) -> tuple[jax.Array, dict]:
    """Single-token decode against the paged KV layout (models/paging.py).

    x: (B, 1, d); cache: {k_pages, v_pages: (n_pages, KV, page_size, hd)};
    pos: (B,) per-slot absolute position of the token being decoded, with
    -1 marking an INACTIVE row riding along in the batch (a masked or empty
    slot); page_tbl: (B, n_lpages) int32 physical page per logical page,
    -1 = unallocated. The new K/V is scattered into page pos//page_size at
    offset pos%page_size under an EXPLICIT write mask: rows with pos < 0 or
    an unallocated table entry are routed to the out-of-bounds page index
    and dropped (``mode="drop"``), so a masked row can never write into a
    page another slot legitimately owns. Then the paged-attention kernel
    (Pallas on TPU, XLA gather elsewhere) attends positions [0, pos] with
    window/softcap masking — masked rows get length pos+1 = 0, every key
    masked, and their (discarded) output stays finite. Pages are position-
    aligned so validity needs no kpos array: stale tokens a recycled page
    carries sit at positions >= the new owner's length and are masked until
    overwritten.
    """
    from repro.kernels.paged_attention import paged_decode

    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    b = x.shape[0]
    n_pages, _, page_size, _ = cache["k_pages"].shape
    pos = jnp.asarray(pos, jnp.int32)
    assert pos.ndim == 1, pos.shape

    q = _split_heads(x @ p["wq"], h, hd)
    k_new = _split_heads(x @ p["wk"], kvh, hd)
    v_new = _split_heads(x @ p["wv"], kvh, hd)
    ppos = pos[:, None, None]
    q = apply_rope(q, ppos, cfg.rope_theta)
    k_new = apply_rope(k_new, ppos, cfg.rope_theta)

    rows = jnp.arange(b)
    live = pos >= 0                                          # explicit mask
    safe_pos = jnp.where(live, pos, 0)
    pid = page_tbl[rows, safe_pos // page_size]              # (B,)
    pid = jnp.where(live & (pid >= 0), pid, n_pages)         # dead -> OOB: drop
    off = safe_pos % page_size
    k_pages = cache["k_pages"].at[pid, :, off].set(
        k_new[:, :, 0].astype(cache["k_pages"].dtype), mode="drop")
    v_pages = cache["v_pages"].at[pid, :, off].set(
        v_new[:, :, 0].astype(cache["v_pages"].dtype), mode="drop")

    rep = h // kvh
    qg = q.reshape(b, kvh, rep, hd)
    out = paged_decode(qg, k_pages, v_pages, page_tbl, pos + 1,
                       scale=cfg.attn_scale or hd ** -0.5, window=window,
                       softcap=cfg.attn_logit_softcap)
    out = out.reshape(b, 1, h * hd).astype(x.dtype)
    return out @ p["wo"], {"k_pages": k_pages, "v_pages": v_pages}


def fused_paged_attention(cfg, p: dict, x: jax.Array, cache: dict,
                          row_pos: jax.Array, row_len: jax.Array,
                          page_tbl: jax.Array, *,
                          window: Optional[int]) -> tuple[jax.Array, dict]:
    """Mixed-row step attention: decode rows AND prefill-chunk rows in ONE
    dispatch against the shared paged KV layout.

    x: (B, W, d) — each batch row carries up to W tokens of new work this
    step (a decode row uses 1, a chunk row uses its page-aligned span);
    row_pos: (B,) absolute position of each row's FIRST token;
    row_len: (B,) valid tokens this step (0 = inactive row — an empty slot,
    a speculative slot stepped separately, or pure padding);
    page_tbl: (B, n_lpages) as in :func:`decode_paged_attention`.

    Token t of row b sits at absolute position row_pos[b] + t. All valid
    tokens are scattered into their pages first (explicit write mask: the
    invalid tail of short rows routes to the out-of-bounds page and drops),
    then token t attends positions [0, row_pos[b] + t] of its slot's
    logical sequence through ``kernels.paged_mixed`` — write-before-attend
    plus the per-query causal mask gives exact in-chunk causality, the
    same semantics as a partial prefill of the span. On the XLA serving
    path that is ONE page gather per slot feeding a dense masked softmax
    (the W queries share the gathered keys as a GEMM — prefill-like cost
    for a wide chunk row); on TPU the queries run as B*W virtual decode
    rows through the Mosaic kernel, whose BlockSpec indexing makes the
    per-row gather free. Invalid positions (row_len 0 rows, short-row
    tails) get every key masked and a finite, discarded output. Within a
    step no two valid tokens collide on a (page, offset) pair: tokens of
    one row are consecutive positions, and distinct rows own distinct
    pages.
    """
    from repro.kernels.paged_attention import paged_mixed

    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    b, w, _ = x.shape
    n_pages, _, page_size, _ = cache["k_pages"].shape
    row_pos = jnp.asarray(row_pos, jnp.int32)
    row_len = jnp.asarray(row_len, jnp.int32)

    q = _split_heads(x @ p["wq"], h, hd)                     # (B, h, W, hd)
    k_new = _split_heads(x @ p["wk"], kvh, hd)
    v_new = _split_heads(x @ p["wv"], kvh, hd)
    tpos = row_pos[:, None] + jnp.arange(w, dtype=jnp.int32)[None, :]
    valid = jnp.arange(w, dtype=jnp.int32)[None, :] < row_len[:, None]
    ppos = tpos[:, None, :]                   # (B,1,W) broadcasts over heads
    q = apply_rope(q, ppos, cfg.rope_theta)
    k_new = apply_rope(k_new, ppos, cfg.rope_theta)

    # scatter all B*W tokens; the explicit write mask routes the invalid
    # tail (and rows over unallocated table entries) out of bounds
    flat_valid = valid.reshape(-1)                           # (B*W,)
    flat_pos = jnp.where(valid, tpos, 0).reshape(-1)
    rows = jnp.repeat(jnp.arange(b), w)
    pid = page_tbl[rows, flat_pos // page_size]
    pid = jnp.where(flat_valid & (pid >= 0), pid, n_pages)
    off = flat_pos % page_size
    k_flat = k_new.transpose(0, 2, 1, 3).reshape(b * w, kvh, hd)
    v_flat = v_new.transpose(0, 2, 1, 3).reshape(b * w, kvh, hd)
    k_pages = cache["k_pages"].at[pid, :, off].set(
        k_flat.astype(cache["k_pages"].dtype), mode="drop")
    v_pages = cache["v_pages"].at[pid, :, off].set(
        v_flat.astype(cache["v_pages"].dtype), mode="drop")

    rep = h // kvh
    qg = q.reshape(b, kvh, rep, w, hd)
    out = paged_mixed(qg, k_pages, v_pages, page_tbl, row_pos, row_len,
                      scale=cfg.attn_scale or hd ** -0.5, window=window,
                      softcap=cfg.attn_logit_softcap)
    out = (out.transpose(0, 3, 1, 2, 4).reshape(b, w, h * hd)
           .astype(x.dtype))
    return out @ p["wo"], {"k_pages": k_pages, "v_pages": v_pages}


def decode_cross_attention(cfg, p: dict, x: jax.Array, cache: dict):
    """Cross-attn during decode: static encoder KV from prefill cache."""
    out, _ = cross_attention(cfg, p, x, enc_kv=(cache["k"], cache["v"]))
    return out, cache
