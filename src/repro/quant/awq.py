"""Activation-aware weight quantization (AWQ, Lin et al. 2024) — the
post-training quantization the paper composes with NBL at 70B (§4.3,
App. E.6).

Weight-only symmetric int-N with per-output-channel, per-group scales.
The AWQ trick: scale salient input channels up before rounding
(w' = w·diag(s), x' = x/s) so their relative rounding error shrinks;
s_c = E|x_c|^α with α grid-searched per tensor against the *true expected
output error*  E‖(Ŵ−W)x‖² = Tr((Ŵ−W) C_xx (Ŵ−W)ᵀ) — we already have C_xx
from the NBL calibration moments, so AWQ here reuses the same single
calibration pass (the "deeper algorithmic integration" the paper's §5
anticipates).

Quantization is simulated (quantize→dequantize in the stored dtype), the
standard PTQ evaluation practice; byte savings are reported analytically.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig

# weight leaves eligible for PTQ (big matmuls only; norms/bias/router stay)
_QUANT_NAMES = ("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down",
                "in_proj", "out_proj", "embed", "head", "w")


def quantize_tensor(w: np.ndarray, bits: int = 4, group: int = 128,
                    s: Optional[np.ndarray] = None
                    ) -> tuple[np.ndarray, np.ndarray]:
    """Symmetric int-N along the LAST axis in groups. w: (..., d_in).
    ``s``: optional per-input-channel AWQ scale (d_in,). Returns
    (q int8-stored, scales) with dequant = (q · scales) / s."""
    wd = w.astype(np.float64)
    if s is not None:
        wd = wd * s                              # scale salient channels up
    d_in = wd.shape[-1]
    g = min(group, d_in)
    pad = (-d_in) % g
    if pad:
        wd = np.concatenate([wd, np.zeros((*wd.shape[:-1], pad))], -1)
    gshape = (*wd.shape[:-1], wd.shape[-1] // g, g)
    wg = wd.reshape(gshape)
    qmax = 2 ** (bits - 1) - 1
    scales = np.abs(wg).max(-1, keepdims=True) / qmax
    scales = np.maximum(scales, 1e-12)
    q = np.clip(np.round(wg / scales), -qmax - 1, qmax).astype(np.int8)
    return q, scales


def dequantize(q: np.ndarray, scales: np.ndarray, d_in: int,
               s: Optional[np.ndarray] = None) -> np.ndarray:
    w = (q.astype(np.float64) * scales).reshape(*q.shape[:-2], -1)[..., :d_in]
    if s is not None:
        w = w / s
    return w


def _expected_err(w: np.ndarray, w_hat: np.ndarray,
                  cxx_diag: Optional[np.ndarray]) -> float:
    """E‖(Ŵ−W)x‖² with diagonal C_xx approx (exact for the α ranking)."""
    d = w_hat - w
    if cxx_diag is None:
        return float((d * d).sum())
    return float((d * d * cxx_diag.reshape((1,) * (d.ndim - 1) + (-1,)))
                 .sum())


def awq_scale_search(w: np.ndarray, act_mag: Optional[np.ndarray], *,
                     bits: int = 4, group: int = 128,
                     alphas=(0.0, 0.25, 0.5, 0.75, 1.0)
                     ) -> tuple[np.ndarray, float, float]:
    """Grid-search α for s = act_mag^α. Returns (best_w_hat, α*, err)."""
    cxx_diag = None if act_mag is None else act_mag ** 2
    best = (None, 0.0, np.inf)
    cand = alphas if act_mag is not None else (0.0,)
    for a in cand:
        s = None
        if act_mag is not None and a > 0:
            s = np.maximum(act_mag, 1e-8) ** a
            s = s / s.mean()                     # keep overall magnitude
        q, scales = quantize_tensor(w, bits, group, s)
        w_hat = dequantize(q, scales, w.shape[-1], s)
        err = _expected_err(w, w_hat, cxx_diag)
        if err < best[2]:
            best = (w_hat, a, err)
    return best


@dataclasses.dataclass
class QuantReport:
    bits: int
    n_quantized: int
    fp_bytes: int
    q_bytes: int
    alphas: dict
    mean_rel_err: float

    def summary(self) -> str:
        return (f"AWQ int{self.bits}: {self.n_quantized} tensors, "
                f"{self.fp_bytes / 2**20:.1f} MiB -> "
                f"{self.q_bytes / 2**20:.1f} MiB "
                f"({self.fp_bytes / max(self.q_bytes, 1):.2f}x), "
                f"mean rel err {self.mean_rel_err:.4f}")


def quantize_model(cfg: ModelConfig, params: dict, *, bits: int = 4,
                   group: int = 128,
                   act_mags: Optional[dict] = None) -> tuple[dict, QuantReport]:
    """Simulated AWQ over all eligible weight leaves. ``act_mags`` maps a
    leaf path-string to E|x| per input channel (from calibration moments;
    None → plain round-to-nearest groupwise, the RTN baseline)."""
    paths, treedef = jax.tree_util.tree_flatten_with_path(params)
    out, alphas, errs = [], {}, []
    n_q = fp_b = q_b = 0
    for path, leaf in paths:
        names = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        name = names[-1]
        key = "/".join(names)
        arr = np.asarray(leaf)
        if name in _QUANT_NAMES and arr.ndim >= 2 and arr.shape[-1] >= 32:
            mag = None if act_mags is None else act_mags.get(key)
            w_hat, a, err = awq_scale_search(arr, mag, bits=bits,
                                             group=group)
            alphas[key] = a
            denom = float((arr.astype(np.float64) ** 2).sum()) or 1.0
            errs.append(err / denom if mag is None else
                        float(((w_hat - arr) ** 2).sum()) / denom)
            n_q += 1
            fp_b += arr.size * arr.dtype.itemsize
            q_b += arr.size * bits // 8 + (arr.size // group) * 2
            out.append(jax.numpy.asarray(w_hat, leaf.dtype))
        else:
            out.append(leaf)
    rep = QuantReport(bits=bits, n_quantized=n_q, fp_bytes=fp_b,
                      q_bytes=q_b, alphas=alphas,
                      mean_rel_err=float(np.mean(errs)) if errs else 0.0)
    return jax.tree_util.tree_unflatten(treedef, out), rep
