from repro.quant.awq import (  # noqa: F401
    awq_scale_search, dequantize, quantize_model, quantize_tensor,
)
