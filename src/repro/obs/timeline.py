"""Step timeline: a bounded ring buffer of per-``step()`` engine records.

Where the registry answers "how much, total" and the tracer answers "what
happened to request X", the timeline answers "what did the ENGINE do on
each of the last N steps": decode batch size, chunk tokens prefilled,
allocator occupancy and the refcount distribution (how shared the pool
is), PrefixIndex size and cumulative LRU evictions, and the host-vs-
dispatch wall-time split — the breakdown a fused-step optimization pass
has to beat.

Records are plain dataclasses appended by the engine's step loop (one
producer); ``snapshot()`` copies the ring under a lock so a server scrape
never reads a half-written deque. Capacity is fixed at construction
(default 1024 steps) so a long-running server's memory stays bounded.
"""
from __future__ import annotations

import threading
from dataclasses import asdict, dataclass, field
from typing import Optional


@dataclass
class StepRecord:
    step: int                    # engine-lifetime step ordinal
    t: float                     # time.monotonic at step start
    host_s: float                # full step wall time
    dispatch_s: float            # decode-jit call + logits device->host
    n_decoding: int              # slots in the batched decode
    n_chunking: int              # slots mid-prompt (chunked prefill)
    n_queued: int                # scheduler depth after admission
    tokens_emitted: int          # step() return value
    prefill_tokens: int          # valid prompt tokens prefilled this step
    chunk_tokens: int            # subset of prefill_tokens via _chunk_step
    pages_in_use: int = 0
    pages_free: int = 0
    refcounts: dict = field(default_factory=dict)  # refcount -> n_pages
    prefix_entries: int = 0
    evictions_cum: int = 0       # PrefixIndex LRU evictions, lifetime
    preemptions_cum: int = 0
    # fused plan->execute->commit pipeline (PR 10): per-step budget
    # pressure and the lifetime dispatch split
    tokens_planned: int = 0      # StepPlan.tokens_planned (0 on legacy)
    budget_utilization: float = 0.0  # planned/budget; 0.0 when unbounded
    fused_dispatches_cum: int = 0    # fused-step jit launches, lifetime
    legacy_dispatches_cum: int = 0   # legacy decode+chunk jit launches


class StepTimeline:
    """Fixed-capacity ring of :class:`StepRecord`."""

    def __init__(self, capacity: int = 1024):
        if capacity < 1:
            raise ValueError(f"timeline capacity must be >= 1, "
                             f"got {capacity}")
        self.capacity = int(capacity)
        self._lock = threading.Lock()
        self._ring: list = []
        self._head = 0                       # next write index once full
        self.total_steps = 0                 # lifetime appends

    def append(self, rec: StepRecord) -> None:
        with self._lock:
            if len(self._ring) < self.capacity:
                self._ring.append(rec)
            else:
                self._ring[self._head] = rec
                self._head = (self._head + 1) % self.capacity
            self.total_steps += 1

    def snapshot(self) -> list:
        """Records oldest-first (a consistent copy)."""
        with self._lock:
            return self._ring[self._head:] + self._ring[:self._head]

    def snapshot_dicts(self) -> list:
        return [asdict(r) for r in self.snapshot()]

    def last(self) -> Optional[StepRecord]:
        with self._lock:
            if not self._ring:
                return None
            return self._ring[(self._head - 1) % len(self._ring)]

    def __len__(self) -> int:
        with self._lock:
            return len(self._ring)
