"""Engine-wide observability: metrics registry + request traces + timeline.

Three complementary views over one serving engine, all dependency-free and
HOST-side only (recording never adds a device dispatch):

``MetricsRegistry`` (registry.py)
    Counters / gauges / histograms with fixed log-spaced latency buckets,
    labeled by engine mode and NBL-m, snapshot-consistent under the
    AsyncEngine step-loop thread. Rendered as JSON or Prometheus text
    exposition (the server's ``metrics`` op).
``Tracer`` (trace.py)
    Per-request lifecycle spans (queued -> [chunk x N | prefill] ->
    decoding -> terminal, with preempt/suspend/first-token instants) plus
    an engine step track, exportable as JSONL or a Chrome-trace/Perfetto
    file.
``StepTimeline`` (timeline.py)
    Ring buffer of per-``step()`` records: decode batch size, chunk tokens,
    allocator occupancy + refcount distribution, PrefixIndex size and LRU
    evictions, host-vs-dispatch wall split.

:class:`Observability` bundles the three behind the HOOK surface the
engine calls (``on_submit`` / ``on_admit`` / ``on_step`` / ...). The
engine holds ``obs=None`` by default and guards every hook call with one
``is not None`` branch, so the disabled hot path pays a single branch and
nothing else. ``python -m repro.launch.server`` enables it by default
(``--no-obs`` to disable); see docs/observability.md for the metric
catalog and span schema.

This module (and only this module) owns ``time.perf_counter`` — engine
code uses the monotonic lifecycle clock, and scripts/ci.sh lints that no
new raw perf_counter call sites appear outside ``obs/``.
"""
from __future__ import annotations

import time
from contextlib import nullcontext
from typing import Optional

from repro.obs.registry import (  # noqa: F401  (re-exported)
    LATENCY_BUCKETS, Counter, Gauge, Histogram, MetricsRegistry,
)
from repro.obs.timeline import StepRecord, StepTimeline  # noqa: F401
from repro.obs.trace import RequestTrace, Span, Tracer   # noqa: F401

clock = time.perf_counter       # the one sanctioned high-res timer


class Observability:
    """The hook layer the engine drives; owns registry + tracer + timeline.

    Default level records everything except jax profiler annotations
    (``trace_annotations=True`` wraps the prefill/decode jit calls in
    ``jax.profiler.TraceAnnotation`` so device profiles line up with the
    host timeline). ``trace=False`` / ``timeline_capacity=0`` shed the
    corresponding subsystem; the registry always exists.
    """

    def __init__(self, *, labels: Optional[dict] = None, trace: bool = True,
                 timeline_capacity: int = 1024,
                 trace_annotations: bool = False, max_traces: int = 4096):
        self.registry = MetricsRegistry(labels=labels)
        self.tracer = Tracer(max_traces=max_traces) if trace else None
        self.timeline = StepTimeline(timeline_capacity) \
            if timeline_capacity else None
        self.trace_annotations = bool(trace_annotations)
        self._null = nullcontext()       # shared: annotate() allocates 0
        self._last_evictions = 0         # delta base for the counter
        self._last_fused = 0             # dispatch-counter delta bases:
        self._last_legacy = 0            # registry == engine, end-of-step

        r = self.registry
        # --- metric catalog (docs/observability.md) --- counters
        self.submitted = r.counter(
            "nbl_requests_submitted_total", "requests accepted into the queue")
        self.admitted = r.counter(
            "nbl_requests_admitted_total",
            "admissions into a slot (re-admission after preemption counts)")
        self.finished = r.counter(
            "nbl_requests_finished_total", "requests retired EOS/max-token")
        self.rejected = r.counter(
            "nbl_requests_rejected_total", "reject-with-error drops")
        self.cancelled = r.counter(
            "nbl_requests_cancelled_total", "cancel() terminal retirements")
        self.tokens = r.counter(
            "nbl_tokens_emitted_total",
            "every generated token emission (preemption replays re-count)")
        self.tokens_discarded = r.counter(
            "nbl_tokens_discarded_total",
            "generated tokens discarded by preemption restarts")
        self.prefills = r.counter(
            "nbl_prefills_total", "prefill jit dispatches (chunks count)")
        self.prefill_tokens = r.counter(
            "nbl_prefill_tokens_total", "valid (unpadded) tokens prefilled")
        self.decode_steps = r.counter(
            "nbl_decode_steps_total", "batched decode dispatches")
        self.chunks = r.counter(
            "nbl_chunks_total", "chunked-prefill chunks processed")
        self.chunk_tokens = r.counter(
            "nbl_chunk_tokens_total", "prompt tokens prefilled via chunks")
        self.interleaved = r.counter(
            "nbl_interleaved_decode_steps_total",
            "decode steps emitted while a prompt was mid-chunking")
        self.preemptions = r.counter(
            "nbl_preemptions_total", "mid-flight preemption restarts")
        self.evictions = r.counter(
            "nbl_prefix_evictions_total", "PrefixIndex LRU pages evicted")
        self.prefix_hits = r.counter(
            "nbl_prefix_hits_total", "admissions served a cached prefix")
        self.shared_tokens = r.counter(
            "nbl_shared_prompt_tokens_total",
            "prompt tokens skipped via prefix sharing")
        self.spec_bursts = r.counter(
            "nbl_spec_bursts_total",
            "speculative draft-and-verify bursts (one draft scan + one "
            "verifier cache-extend each)")
        self.spec_draft_tokens = r.counter(
            "nbl_spec_draft_tokens_total",
            "draft tokens proposed by speculative bursts")
        self.spec_accepted = r.counter(
            "nbl_spec_accepted_tokens_total",
            "draft-origin tokens accepted and actually emitted")
        self.spec_tokens = r.counter(
            "nbl_spec_tokens_total",
            "tokens emitted by speculative bursts (accepted + corrections)")
        self.fused_dispatches = r.counter(
            "nbl_fused_dispatches_total",
            "fused-step jit launches (ONE per fused step with work)")
        self.legacy_dispatches = r.counter(
            "nbl_legacy_dispatches_total",
            "legacy step-path jit launches (batched decode + chunk "
            "prefills — the dispatches the fused jit replaces)")
        # --- gauges
        self.g_queue = r.gauge("nbl_queue_depth", "scheduler queue length")
        self.g_active = r.gauge("nbl_slots_active", "occupied slots")
        self.g_slots = r.gauge("nbl_slots_total", "engine slot count")
        self.g_pages_used = r.gauge("nbl_pages_in_use", "allocator occupancy")
        self.g_pages_free = r.gauge("nbl_pages_free", "allocator free pages")
        self.g_prefix = r.gauge("nbl_prefix_index_entries",
                                "PrefixIndex published pages")
        self.g_budget_util = r.gauge(
            "nbl_step_budget_utilization",
            "last step's planned tokens / step_tokens budget "
            "(0.0 when unbudgeted or on the legacy path)")
        # --- histograms (fixed log-spaced latency buckets)
        self.h_ttft = r.histogram("nbl_ttft_seconds",
                                  "submit -> first token")
        self.h_latency = r.histogram("nbl_request_latency_seconds",
                                     "submit -> terminal")
        self.h_queue_delay = r.histogram("nbl_queue_delay_seconds",
                                         "submit -> admission")
        self.h_step_host = r.histogram("nbl_step_host_seconds",
                                       "full step() wall time")
        self.h_step_dispatch = r.histogram(
            "nbl_step_dispatch_seconds",
            "decode jit call + logits device->host inside step()")

    # ------------------------------------------------------------- hooks --

    def bind(self, **labels) -> None:
        self.registry.bind(**labels)

    def annotate(self, name: str):
        """Context manager around a jit call site: a jax profiler
        TraceAnnotation when enabled (device profile rows line up with the
        host timeline), else a no-op."""
        if self.trace_annotations:
            from jax.profiler import TraceAnnotation
            return TraceAnnotation(name)
        return self._null

    def on_submit(self, req, queue_depth: int) -> None:
        self.submitted.inc()
        self.g_queue.set(queue_depth)
        if self.tracer:
            self.tracer.begin(req.rid, "queued", t=req.t_submit)

    def on_reject(self, req, now: float) -> None:
        self.rejected.inc()
        if self.tracer:
            self.tracer.terminate(req.rid, "rejected", t=now)

    def on_admit(self, req, now: float, chunked: bool) -> None:
        self.admitted.inc()
        self.h_queue_delay.observe(max(0.0, now - req.t_submit))
        if self.tracer:
            if not self.tracer.has_open(req.rid, "queued"):
                # direct Scheduler.submit bypassed the traced submit path:
                # synthesize the queued span so the lifecycle stays whole
                self.tracer.begin(req.rid, "queued", t=req.t_submit)
            self.tracer.end(req.rid, "queued", t=now)
            if not chunked:
                self.tracer.begin(req.rid, "prefill", t=now)

    def on_prefill_done(self, req, now: float, n_tokens: int) -> None:
        """Non-chunked admission prefill completed; decoding begins."""
        if self.tracer:
            self.tracer.end(req.rid, "prefill", t=now, tokens=n_tokens)
            self.tracer.begin(req.rid, "decoding", t=now)

    def on_chunk(self, req, t0: float, t1: float, start: int, end: int,
                 final: bool) -> None:
        self.chunks.inc()
        self.chunk_tokens.inc(end - start)
        if self.tracer:
            self.tracer.begin(req.rid, "chunk", t=t0, start=start)
            self.tracer.end(req.rid, "chunk", t=t1, end=end)
            if final:
                self.tracer.begin(req.rid, "decoding", t=t1)

    def on_suspend(self, req, now: float) -> None:
        if self.tracer:
            self.tracer.instant(req.rid, "suspend", t=now)

    def on_token(self, req, first: bool, now: float) -> None:
        self.tokens.inc()
        if first:
            self.h_ttft.observe(max(0.0, now - req.t_submit))
            if self.tracer:
                self.tracer.instant(req.rid, "first_token", t=now)

    def on_retire(self, req, now: float) -> None:
        self.finished.inc()
        self.h_latency.observe(max(0.0, now - req.t_submit))
        if self.tracer:
            self.tracer.end(req.rid, "decoding", t=now,
                            tokens=len(req.tokens))
            self.tracer.terminate(req.rid, "retired", t=now)

    def on_cancel(self, req, now: float) -> None:
        self.cancelled.inc()
        if self.tracer:
            self.tracer.terminate(req.rid, "cancelled", t=now)

    def on_preempt(self, req, now: float, n_discarded: int) -> None:
        self.preemptions.inc()
        self.tokens_discarded.inc(n_discarded)
        if self.tracer:
            # whatever was open (decoding; chunking slots close their chunk
            # spans every step) ends here, and the request re-queues
            self.tracer.end(req.rid, "decoding", t=now)
            self.tracer.instant(req.rid, "preempt", t=now)
            self.tracer.begin(req.rid, "queued", t=now)

    def on_prefix_hit(self, req, n_shared_tokens: int) -> None:
        self.prefix_hits.inc()
        self.shared_tokens.inc(n_shared_tokens)

    def on_spec_burst(self, req, t0: float, t1: float, gamma: int,
                      n_accepted: int, n_emitted: int) -> None:
        """One speculative draft-and-verify burst for ``req``: γ draft
        tokens proposed, ``n_accepted`` of them emitted (post-truncation —
        tokens past max_new/EOS never count) plus the verifier's
        correction for ``n_emitted`` total. Fired BEFORE the burst's
        token emissions so the span precedes any terminal transition the
        final token triggers."""
        self.spec_bursts.inc()
        self.spec_draft_tokens.inc(gamma)
        self.spec_accepted.inc(n_accepted)
        self.spec_tokens.inc(n_emitted)
        if self.tracer:
            # request tracks are FLAT (validate() forbids overlap), so the
            # burst is spliced into the decoding span rather than nested:
            # decoding ends at burst start and reopens at burst end — the
            # reopened span is what retire/preempt later closes
            self.tracer.end(req.rid, "decoding", t=t0)
            self.tracer.begin(req.rid, "spec", t=t0, gamma=gamma)
            self.tracer.end(req.rid, "spec", t=t1, accepted=n_accepted,
                            emitted=n_emitted)
            self.tracer.begin(req.rid, "decoding", t=t1)

    def on_prefill(self, n_tokens: int) -> None:
        self.prefills.inc()
        self.prefill_tokens.inc(n_tokens)

    def on_step(self, engine, *, t0: float, t1: float, dispatch_s: float,
                n_decoding: int, n_chunking: int, tokens_emitted: int,
                prefill_tokens: int, chunk_tokens: int,
                tokens_planned: int = 0,
                budget_utilization: float = 0.0) -> None:
        """End-of-step rollup: counters, gauges, step histograms, the
        engine trace track, and one StepRecord. Reads only host state."""
        host_s = t1 - t0
        self.h_step_host.observe(host_s)
        if n_decoding:
            self.decode_steps.inc()
            self.h_step_dispatch.observe(dispatch_s)
            if n_chunking:
                self.interleaved.inc()
        # dispatch-split counters mirror the engine's lifetime counts via
        # one end-of-step delta (the evictions pattern): registry ==
        # engine exactly, wherever inside the step the dispatch happened
        fused_cum = getattr(engine, "n_fused_dispatches", 0)
        legacy_cum = getattr(engine, "n_legacy_dispatches", 0)
        self.fused_dispatches.inc(fused_cum - self._last_fused)
        self.legacy_dispatches.inc(legacy_cum - self._last_legacy)
        self._last_fused, self._last_legacy = fused_cum, legacy_cum
        self.g_budget_util.set(budget_utilization)
        n_queued = len(engine.scheduler)
        self.g_queue.set(n_queued)
        self.g_active.set(len(engine.active_slots))
        self.g_slots.set(engine.n_slots)
        rec = StepRecord(
            step=(self.timeline.total_steps
                  if self.timeline is not None else 0),
            t=t0, host_s=host_s, dispatch_s=dispatch_s,
            n_decoding=n_decoding, n_chunking=n_chunking, n_queued=n_queued,
            tokens_emitted=tokens_emitted, prefill_tokens=prefill_tokens,
            chunk_tokens=chunk_tokens,
            preemptions_cum=engine.n_preemptions,
            tokens_planned=tokens_planned,
            budget_utilization=budget_utilization,
            fused_dispatches_cum=fused_cum,
            legacy_dispatches_cum=legacy_cum)
        if engine.paged:
            alloc = engine.allocator
            rec.pages_in_use = alloc.in_use
            rec.pages_free = alloc.free_pages
            rec.refcounts = alloc.refcount_histogram()
            self.g_pages_used.set(alloc.in_use)
            self.g_pages_free.set(alloc.free_pages)
            if engine.prefix_index is not None:
                rec.prefix_entries = engine.prefix_index.n_entries
                rec.evictions_cum = engine.prefix_index.n_evictions
                self.g_prefix.set(engine.prefix_index.n_entries)
                # evictions happen at several sites inside step() (reclaim
                # during admission / chunking / decode-page faults); one
                # end-of-step delta keeps the counter == n_evictions exact
                self.evictions.inc(rec.evictions_cum - self._last_evictions)
                self._last_evictions = rec.evictions_cum
        if self.timeline is not None:
            self.timeline.append(rec)
        if self.tracer:
            self.tracer.step_event(
                "step", t0, t1, n_decoding=n_decoding,
                n_chunking=n_chunking, tokens=tokens_emitted,
                chunk_tokens=chunk_tokens, dispatch_s=round(dispatch_s, 6))

    # ------------------------------------------------------------ exports --

    def snapshot(self) -> dict:
        """Registry snapshot plus the latest step record (JSON-ready)."""
        out = self.registry.snapshot()
        if self.timeline is not None:
            last = self.timeline.last()
            if last is not None:
                from dataclasses import asdict
                out["last_step"] = asdict(last)
        return out

    def render_prometheus(self) -> str:
        return self.registry.render_prometheus()
