"""Per-request trace spans + engine step track, exportable to Perfetto.

Every request gets its own TRACK (trace_event ``tid`` = rid) carrying the
lifecycle as a sequence of non-overlapping spans::

    queued -> prefill -> decoding -> (terminal)          # non-chunked
    queued -> chunk x N -> decoding -> (terminal)        # chunked prefill

with instant events for ``first_token``, ``preempt`` (which re-opens a
``queued`` span — the request is requeued and restarts from its prompt)
and ``suspend`` (a mid-prompt chunking slot parked under pool pressure).
Terminal status is one of ``retired`` / ``cancelled`` / ``rejected`` /
``aborted``; ``terminate`` closes any span still open so a trace is always
well-formed at the end of a request's life.

The ENGINE track (``tid`` = "engine") records one span per ``step()``
(args: decoding/chunking slot counts, tokens emitted, chunk tokens) — in
the Perfetto UI the chunked engine's interleaving claim is literally
visible: decode-step spans with ``n_decoding > 0`` sitting between a
request's chunk spans.

Timestamps are ``time.monotonic`` seconds (the same clock the Request
lifecycle fields use); exports convert to the microseconds trace_event
wants. Memory is bounded: at most ``max_traces`` request traces are
retained (oldest TERMINAL traces evicted first) and the engine track is a
ring of ``max_engine_events``.
"""
from __future__ import annotations

import json
import threading
import time
from collections import OrderedDict, deque
from dataclasses import dataclass, field
from typing import Optional


@dataclass
class Span:
    name: str
    t0: float
    t1: Optional[float] = None            # None while open
    args: dict = field(default_factory=dict)


@dataclass
class RequestTrace:
    rid: int
    spans: list = field(default_factory=list)      # closed in open order
    events: list = field(default_factory=list)     # (name, t, args) instants
    status: Optional[str] = None                   # terminal state
    _open: list = field(default_factory=list)      # stack of open spans

    def validate(self) -> None:
        """Well-formedness: every span closed with t1 >= t0, spans on the
        track strictly sequenced (no overlap), and a terminal status set.
        Raises AssertionError with the offending trace on violation."""
        assert self.status is not None, f"rid {self.rid}: no terminal status"
        assert not self._open, \
            f"rid {self.rid}: open spans at terminal: " \
            f"{[s.name for s in self._open]}"
        prev_end = -float("inf")
        for s in sorted(self.spans, key=lambda s: (s.t0, s.t1)):
            assert s.t1 is not None and s.t1 >= s.t0, (self.rid, s)
            assert s.t0 >= prev_end - 1e-9, \
                f"rid {self.rid}: span {s.name!r} overlaps previous " \
                f"(t0={s.t0} < prev_end={prev_end})"
            prev_end = s.t1


class Tracer:
    """Thread-safe recorder of request lifecycle spans + engine steps."""

    def __init__(self, *, max_traces: int = 4096,
                 max_engine_events: int = 4096):
        self._lock = threading.Lock()
        self._traces: OrderedDict = OrderedDict()   # rid -> RequestTrace
        self.max_traces = int(max_traces)
        self.engine_events: deque = deque(maxlen=int(max_engine_events))
        self._t0 = time.monotonic()                 # export origin

    # ------------------------------------------------------ request track

    def _trace(self, rid: int) -> RequestTrace:
        tr = self._traces.get(rid)
        if tr is None:
            tr = self._traces[rid] = RequestTrace(rid)
            if len(self._traces) > self.max_traces:
                # evict the oldest TERMINAL trace; never drop a live one
                for r, t in self._traces.items():
                    if t.status is not None:
                        del self._traces[r]
                        break
        return tr

    def begin(self, rid: int, name: str, t: Optional[float] = None,
              **args) -> None:
        with self._lock:
            tr = self._trace(rid)
            tr._open.append(Span(name, time.monotonic() if t is None else t,
                                 args=args))

    def has_open(self, rid: int, name: str) -> bool:
        with self._lock:
            tr = self._traces.get(rid)
            return bool(tr and tr._open and tr._open[-1].name == name)

    def end(self, rid: int, name: str, t: Optional[float] = None,
            **args) -> None:
        """Close the innermost open span (must be ``name``); a close with
        no matching open span is a no-op — admission may see requests that
        bypassed the traced submit path (direct Scheduler.submit)."""
        with self._lock:
            tr = self._traces.get(rid)
            if tr is None or not tr._open or tr._open[-1].name != name:
                return
            s = tr._open.pop()
            s.t1 = time.monotonic() if t is None else t
            s.args.update(args)
            tr.spans.append(s)

    def instant(self, rid: int, name: str, t: Optional[float] = None,
                **args) -> None:
        with self._lock:
            self._trace(rid).events.append(
                (name, time.monotonic() if t is None else t, args))

    def terminate(self, rid: int, status: str,
                  t: Optional[float] = None) -> None:
        """Close every open span and stamp the terminal status. Idempotent:
        the first terminal transition wins (a cancel racing a retire must
        not rewrite history)."""
        now = time.monotonic() if t is None else t
        with self._lock:
            tr = self._trace(rid)
            if tr.status is not None:
                return
            while tr._open:
                s = tr._open.pop()
                s.t1 = now
                tr.spans.append(s)
            tr.status = status

    # ------------------------------------------------------- engine track

    def step_event(self, name: str, t0: float, t1: float, **args) -> None:
        with self._lock:
            self.engine_events.append(Span(name, t0, t1, args))

    # ----------------------------------------------------------- reading

    def get(self, rid: int) -> Optional[RequestTrace]:
        with self._lock:
            return self._traces.get(rid)

    def traces(self) -> list:
        with self._lock:
            return list(self._traces.values())

    def validate_all(self) -> None:
        """Assert well-formedness of every TERMINAL trace (live requests
        legitimately hold open spans)."""
        for tr in self.traces():
            if tr.status is not None:
                tr.validate()

    # ----------------------------------------------------------- exports

    def _us(self, t: float) -> float:
        return (t - self._t0) * 1e6

    def export_jsonl(self, path: str) -> int:
        """One JSON object per line: request rows ({"rid", "status",
        "spans": [...], "events": [...]}) then engine-step rows. Returns
        the number of lines written."""
        n = 0
        with open(path, "w") as f:
            for tr in self.traces():
                row = {"rid": tr.rid, "status": tr.status,
                       "spans": [{"name": s.name, "t0": s.t0, "t1": s.t1,
                                  "args": s.args} for s in tr.spans],
                       "events": [{"name": e[0], "t": e[1], "args": e[2]}
                                  for e in tr.events]}
                f.write(json.dumps(row) + "\n")
                n += 1
            with self._lock:
                steps = list(self.engine_events)
            for s in steps:
                f.write(json.dumps({"engine_step": s.name, "t0": s.t0,
                                    "t1": s.t1, "args": s.args}) + "\n")
                n += 1
        return n

    def chrome_trace(self) -> dict:
        """Chrome ``trace_event`` JSON (open in Perfetto / chrome://tracing):
        complete ("X") events per span, instant ("i") events, one tid per
        request plus the engine-step track on tid 0."""
        ev: list = [
            {"name": "process_name", "ph": "M", "pid": 1, "tid": 0,
             "args": {"name": "nbl-engine"}},
            {"name": "thread_name", "ph": "M", "pid": 1, "tid": 0,
             "args": {"name": "engine-steps"}},
        ]
        with self._lock:
            steps = list(self.engine_events)
            traces = list(self._traces.values())
        for s in steps:
            ev.append({"name": s.name, "ph": "X", "pid": 1, "tid": 0,
                       "ts": self._us(s.t0),
                       "dur": max(0.0, self._us(s.t1) - self._us(s.t0)),
                       "args": s.args})
        for tr in traces:
            tid = tr.rid + 1                       # 0 is the engine track
            ev.append({"name": "thread_name", "ph": "M", "pid": 1,
                       "tid": tid,
                       "args": {"name": f"request {tr.rid} "
                                        f"[{tr.status or 'live'}]"}})
            for s in tr.spans:
                ev.append({"name": s.name, "ph": "X", "pid": 1, "tid": tid,
                           "ts": self._us(s.t0),
                           "dur": max(0.0,
                                      self._us(s.t1) - self._us(s.t0)),
                           "args": s.args})
            for name, t, args in tr.events:
                ev.append({"name": name, "ph": "i", "pid": 1, "tid": tid,
                           "ts": self._us(t), "s": "t", "args": args})
        return {"traceEvents": ev, "displayTimeUnit": "ms"}

    def export_chrome_trace(self, path: str) -> int:
        trace = self.chrome_trace()
        with open(path, "w") as f:
            json.dump(trace, f)
        return len(trace["traceEvents"])
