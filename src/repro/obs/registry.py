"""Metrics registry: Counter / Gauge / Histogram with Prometheus rendering.

Dependency-free and host-side only — instruments are plain Python numbers
behind one registry lock, so a snapshot is CONSISTENT (no torn reads of a
histogram's count vs its buckets) even while the AsyncEngine step-loop
thread and client threads mutate concurrently. Nothing here ever touches a
jax array: recording a metric can never add a device dispatch.

Registry-level ``labels`` (the engine binds ``engine_mode`` and ``nbl_m``
at construction) are rendered into every series, so two engines' scrapes
are distinguishable without per-instrument label plumbing.

``LATENCY_BUCKETS`` is the single fixed log-spaced bucket ladder every
latency histogram uses: 4 buckets per decade from 10 µs to 100 s. Fixed
buckets keep ``observe`` O(log n_buckets) (bisect) with zero allocation,
and make any two histograms directly comparable.
"""
from __future__ import annotations

import threading
from bisect import bisect_left
from typing import Optional

# 4 log-spaced buckets per decade, 1e-5 s .. 1e2 s (29 upper bounds);
# +Inf is implicit (count - last cumulative bucket).
LATENCY_BUCKETS: tuple = tuple(
    round(10.0 ** (e / 4.0), 12) for e in range(-20, 9))


class Counter:
    """Monotone float/int counter. ``inc`` only; never reset in place."""

    __slots__ = ("name", "help", "_value", "_lock")

    def __init__(self, name: str, help: str, lock: threading.Lock):
        self.name, self.help = name, help
        self._value = 0                      # guarded-by: _lock
        self._lock = lock

    def inc(self, n=1) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name}: inc({n}) < 0")
        with self._lock:
            self._value += n

    @property
    def value(self):
        # single attribute read: GIL-atomic, no torn state possible, and
        # taking the shared registry lock here would let a hot probe loop
        # contend with the step thread's inc()
        return self._value  # nbl: disable=guarded-by -- lock-free single read is GIL-atomic


class Gauge:
    """Point-in-time value; ``set`` wins, ``add`` for up/down deltas."""

    __slots__ = ("name", "help", "_value", "_lock")

    def __init__(self, name: str, help: str, lock: threading.Lock):
        self.name, self.help = name, help
        self._value = 0                      # guarded-by: _lock
        self._lock = lock

    def set(self, v) -> None:
        with self._lock:
            self._value = v

    def add(self, n) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self):
        return self._value  # nbl: disable=guarded-by -- lock-free single read is GIL-atomic


class Histogram:
    """Fixed-bucket histogram (cumulative counts rendered Prometheus-style).

    ``buckets`` are the UPPER bounds (sorted ascending); an observation
    lands in the first bucket whose bound is >= the value, or the implicit
    +Inf overflow. ``percentile`` interpolates within the winning bucket —
    good enough for a live ticker, not a substitute for the exact
    percentiles ``latency_stats`` computes over retained requests.
    """

    __slots__ = ("name", "help", "buckets", "_counts", "_sum", "_count",
                 "_lock")

    def __init__(self, name: str, help: str, lock: threading.Lock,
                 buckets: tuple = LATENCY_BUCKETS):
        if list(buckets) != sorted(buckets) or len(set(buckets)) != \
                len(buckets):
            raise ValueError("histogram buckets must be strictly ascending")
        self.name, self.help = name, help
        self.buckets = tuple(float(b) for b in buckets)
        self._counts = [0] * (len(self.buckets) + 1)   # [+Inf] is last # guarded-by: _lock
        self._sum = 0.0                      # guarded-by: _lock
        self._count = 0                      # guarded-by: _lock
        self._lock = lock

    def observe(self, v) -> None:
        v = float(v)
        i = bisect_left(self.buckets, v)
        with self._lock:
            self._counts[i] += 1
            self._sum += v
            self._count += 1

    @property
    def count(self) -> int:
        return self._count  # nbl: disable=guarded-by -- lock-free single read is GIL-atomic

    @property
    def sum(self) -> float:
        return self._sum  # nbl: disable=guarded-by -- lock-free single read is GIL-atomic

    def percentile(self, q: float) -> float:
        """Bucket-interpolated percentile, q in [0, 100]. 0.0 when empty."""
        with self._lock:
            total = self._count
            counts = list(self._counts)
        if total == 0:
            return 0.0
        rank = max(1, int(round(q / 100.0 * total)))
        cum = 0
        for i, c in enumerate(counts):
            cum += c
            if cum >= rank:
                hi = self.buckets[i] if i < len(self.buckets) \
                    else self.buckets[-1]
                lo = self.buckets[i - 1] if i > 0 else 0.0
                frac = (rank - (cum - c)) / max(1, c)
                return lo + (hi - lo) * frac
        return self.buckets[-1]


def _fmt_value(v) -> str:
    if isinstance(v, float):
        return repr(v)
    return str(v)


def _fmt_labels(labels: dict) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in sorted(labels.items()))
    return "{" + inner + "}"


class MetricsRegistry:
    """Instrument factory + consistent snapshot + Prometheus rendering.

    One registry per engine; ``counter``/``gauge``/``histogram`` are
    idempotent by name (the existing instrument is returned, so two code
    paths can share a series). ``snapshot`` and ``render_prometheus`` take
    the registry lock once, so a scrape mid-step never observes a
    histogram whose count and buckets disagree.
    """

    def __init__(self, labels: Optional[dict] = None):
        self._lock = threading.Lock()
        self.labels: dict = dict(labels or {})   # guarded-by: _lock
        self._metrics: dict = {}   # name -> instrument # guarded-by: _lock

    def bind(self, **labels) -> None:
        """Set registry labels that are not already set (the engine binds
        ``engine_mode``/``nbl_m`` defaults without clobbering a caller's)."""
        with self._lock:
            for k, v in labels.items():
                self.labels.setdefault(k, str(v))

    def _make(self, cls, name: str, help: str, **kw):
        with self._lock:
            inst = self._metrics.get(name)
            if inst is None:
                inst = cls(name, help, self._lock, **kw)
                self._metrics[name] = inst
            elif not isinstance(inst, cls):
                raise ValueError(f"metric {name!r} already registered as "
                                 f"{type(inst).__name__}")
            return inst

    def counter(self, name: str, help: str = "") -> Counter:
        return self._make(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._make(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: tuple = LATENCY_BUCKETS) -> Histogram:
        return self._make(Histogram, name, help, buckets=buckets)

    def get(self, name: str):
        """Current value of a counter/gauge by name (None if absent)."""
        # the dict lookup needs the lock (a concurrent _make may be
        # inserting — dict mutation during .get is only safe for the
        # built-in path, and the guarded-by rule treats _metrics as owned
        # by _lock); the value read itself is the instrument's own
        # lock-free GIL-atomic read
        with self._lock:
            m = self._metrics.get(name)
        return None if m is None else m.value

    def snapshot(self) -> dict:
        """Consistent point-in-time copy of every series, JSON-ready."""
        with self._lock:
            out: dict = {"labels": dict(self.labels), "counters": {},
                         "gauges": {}, "histograms": {}}
            for name, m in self._metrics.items():
                if isinstance(m, Counter):
                    out["counters"][name] = m._value
                elif isinstance(m, Gauge):
                    out["gauges"][name] = m._value
                else:
                    cum, buckets = 0, []
                    for b, c in zip(m.buckets, m._counts):
                        cum += c
                        buckets.append([b, cum])
                    out["histograms"][name] = {
                        "count": m._count, "sum": m._sum, "buckets": buckets}
        return out

    def render_prometheus(self) -> str:
        """Text exposition format (one consistent scrape)."""
        with self._lock:
            labels = dict(self.labels)
            items = list(self._metrics.items())
            rows: dict = {}
            for name, m in items:
                if isinstance(m, Histogram):
                    rows[name] = ("histogram", m._count, m._sum,
                                  list(m._counts), m.buckets, m.help)
                else:
                    kind = "counter" if isinstance(m, Counter) else "gauge"
                    rows[name] = (kind, m._value, m.help)
        lines: list = []
        for name, row in rows.items():
            kind = row[0]
            help = row[-1]
            if help:
                lines.append(f"# HELP {name} {help}")
            lines.append(f"# TYPE {name} {kind}")
            if kind == "histogram":
                _, count, total, counts, buckets, _ = row
                cum = 0
                for b, c in zip(buckets, counts):
                    cum += c
                    lb = _fmt_labels({**labels, "le": repr(float(b))})
                    lines.append(f"{name}_bucket{lb} {cum}")
                lb = _fmt_labels({**labels, "le": "+Inf"})
                lines.append(f"{name}_bucket{lb} {count}")
                lines.append(f"{name}_sum{_fmt_labels(labels)} "
                             f"{_fmt_value(total)}")
                lines.append(f"{name}_count{_fmt_labels(labels)} {count}")
            else:
                _, value, _ = row
                lines.append(f"{name}{_fmt_labels(labels)} "
                             f"{_fmt_value(value)}")
        return "\n".join(lines) + "\n"
