from repro.eval.perplexity import perplexity, eval_suite  # noqa: F401
