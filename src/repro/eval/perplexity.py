"""Perplexity + probe-task evaluation (paper §4 stand-in, offline).

The paper evaluates on HF reasoning benchmarks; 7B checkpoints are not
available offline, so the reproduction validates the *orderings* the paper
claims (NBL ≥ DROP at equal m, CCA ≥ cosine, later layers more linearizable)
with perplexity on the synthetic corpus plus a deterministic-successor probe
accuracy (the learnable structure of the Zipf–Markov stream)."""
from __future__ import annotations

import math
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.jitcache import shared_jit
from repro.models import loss_fn, apply


def _ppl_step(cfg: ModelConfig, p, batch):
    _, m = loss_fn(cfg, p, batch, remat=False)
    return m["ce"] * m["ntokens"], m["ntokens"]


def _succ_step(cfg: ModelConfig, p, tokens):
    logits, _ = apply(cfg, p, tokens)
    return jnp.argmax(logits, axis=-1)


def perplexity(cfg: ModelConfig, params: dict,
               data_factory: Callable) -> float:
    # shared across calls: eval sweeps score every (m, layer-set) variant
    # of the SAME architecture, and cfg is the whole closure
    step = shared_jit(("eval.ppl", cfg),
                      lambda: jax.jit(partial(_ppl_step, cfg)))
    tot, n = 0.0, 0.0
    for batch in data_factory():
        ce, nt = step(params, batch)
        tot += float(ce)
        n += float(nt)
    return math.exp(tot / max(n, 1.0))


def successor_accuracy(cfg: ModelConfig, params: dict,
                       data_factory: Callable, succ: np.ndarray) -> float:
    """Fraction of positions where the model's argmax equals the Markov
    successor — a crisp 'did compression preserve the learned structure'
    probe (higher = better)."""
    step = shared_jit(("eval.succ", cfg),
                      lambda: jax.jit(partial(_succ_step, cfg)))
    hit, n = 0, 0
    for batch in data_factory():
        pred = np.asarray(step(params, batch["tokens"]))
        want = succ[batch["tokens"]]
        hit += int((pred[:, :-1] == want[:, :-1]).sum())
        n += pred[:, :-1].size
    return hit / max(n, 1)


def eval_suite(cfg: ModelConfig, params: dict, data_factory: Callable,
               succ: np.ndarray | None = None) -> dict:
    out = {"ppl": perplexity(cfg, params, data_factory)}
    if succ is not None:
        out["succ_acc"] = successor_accuracy(cfg, params, data_factory, succ)
    return out
