from repro.data.synthetic import ZipfMarkov, lm_batches, calib_factory  # noqa: F401
from repro.data.loader import ShardedLoader  # noqa: F401
