"""Host-sharded data loader for multi-pod training.

Each host owns global_batch / n_hosts rows of every global step. Assignment
is a pure function of (step, host_index, n_hosts):

    rows(step, h) = [h * per_host, (h+1) * per_host)

so (a) an *elastic* restart with a different host count re-partitions the
same global stream without skipping or duplicating data, and (b) *straggler
mitigation* — a slow/failed host's rows can be deterministically re-assigned
to a healthy host (``reassign``) while preserving the global batch content.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.data.synthetic import ZipfMarkov


class ShardedLoader:
    def __init__(self, vocab_size: int, global_batch: int, seq: int, *,
                 seed: int = 0, host_index: int = 0, n_hosts: int = 1):
        assert global_batch % n_hosts == 0, (global_batch, n_hosts)
        self.vocab = vocab_size
        self.global_batch = global_batch
        self.seq = seq
        self.seed = seed
        self.host_index = host_index
        self.n_hosts = n_hosts
        self.proc = ZipfMarkov(vocab_size, seed=seed)
        self._extra_hosts: list[int] = []   # stragglers we cover for

    @property
    def per_host(self) -> int:
        return self.global_batch // self.n_hosts

    def reassign(self, failed_host: int) -> None:
        """Take over a straggler/failed host's shard (deterministic)."""
        if failed_host not in self._extra_hosts:
            self._extra_hosts.append(failed_host)

    def _host_rows(self, step: int, host: int) -> np.ndarray:
        """The rows of the *global* batch owned by ``host`` at ``step``.
        Sampling is per-row-block so any host can materialize any shard."""
        return self.proc.sample(self.per_host, self.seq,
                                (self.seed * 1_000_003 + step) * 4096 + host)

    def batch(self, step: int) -> dict:
        hosts = [self.host_index, *self._extra_hosts]
        toks = np.concatenate([self._host_rows(step, h) for h in hosts])
        labels = np.full_like(toks, -1)
        labels[:, :-1] = toks[:, 1:]
        return {"tokens": toks, "labels": labels}

    def global_batch_at(self, step: int) -> dict:
        """All hosts' rows (single-host testing / verification)."""
        toks = np.concatenate([self._host_rows(step, h)
                               for h in range(self.n_hosts)])
        labels = np.full_like(toks, -1)
        labels[:, :-1] = toks[:, 1:]
        return {"tokens": toks, "labels": labels}
