"""Deterministic synthetic LM corpus (offline C4/WikiText-2 stand-in).

A Zipf–Markov process: each token is either the deterministic successor of
the previous token under a fixed random permutation (probability
``p_copy``) or an i.i.d. draw from a Zipf marginal. This gives the stream
(a) a heavy-tailed unigram distribution (realistic embedding-gather
behavior and covariance spectra for NBL calibration) and (b) learnable
bigram structure, so small models trained on it show a real,
monotonically-decreasing loss and perplexity separates good models from
broken ones (used by the SLEB baseline and eval/).

Everything is a pure function of (seed, shape): calibration replays, elastic
restarts, and straggler re-assignment all reproduce bit-identical batches.
"""
from __future__ import annotations

from typing import Iterator, Optional

import numpy as np


class ZipfMarkov:
    def __init__(self, vocab_size: int, *, zipf_a: float = 1.2,
                 p_copy: float = 0.6, seed: int = 0):
        self.vocab = vocab_size
        self.p_copy = p_copy
        rng = np.random.default_rng(seed)
        self.succ = rng.permutation(vocab_size)
        ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
        probs = ranks ** -zipf_a
        self.marginal = probs / probs.sum()

    def sample(self, batch: int, seq: int, seed: int) -> np.ndarray:
        rng = np.random.default_rng((seed, 0xC0FFEE))
        iid = rng.choice(self.vocab, size=(batch, seq), p=self.marginal)
        copy = rng.random((batch, seq)) < self.p_copy
        out = np.empty((batch, seq), np.int32)
        out[:, 0] = iid[:, 0]
        for t in range(1, seq):
            out[:, t] = np.where(copy[:, t], self.succ[out[:, t - 1]],
                                 iid[:, t])
        return out


def lm_batches(vocab_size: int, batch: int, seq: int, n_batches: int, *,
               seed: int = 0, start_step: int = 0,
               proc: ZipfMarkov | None = None) -> Iterator[dict]:
    """Yields {"tokens", "labels"} with next-token labels (-1 on the final
    position). Batch ``i`` depends only on (seed, start_step + i)."""
    proc = proc or ZipfMarkov(vocab_size, seed=seed)
    for i in range(start_step, start_step + n_batches):
        toks = proc.sample(batch, seq, seed * 1_000_003 + i)
        labels = np.full_like(toks, -1)
        labels[:, :-1] = toks[:, 1:]
        yield {"tokens": toks, "labels": labels}


def calib_factory(cfg, *, batch: int = 4, seq: int = 128,
                  n_batches: int = 8, seed: int = 1234,
                  enc_tokens: Optional[int] = None):
    """Data factory for core.calibrate — the paper's "256 C4 samples of
    context t" (scaled down by default; sizes are caller-controlled)."""
    n_enc = enc_tokens if enc_tokens is not None else cfg.n_frontend_tokens

    def factory():
        for i, b in enumerate(lm_batches(cfg.vocab_size, batch, seq,
                                         n_batches, seed=seed)):
            if cfg.family == "vlm" and n_enc:
                rng = np.random.default_rng((seed, i, 7))
                b["enc"] = rng.standard_normal(
                    (batch, n_enc, cfg.d_model)).astype(np.float32)
            yield b
    return factory
