"""Process-wide shared jit registry (the ``_SHARED_JITS`` discipline).

Jit wrappers built in FUNCTION scope are a retrace hazard: every call of
the enclosing function makes a fresh closure, every fresh closure is a new
cache key to jax, and the same jaxpr gets re-traced (and on a cold XLA
cache, re-compiled) over and over. The engine learned this in PR 4 —
sharing its decode/prefill/assign jits across instances cut the serving
suites ~35% — and ``repro.analysis``'s jit-discipline pass now enforces it
everywhere: a ``jax.jit`` site must be module-level (built once per
import), routed through :func:`shared_jit` here, or carry an explicit
``# nbl: disable=jit-discipline -- <reason>`` allowlist comment.

Use it when the jitted closure captures only HASHABLE, value-equal
constants (a frozen ``ModelConfig``, static plan ints/bools): two builds
over equal keys lower to identical jaxprs, so handing every caller the
same callable lets jax's trace cache do its job. Do NOT use it when the
closure captures arrays (params) or mesh-captured shardings — those must
stay per-instance, and their sites carry allowlist reasons instead.

Keys are plain hashable tuples, conventionally ``("<site>", cfg, ...)``
with every closure-captured constant included — a key that under-describes
its closure silently serves the wrong function.
"""
from __future__ import annotations

from typing import Callable

SHARED_JITS: dict = {}


def shared_jit(key, build: Callable):
    """Return the process-wide jit for ``key``, building it on first use."""
    fn = SHARED_JITS.get(key)
    if fn is None:
        fn = SHARED_JITS[key] = build()
    return fn
