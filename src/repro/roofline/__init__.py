from repro.roofline.analysis import (  # noqa: F401
    HW_V5E, collective_bytes, roofline_terms, model_flops,
)
