"""Render the §Roofline markdown table from dry-run JSON records.

    PYTHONPATH=src python -m repro.roofline.report experiments/*.json
"""
from __future__ import annotations

import json
import sys


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.1f}µs"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def rows_from(records: list[dict]) -> list[dict]:
    out = []
    for r in records:
        if r.get("status") == "skipped":
            out.append({"arch": r["arch"], "shape": r["shape"],
                        "mesh": r["mesh"], "skip": r["reason"]})
            continue
        if r.get("status") != "ok":
            out.append({"arch": r["arch"], "shape": r["shape"],
                        "mesh": r["mesh"], "skip": "FAIL " + r.get("error", "")})
            continue
        rf = r["roofline"]
        out.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "nbl_m": r.get("nbl_m", 0),
            "t_c": rf["t_compute"], "t_m": rf["t_memory"],
            "t_x": rf["t_collective"], "dom": rf["dominant"],
            "frac": rf.get("frac_compute", 0.0),
            "useful": rf.get("useful_flop_ratio", 0.0),
            "flops": rf["hlo_flops"], "bytes": rf["hlo_bytes"],
            "coll": rf["collectives"]["total"],
            "mem": r.get("memory", {}),
        })
    return out


def markdown(rows: list[dict], mesh: str | None = "16x16") -> str:
    hdr = ("| arch | shape | t_compute | t_memory | t_collective | dominant "
           "| compute-frac | 6ND/HLO |\n|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in rows:
        if mesh and r["mesh"] != mesh:
            continue
        if "skip" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skipped | — | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(r['t_c'])} "
            f"| {fmt_s(r['t_m'])} | {fmt_s(r['t_x'])} "
            f"| {r['dom'].replace('t_', '')} | {r['frac']:.3f} "
            f"| {r['useful']:.2f} |")
    return "\n".join(lines)


def main() -> None:
    records = []
    for path in sys.argv[1:]:
        with open(path) as f:
            records.extend(json.load(f))
    # dedupe on (arch, shape, mesh, nbl) keeping the LAST occurrence
    seen = {}
    for r in records:
        seen[(r["arch"], r["shape"], r["mesh"], r.get("nbl_m", 0))] = r
    rows = rows_from(list(seen.values()))
    for mesh in ("16x16", "2x16x16"):
        print(f"\n### mesh {mesh}\n")
        print(markdown(rows, mesh))


if __name__ == "__main__":
    main()
