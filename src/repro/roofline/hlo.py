"""Trip-count-aware, TPU-faithful cost analysis of optimized (post-SPMD)
HLO text.

``compiled.cost_analysis()`` counts while-loop bodies ONCE and reports
per-partition shapes, which silently undercounts a scanned 61-layer model
by 61×. Additionally, XLA:CPU (the dry-run backend) lowers bf16 through
explicit f32 convert fusions and materializes whole-buffer copies around
sharded in-place updates — none of which costs HBM traffic on the TPU
target. This module re-derives the three roofline inputs from the HLO with
a TPU-semantics cost model:

  FLOPs        dot/conv = 2·|result|·Π(contracting dims); while bodies
               multiplied by backend_config known_trip_count.
  HBM bytes    fusion/op boundaries count operand+result bytes once, with
               - convert/bitcast/copy chains collapsed (bytes = the
                 narrowest dtype along the chain: TPU fuses converts),
               - pure-convert fusions treated as aliases (zero cost),
               - dynamic-update-slice in place: traffic = 2×update slice,
                 even through convert wrappers (CPU artifact),
               - stash reads via dynamic-slice: traffic = the slice, not
                 the (L,·) remat/scan buffer it gathers from.
  collectives  per kind (all-gather/all-reduce/reduce-scatter/all-to-all/
               collective-permute), operand sizes, trip-multiplied.

All shapes in the partitioned module are per-device, so every cost is
*per-chip per-step*; multiply by #chips for globals. Validated against
hand-counted synthetic modules in tests/test_roofline.py.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}
COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*\S.*\{\s*$")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*"
                       r"((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\]\S*))\s+"
                       r"([\w\-]+)\((.*)$")
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_NAME_RE = re.compile(r"%([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CDIM_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

_VIEW_OPS = ("convert", "bitcast", "copy", "get-tuple-element", "reshape")
_FREE_OPS = ("parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "after-all", "convert", "iota", "partition-id",
             "replica-id")


def _shapes(type_str: str) -> list[tuple[str, list[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        out.append((dt, [int(d) for d in dims.split(",")] if dims else []))
    return out


def _bytes(shapes) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


@dataclasses.dataclass
class Instr:
    name: str
    shapes: list
    opcode: str
    rest: str           # operand list + attributes (raw tail of the line)

    def operand_names(self) -> list[str]:
        depth, end = 1, len(self.rest)
        for i, ch in enumerate(self.rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        return _NAME_RE.findall(self.rest[:end])

    def attr(self, key: str) -> Optional[str]:
        m = re.search(key + r"=%([\w.\-]+)", self.rest)
        return m.group(1) if m else None


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVES})

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k in COLLECTIVES:
            self.coll[k] += other.coll[k] * mult

    @property
    def coll_bytes(self) -> float:
        return sum(self.coll.values())


def parse_module(text: str) -> tuple[dict, Optional[str]]:
    comps: dict[str, list[Instr]] = {}
    entry = None
    cur: Optional[list[Instr]] = None
    for line in text.splitlines():
        m = _COMP_RE.match(line.strip())
        if m and line.rstrip().endswith("{"):
            name = m.group(1)
            comps[name] = cur = []
            if line.lstrip().startswith("ENTRY"):
                entry = name
            continue
        if cur is None:
            continue
        im = _INSTR_RE.match(line)
        if im:
            nm, tp, op, rest = im.groups()
            cur.append(Instr(nm, _shapes(tp), op, rest))
    return comps, entry


# ---------------------------------------------------------------------------
# Per-computation index with alias (convert-chain) resolution
# ---------------------------------------------------------------------------

class CompIndex:
    def __init__(self, name: str, comps: dict):
        self.name = name
        self.instrs: list[Instr] = comps.get(name, [])
        self.by_name = {i.name: i for i in self.instrs}
        self.comps = comps
        self._pure_conv: dict[str, bool] = {}

    def is_pure_convert_fusion(self, ins: Instr) -> bool:
        """Fusion whose callee only converts/reshapes — an alias on TPU."""
        if ins.opcode != "fusion":
            return False
        callee = ins.attr("calls")
        if callee is None:
            return False
        if callee in self._pure_conv:
            return self._pure_conv[callee]
        ops = {ci.opcode for ci in self.comps.get(callee, [])}
        pure = ops <= set(_FREE_OPS) | {"copy", "reshape", "broadcast"}
        self._pure_conv[callee] = pure
        return pure

    def resolve(self, name: str) -> tuple[Optional[Instr], float]:
        """Follow view/convert chains; returns (source instr, min bytes
        along the chain) — the narrowest materialization is the traffic."""
        best = float("inf")
        ins = self.by_name.get(name)
        hops = 0
        while ins is not None and hops < 12:
            b = _bytes(ins.shapes)
            if b:
                best = min(best, b)
            nxt = None
            if ins.opcode in _VIEW_OPS:
                ops = ins.operand_names()
                nxt = self.by_name.get(ops[0]) if ops else None
            elif self.is_pure_convert_fusion(ins):
                ops = ins.operand_names()
                # alias the largest operand (the converted buffer)
                cand = [self.by_name.get(o) for o in ops]
                cand = [c for c in cand if c is not None]
                nxt = max(cand, key=lambda c: _bytes(c.shapes),
                          default=None)
            if nxt is None:
                break
            ins = nxt
            hops += 1
        if best == float("inf"):
            best = 0.0
        return ins, best

    def operand_bytes(self, ins: Instr) -> float:
        return float(sum(self.resolve(n)[1] for n in ins.operand_names()))

    def io_bytes(self, ins: Instr) -> float:
        return self.operand_bytes(ins) + _bytes(ins.shapes)


def _dot_flops(ins: Instr, idx: CompIndex) -> float:
    res_elems = 1
    for _, dims in ins.shapes:
        for d in dims:
            res_elems *= d
    contract = 1
    cm = _CDIM_RE.search(ins.rest)
    ops = ins.operand_names()
    if cm and ops:
        src = idx.by_name.get(ops[0])
        if src is not None and len(src.shapes) == 1 and cm.group(1):
            lhs_dims = src.shapes[0][1]
            for s in cm.group(1).split(","):
                i = int(s)
                if i < len(lhs_dims):
                    contract *= lhs_dims[i]
    return 2.0 * res_elems * contract


def _fusion_boundary_bytes(ins: Instr, idx: CompIndex) -> float:
    """HBM traffic of one fusion call under TPU in-place semantics."""
    callee = ins.attr("calls")
    cidx = CompIndex(callee, idx.comps) if callee else None
    if cidx is None or not cidx.instrs:
        return idx.io_bytes(ins)
    params: dict[str, int] = {}
    for ci in cidx.instrs:
        if ci.opcode == "parameter":
            m = re.match(r"(\d+)", ci.rest)
            if m:
                params[ci.name] = int(m.group(1))

    # in-place DUS targets (through convert wrappers)
    inplace_params: set[str] = set()
    dus_update = 0.0
    for ci in cidx.instrs:
        if ci.opcode != "dynamic-update-slice":
            continue
        names = ci.operand_names()
        if len(names) < 2:
            continue
        src, _ = cidx.resolve(names[0])
        if src is not None and src.opcode == "parameter" and \
                [d for _, d in src.shapes] == [d for _, d in ci.shapes]:
            inplace_params.add(src.name)
            _, ub = cidx.resolve(names[1])
            dus_update += 2 * ub

    # stash-gather params: consumed (through views) only by dynamic-slice
    def gather_bytes(pname: str) -> Optional[float]:
        frontier, terminals, hops = {pname}, [], 0
        while frontier and hops < 10:
            nxt = set()
            for ci in cidx.instrs:
                ops = ci.operand_names()
                if not (frontier & set(ops)):
                    continue
                if ci.opcode in _VIEW_OPS:
                    nxt.add(ci.name)
                else:
                    terminals.append(ci)
            frontier = nxt
            hops += 1
        if terminals and all(t.opcode == "dynamic-slice"
                             for t in terminals):
            return float(sum(_bytes(t.shapes) for t in terminals))
        return None

    total = 0.0
    pinstrs = {i: n for n, i in params.items()}
    for opi, op_name in enumerate(ins.operand_names()):
        pname = pinstrs.get(opi)
        _, dflt = idx.resolve(op_name)
        if pname is None:
            total += dflt
            continue
        if pname in inplace_params:
            continue
        p = cidx.by_name[pname]
        # narrowest of caller-side chain and callee param dtype view
        dflt = min(dflt, float(_bytes(p.shapes)) or dflt)
        g = gather_bytes(pname)
        total += g if g is not None else dflt

    if inplace_params:
        total += dus_update
        root = cidx.instrs[-1]
        if root.opcode == "tuple":
            for n in root.operand_names():
                el, eb = cidx.resolve(n)
                if el is not None and el.opcode != "dynamic-update-slice":
                    total += eb
    else:
        total += _bytes(ins.shapes)
    return total


# ---------------------------------------------------------------------------
# Walk
# ---------------------------------------------------------------------------

def _walk(name: str, comps: dict, memo: dict, boundary_only: bool,
          sink=None, mult: int = 1) -> Cost:
    key = (name, boundary_only)
    if sink is None and key in memo:
        return memo[key]
    idx = CompIndex(name, comps)
    cost = Cost()
    if sink is None:
        memo[key] = cost

    def emit(b: float, ins: Instr) -> None:
        cost.bytes += b
        if sink is not None and b:
            sink(b, mult, ins)

    for ins in idx.instrs:
        op = ins.opcode
        if op in _FREE_OPS:
            continue
        if op == "fusion":
            if idx.is_pure_convert_fusion(ins):
                continue
            callee = ins.attr("calls")
            if callee:
                cost.add(_walk(callee, comps, memo, True,
                               sink=None))          # flops only inside
                if sink is not None:
                    inner = _walk(callee, comps, memo, True, sink=None)
                    del inner
            if not boundary_only:
                emit(_fusion_boundary_bytes(ins, idx), ins)
            continue
        if op == "while":
            body = ins.attr("body")
            tm = _TRIP_RE.search(ins.rest)
            trips = int(tm.group(1)) if tm else 1
            if body:
                sub = _walk(body, comps, memo, False,
                            sink=sink, mult=mult * trips)
                cost.add(sub, mult=trips)
            continue
        if op in ("call", "conditional", "async-start"):
            callee = ins.attr("calls") or ins.attr("to_apply")
            if callee:
                cost.add(_walk(callee, comps, memo, boundary_only,
                               sink=sink, mult=mult))
            continue
        base = op
        for suf in ("-start", "-done"):
            if base.endswith(suf):
                base = base[:-len(suf)]
        if base in COLLECTIVES:
            if not op.endswith("-done"):
                cost.coll[base] += idx.operand_bytes(ins)
                if not boundary_only:
                    emit(idx.io_bytes(ins), ins)
            continue
        if op in ("dot", "convolution"):
            cost.flops += _dot_flops(ins, idx)
            if not boundary_only:
                emit(idx.io_bytes(ins), ins)
            continue
        if op == "dynamic-update-slice":
            if not boundary_only:
                names = ins.operand_names()
                ub = idx.resolve(names[1])[1] if len(names) > 1 else 0.0
                emit(2 * ub, ins)
            continue
        if op == "dynamic-slice":
            if not boundary_only:
                emit(2 * _bytes(ins.shapes), ins)
            continue
        if op == "copy":
            # layout copies are real on TPU only when layouts differ; we
            # keep them (conservative) but at narrowest-chain size
            if not boundary_only:
                emit(idx.io_bytes(ins), ins)
            continue
        # other elementwise / data movement
        if not boundary_only:
            emit(idx.io_bytes(ins), ins)

    return cost


def analyze(text: str) -> Cost:
    """Per-chip per-step cost of the optimized module's entry computation."""
    comps, entry = parse_module(text)
    if entry is None:
        return Cost()
    return _walk(entry, comps, {}, False)


def attribute(text: str, top: int = 15) -> list[tuple[float, int, str, str]]:
    """Top HBM-byte contributors [(bytes, trip_mult, instr, op_name_meta)]
    under the same cost model as analyze() — the hillclimbing 'profile'."""
    comps, entry = parse_module(text)
    if entry is None:
        return []
    rows: list[tuple[float, int, str, str]] = []

    def sink(b: float, mult: int, ins: Instr) -> None:
        m = re.search(r'op_name="([^"]*)"', ins.rest)
        rows.append((b * mult, mult, f"{ins.opcode}:{ins.name}",
                     m.group(1)[-100:] if m else ""))

    _walk(entry, comps, {}, False, sink=sink)
    rows.sort(reverse=True)
    return rows[:top]
