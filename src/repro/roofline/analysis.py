"""Three-term roofline from the compiled dry-run artifact.

    t_compute = HLO_FLOPs        / (chips · peak_FLOP/s)
    t_memory  = HLO_bytes        / (chips · HBM_bw)
    t_coll    = collective_bytes / (chips · link_bw)

FLOPs/bytes come from ``compiled.cost_analysis()``. Collective bytes are
parsed out of the optimized HLO text: the sum of operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.
Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s ICI
per link.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class Hardware:
    name: str
    peak_flops: float      # per chip, bf16
    hbm_bw: float          # bytes/s per chip
    ici_bw: float          # bytes/s per link
    hbm_bytes: float       # capacity per chip


HW_V5E = Hardware("tpu_v5e", peak_flops=197e12, hbm_bw=819e9, ici_bw=50e9,
                  hbm_bytes=16e9)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "fp8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  bf16[8,128,2048]{2,1,0}   or  f32[] (scalar)
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
# "%name = <result-type> opcode(%op1, %op2, ...), ..."
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_CALL_RE = re.compile(
    r"^((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^\s]*))\s+([\w\-]+)\((.*)$")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _type_bytes(type_str: str) -> int:
    return sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(type_str))


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-op-kind *operand* bytes summed over the module. Optimized HLO
    references operands by name only, so pass 1 maps %name -> result-type
    bytes and pass 2 resolves each collective's operand list. ``-done`` ops
    are skipped (their ``-start`` already counted)."""
    sizes: dict[str, int] = {}
    calls: list[tuple[str, str]] = []       # (kind, operand-string)
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rest = m.groups()
        c = _CALL_RE.match(rest)
        if not c:
            continue
        rtype, opcode, operands = c.groups()
        sizes[name] = _type_bytes(rtype)
        base = opcode
        for suf in ("-start", "-done"):
            if base.endswith(suf):
                base = base[:-len(suf)]
        if base in _COLLECTIVES and not opcode.endswith("-done"):
            # cut operand list at the closing paren of the call
            depth, end = 1, len(operands)
            for i, ch in enumerate(operands):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        end = i
                        break
            calls.append((base, operands[:end]))
    out = {k: 0 for k in _COLLECTIVES}
    for kind, operands in calls:
        total = sum(sizes.get(nm, 0) for nm in _OPERAND_RE.findall(operands))
        out[kind] += total
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def roofline_terms(flops: float, bytes_accessed: float, coll_bytes: float,
                   chips: int, hw: Hardware = HW_V5E) -> dict:
    t_c = flops / (chips * hw.peak_flops)
    t_m = bytes_accessed / (chips * hw.hbm_bw)
    t_x = coll_bytes / (chips * hw.ici_bw)
    terms = {"t_compute": t_c, "t_memory": t_m, "t_collective": t_x}
    dom = max(terms, key=terms.get)
    bound = max(t_c, t_m, t_x)
    return dict(terms, dominant=dom, t_bound=bound,
                frac_compute=(t_c / bound if bound else 0.0))


def model_flops(cfg, n_tokens: int, *, backward: bool = False) -> float:
    """6·N·D (dense) / 6·N_active·D (MoE); D = tokens. Train = fwd+bwd
    (the 6 already includes backward; forward-only = 2·N·D)."""
    n = cfg.n_active_params() if cfg.moe is not None else cfg.n_params()
    per_tok = 6 * n if backward else 2 * n
    return float(per_tok) * n_tokens


def summarize(hlo_text: str, chips: int, cfg=None,
              n_tokens: Optional[int] = None, backward: bool = False,
              hw: Hardware = HW_V5E, xla_cost: Optional[dict] = None) -> dict:
    """Roofline record from optimized HLO text. Uses the trip-count-aware
    walker (roofline.hlo) — compiled.cost_analysis() counts while bodies
    once and is kept only as a cross-reference field."""
    from repro.roofline import hlo as hlo_mod
    c = hlo_mod.analyze(hlo_text)
    flops = c.flops * chips            # per-chip -> global
    bts = c.bytes * chips
    coll = {k: v * chips for k, v in c.coll.items()}
    coll["total"] = c.coll_bytes * chips
    terms = roofline_terms(flops, bts, coll["total"], chips, hw)
    out = {"hlo_flops": flops, "hlo_bytes": bts,
           "collectives": coll, **terms, "chips": chips}
    if xla_cost:
        # old jax returns cost_analysis() as a one-element list of dicts
        if isinstance(xla_cost, (list, tuple)):
            xla_cost = xla_cost[0] if xla_cost else {}
        out["xla_cost_flops"] = float(xla_cost.get("flops", 0.0))
    if cfg is not None and n_tokens:
        mf = model_flops(cfg, n_tokens, backward=backward)
        out["model_flops"] = mf
        out["useful_flop_ratio"] = mf / flops if flops else 0.0
    return out
