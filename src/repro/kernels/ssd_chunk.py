"""Pallas SSD intra-chunk kernel (Mamba2's hot spot, TPU-native).

EXPERIMENTS.md §Perf H1 shows the XLA SSD path is memory-bound with a flat
optimum in chunk size: the (c×c) decay tensor L = exp(segsum(a)), the
(c×c) C·Bᵀ Gram tile and the chunk state all round-trip HBM between the
fusions XLA builds. This kernel is the hardware adaptation the Mamba2
authors make with Triton on GPU: one grid step owns a whole
(chunk × head) tile in VMEM — builds L in registers, runs the two MXU
matmuls (CBᵀ∘L)·x and the decay-weighted state update, and writes ONLY
y_intra and the per-chunk state back to HBM. Traffic per chunk drops from
~8 materialized (c,c)/(c,p)-sized passes to x/B/C/a reads + y/S writes.

Grid: (batch, n_chunks, heads). The inter-chunk recurrence (tiny,
sequential over n_chunks) stays in XLA — see models/ssm.py.
Validated in interpret mode against ref.ssd_chunk_ref (pure-jnp oracle,
itself equivalent to models/ssm._ssd_chunked's intra-chunk math).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, a_ref, b_ref, c_ref, y_ref, s_ref, atot_ref):
    x = x_ref[0, 0, :, 0].astype(jnp.float32)        # (c, p)
    a = a_ref[0, 0, :, 0].astype(jnp.float32)        # (c,)
    bb = b_ref[0, 0].astype(jnp.float32)             # (c, n)
    cc = c_ref[0, 0].astype(jnp.float32)             # (c, n)
    c = x.shape[0]

    cs = jnp.cumsum(a)                               # (c,)
    # L[i, j] = exp(cs_i - cs_j) for i >= j else 0   (decay, in-registers)
    diff = cs[:, None] - cs[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    l_mat = jnp.where(ii >= jj, jnp.exp(diff), 0.0)

    # y_intra = ((C Bᵀ) ∘ L) x
    cb = jax.lax.dot_general(cc, bb, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (c, c)
    y = jax.lax.dot(cb * l_mat, x, preferred_element_type=jnp.float32)
    y_ref[0, 0, :, 0] = y.astype(y_ref.dtype)

    # chunk state S = Σ_j exp(cs_last − cs_j)·B_j ⊗ x_j    (n, p)
    decay = jnp.exp(cs[c - 1] - cs)                  # (c,)
    s = jax.lax.dot_general(bb * decay[:, None], x,
                            (((0,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s_ref[0, 0, 0] = s.astype(s_ref.dtype)
    atot_ref[0, 0, 0] = cs[c - 1]


def ssd_chunk(x: jax.Array, a: jax.Array, b: jax.Array, c: jax.Array, *,
              interpret: bool = False):
    """Intra-chunk SSD.

    x: (B, NC, C, H, P) dt-weighted inputs; a: (B, NC, C, H) log-decays;
    b, c: (B, NC, C, N) input/output projections (shared across heads).
    Returns (y_intra (B,NC,C,H,P) f32, S (B,NC,H,N,P) f32,
             a_tot (B,NC,H) f32).
    """
    bsz, nc, ch, h, p = x.shape
    n = b.shape[-1]

    grid = (bsz * nc, h)
    # collapse (B, NC) into one grid dim; heads in the second
    x2 = x.reshape(bsz * nc, ch, h, p)
    a2 = a.reshape(bsz * nc, ch, h)
    b2 = b.reshape(bsz * nc, ch, n)
    c2 = c.reshape(bsz * nc, ch, n)

    y, s, atot = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, ch, 1, p),
                         lambda g, hi: (g, 0, 0, hi, 0)),
            pl.BlockSpec((1, 1, ch, 1),
                         lambda g, hi: (g, 0, 0, hi)),
            pl.BlockSpec((1, 1, ch, n), lambda g, hi: (g, 0, 0, 0)),
            pl.BlockSpec((1, 1, ch, n), lambda g, hi: (g, 0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, ch, 1, p),
                         lambda g, hi: (g, 0, 0, hi, 0)),
            pl.BlockSpec((1, 1, 1, n, p), lambda g, hi: (g, 0, hi, 0, 0)),
            pl.BlockSpec((1, 1, 1), lambda g, hi: (g, 0, hi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz * nc, 1, ch, h, p), jnp.float32),
            jax.ShapeDtypeStruct((bsz * nc, 1, h, n, p), jnp.float32),
            jax.ShapeDtypeStruct((bsz * nc, 1, h), jnp.float32),
        ],
        interpret=interpret,
    )(x2[:, None], a2[:, None], b2[:, None], c2[:, None])
    return (y.reshape(bsz, nc, ch, h, p),
            s.reshape(bsz, nc, h, n, p),
            atot.reshape(bsz, nc, h))
