"""jit'd public wrappers around the Pallas kernels.

On this container (CPU) kernels run with interpret=True; on a real TPU
backend the same call sites compile to Mosaic. ``use_pallas()`` central-
switches; model code goes through these wrappers only where the kernel is
profitable (full-seq attention, the NBL block GEMM, covariance updates).
Shapes are padded to block multiples here so kernels stay assert-simple.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.cov_accum import cov_accum
from repro.kernels.flash_attention import flash_attention
from repro.kernels.nbl_linear import nbl_linear


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jax.Array, axis: int, mult: int):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), n


@functools.partial(jax.jit, static_argnames=(
    "scale", "causal", "window", "softcap", "block_q", "block_k",
    "interpret"))
def attention(q, k, v, *, scale: Optional[float] = None, causal: bool = True,
              window: Optional[int] = None, softcap: Optional[float] = None,
              block_q: int = 128, block_k: int = 128,
              interpret: Optional[bool] = None) -> jax.Array:
    """Flash attention with seq/head-dim padding to kernel block multiples."""
    interpret = (not on_tpu()) if interpret is None else interpret
    s, t, d = q.shape[2], k.shape[2], q.shape[3]
    qp, _ = _pad_to(q, 2, block_q)
    kp, _ = _pad_to(k, 2, block_k)
    vp, _ = _pad_to(v, 2, block_k)
    # pad head_dim to the 128-lane register width
    qp, _ = _pad_to(qp, 3, 128)
    kp, _ = _pad_to(kp, 3, 128)
    vp, _ = _pad_to(vp, 3, 128)
    scale = d ** -0.5 if scale is None else scale  # scale by TRUE head dim
    # padded K positions are masked out by causal/window iff they are in the
    # future of every query; with right-padding kpos >= t > qpos, causal
    # masking handles it. Non-causal callers must pass exact multiples.
    assert causal or (t % block_k == 0 and s % block_q == 0)
    out = flash_attention(qp, kp, vp, scale=scale, causal=causal,
                          window=window, softcap=softcap, block_q=block_q,
                          block_k=block_k, interpret=interpret)
    return out[:, :, :s, :d]


@functools.partial(jax.jit, static_argnames=("residual", "interpret"))
def nbl_apply(x, w, b, *, residual: bool = True,
              interpret: Optional[bool] = None) -> jax.Array:
    """NBL replacement block on (B, S, d) activations."""
    interpret = (not on_tpu()) if interpret is None else interpret
    bsz, s, d = x.shape
    xt = x.reshape(bsz * s, d)
    xt, m = _pad_to(xt, 0, 256)
    out = nbl_linear(xt, w, b, residual=residual, interpret=interpret)
    return out[:m].reshape(bsz, s, d)


@functools.partial(jax.jit, static_argnames=("interpret",))
def cov_update(acc, x, y=None, *, interpret: Optional[bool] = None):
    """acc += yᵀx on (T, D) token blocks (y=None → Gram update)."""
    interpret = (not on_tpu()) if interpret is None else interpret
    xt, _ = _pad_to(x, 0, 512)      # zero rows contribute nothing
    yt = None if y is None else _pad_to(y, 0, 512)[0]
    return cov_accum(acc, xt, yt, interpret=interpret)


# re-exported oracles
attention_ref = ref.flash_attention_ref
nbl_apply_ref = ref.nbl_linear_ref
cov_update_ref = ref.cov_accum_ref
