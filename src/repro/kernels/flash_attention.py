"""Flash attention Pallas kernel (TPU target, interpret-validated on CPU).

Online-softmax tiling: grid (batch, q_heads, q_blocks, k_blocks) with the
K dimension innermost; running max/denominator/accumulator live in VMEM
scratch across the K sweep. Q/K/V tiles stream HBM→VMEM per BlockSpec, the
(block_q × block_k) logit tile and the two MXU matmuls stay in VMEM/VREGs —
O(S·block_k) memory instead of O(S²), which is exactly the prefill hot spot
NBL deletes on linearized layers (the speed comparison in benchmarks/).

Supports GQA (kv_head = q_head // rep via index_map), causal masking,
sliding windows and Gemma-2 logit soft-capping. MXU alignment: block sizes
multiples of 128; head_dim is the lane dimension (pad to 128 in ops.py).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e38


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: Optional[int],
            softcap: Optional[float], block_q: int, block_k: int,
            n_kblocks: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)              # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)              # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)              # (bk, d)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = jnp.ones(s.shape, jnp.bool_)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
    acc_scr[...] = (acc_scr[...] * corr[:, None]
                    + jax.lax.dot(p, v, preferred_element_type=jnp.float32))
    m_scr[...] = m_new

    @pl.when(ki == n_kblocks - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    scale: Optional[float] = None, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: (B, H, S, D); k, v: (B, KV, T, D), H % KV == 0. Returns (B, H, S, D)."""
    b, h, s, d = q.shape
    kv, t = k.shape[1], k.shape[2]
    assert h % kv == 0, (h, kv)
    rep = h // kv
    block_q = min(block_q, s)
    block_k = min(block_k, t)
    assert s % block_q == 0 and t % block_k == 0, (s, t, block_q, block_k)
    nq, nk = s // block_q, t // block_k
    scale = d ** -0.5 if scale is None else scale

    kern = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_k=block_k, n_kblocks=nk)

    return pl.pallas_call(
        kern,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bi, hi, qi, ki: (bi, hi // rep, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bi, hi, qi, ki: (bi, hi // rep, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),      # running max
            pltpu.VMEM((block_q,), jnp.float32),      # running denom
            pltpu.VMEM((block_q, d), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
