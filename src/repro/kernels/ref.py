"""Pure-jnp oracles for every Pallas kernel (allclose-swept in tests)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


def flash_attention_ref(q, k, v, *, scale: Optional[float] = None,
                        causal: bool = True, window: Optional[int] = None,
                        softcap: Optional[float] = None) -> jax.Array:
    """Naive full-softmax attention. q: (B,H,S,D); k, v: (B,KV,T,D)."""
    b, h, s, d = q.shape
    kv, t = k.shape[1], k.shape[2]
    rep = h // kv
    scale = d ** -0.5 if scale is None else scale
    k = jnp.repeat(k, rep, axis=1)
    v = jnp.repeat(v, rep, axis=1)
    logits = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhst,bhtd->bhsd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def nbl_linear_ref(x, w, b, *, residual: bool = True) -> jax.Array:
    y = x.astype(jnp.float32) @ w.astype(jnp.float32) + b.astype(jnp.float32)
    if residual:
        y = y + x.astype(jnp.float32)
    return y.astype(x.dtype)


def ssd_chunk_ref(x, a, b, c):
    """Intra-chunk SSD oracle. Shapes as kernels.ssd_chunk.
    Returns (y_intra, S (B,NC,H,N,P), a_tot)."""
    xf = x.astype(jnp.float32)
    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    cf = c.astype(jnp.float32)
    cs = jnp.cumsum(af, axis=2)                          # (B,NC,C,H)
    diff = cs[:, :, :, None, :] - cs[:, :, None, :, :]   # (B,NC,C,C,H)
    ch = x.shape[2]
    tri = jnp.tril(jnp.ones((ch, ch), bool))
    l_mat = jnp.where(tri[None, None, :, :, None], jnp.exp(diff), 0.0)
    cb = jnp.einsum("bzin,bzjn->bzij", cf, bf)
    y = jnp.einsum("bzij,bzijh,bzjhp->bzihp", cb, l_mat, xf)
    decay = jnp.exp(cs[:, :, -1:, :] - cs)               # (B,NC,C,H)
    s = jnp.einsum("bzch,bzcn,bzchp->bzhnp", decay, bf, xf)
    return y, s, cs[:, :, -1]


def cov_accum_ref(acc, x, y=None) -> jax.Array:
    y = x if y is None else y
    return acc + y.astype(jnp.float32).T @ x.astype(jnp.float32)
