"""Fused NBL replacement-block kernel: y = x @ W + b (+ x residual).

This is the layer the paper *inserts*: one (T, d) × (d, d) GEMM replacing
the whole attention sub-block. Fusing bias + residual means x is read from
HBM once and y written once (3 HBM tensor-touches total vs 5 for
matmul→add→add), and at d ≥ 2048 the kernel is MXU-bound — the ideal regime.

Tiling: grid (M/bm, N/bn, K/bk), K innermost, f32 VMEM accumulator; W tiles
stream through VMEM, the x tile is reused across the N sweep. Block sizes
are multiples of 128 (MXU systolic dims).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, b_ref, xres_ref, o_ref, acc_scr, *,
            n_kblocks: int, residual: bool):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    acc_scr[...] += jax.lax.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(ki == n_kblocks - 1)
    def _finish():
        out = acc_scr[...] + b_ref[...].astype(jnp.float32)[None, :]
        if residual:
            out = out + xres_ref[...].astype(jnp.float32)
        o_ref[...] = out.astype(o_ref.dtype)


def nbl_linear(x: jax.Array, w: jax.Array, b: jax.Array, *,
               residual: bool = True, block_m: int = 256,
               block_n: int = 256, block_k: int = 512,
               interpret: bool = False) -> jax.Array:
    """x: (M, K); w: (K, N); b: (N,). residual requires K == N."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and b.shape == (n,)
    if residual:
        assert k == n, "residual needs square W (d_model -> d_model)"
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    block_k = min(block_k, k)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0
    nk = k // block_k

    kern = functools.partial(_kernel, n_kblocks=nk, residual=residual)
    return pl.pallas_call(
        kern,
        grid=(m // block_m, n // block_n, nk),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda mi, ni, ki: (mi, ki)),
            pl.BlockSpec((block_k, block_n), lambda mi, ni, ki: (ki, ni)),
            pl.BlockSpec((block_n,), lambda mi, ni, ki: (ni,)),
            # residual tile: the (mi, ni) block of x (valid since K == N)
            pl.BlockSpec((block_m, block_n), lambda mi, ni, ki: (mi, ni)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n),
                               lambda mi, ni, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(x, w, b, x)
