"""Paged-attention decode Pallas kernel (TPU target, interpret-validated).

Single-token decode against the page-pool KV layout of models/paging.py:
each serving slot's K/V live in fixed-size, position-aligned pages scattered
through a per-layer pool, addressed by a per-slot page table.

Grid (slot, kv_head, logical_page) with the page sweep innermost. The page
table and per-slot lengths ride in as SCALAR-PREFETCH operands
(pltpu.PrefetchScalarGridSpec), so the K/V BlockSpec index_maps read the
*physical* page id for the current (slot, logical_page) cell and the
pallas_call machinery DMAs exactly that page HBM->VMEM — the gather IS the
block indexing, no materialized (B, T) copy. Running max/denominator/output
accumulator persist in VMEM scratch across the page sweep (online softmax,
same recurrence as kernels/flash_attention.py).

Masking is positional: logical page l covers absolute positions
[l*page_size, (l+1)*page_size); token t of slot b is valid iff
t < lengths[b], plus the sliding-window predicate and an allocated-page
check (unallocated table entries are clamped to page 0 by the index_map and
killed by the mask). GQA (q heads grouped per kv head), sliding window and
Gemma-2 logit soft-capping match kernels/flash_attention.py semantics.

MXU alignment for real TPUs wants page_size a multiple of the sublane tile
and head_dim padded to 128 lanes (ops.attention-style); interpret mode (this
container) accepts the tiny test shapes as-is.

``paged_decode`` is the call-site dispatcher: the Pallas kernel on TPU, the
pure-XLA gather reference (``paged_decode_xla``) elsewhere — the kernel is
validated against the reference in interpret mode by tests/test_paging.py.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e38


def _kernel(tbl_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
            m_scr, l_scr, acc_scr, *, scale: float, window: Optional[int],
            softcap: Optional[float], page_size: int, n_lpages: int):
    b = pl.program_id(0)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)              # (rep, hd)
    k = k_ref[0, 0].astype(jnp.float32)              # (page_size, hd)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    length = len_ref[b]                              # valid tokens (pos + 1)
    t = p * page_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = (t < length) & (tbl_ref[b, p] >= 0)
    if window is not None:
        mask &= (length - 1 - t) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    pr = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + pr.sum(axis=1)
    acc_scr[...] = (acc_scr[...] * corr[:, None]
                    + jax.lax.dot(pr, v, preferred_element_type=jnp.float32))
    m_scr[...] = m_new

    @pl.when(p == n_lpages - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def paged_attention(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                    page_tbl: jax.Array, lengths: jax.Array, *,
                    scale: Optional[float] = None,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    interpret: bool = False) -> jax.Array:
    """q: (B, KV, rep, hd); k_pages/v_pages: (n_pages, KV, page_size, hd);
    page_tbl: (B, n_lpages) int32 physical ids, -1 = unallocated;
    lengths: (B,) int32 valid tokens per slot (query sits at lengths-1).
    Returns (B, KV, rep, hd)."""
    b, kvh, rep, hd = q.shape
    n_pages, kvh2, page_size, _ = k_pages.shape
    assert kvh == kvh2, (kvh, kvh2)
    n_lpages = page_tbl.shape[1]
    scale = hd ** -0.5 if scale is None else scale

    kern = functools.partial(
        _kernel, scale=scale, window=window, softcap=softcap,
        page_size=page_size, n_lpages=n_lpages)

    def kv_map(bi, hi, pi, tbl, lens):
        # physical page for this (slot, logical page); clamp the -1 sentinel
        # to page 0 — the kernel mask kills those positions.
        return (jnp.maximum(tbl[bi, pi], 0), hi, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, kvh, n_lpages),
        in_specs=[
            pl.BlockSpec((1, 1, rep, hd),
                         lambda bi, hi, pi, tbl, lens: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, page_size, hd), kv_map),
            pl.BlockSpec((1, 1, page_size, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, rep, hd),
                               lambda bi, hi, pi, tbl, lens: (bi, hi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((rep,), jnp.float32),          # running max
            pltpu.VMEM((rep,), jnp.float32),          # running denom
            pltpu.VMEM((rep, hd), jnp.float32),       # output accumulator
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(page_tbl.astype(jnp.int32), lengths.astype(jnp.int32),
      q, k_pages, v_pages)


def paged_decode_xla(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                     page_tbl: jax.Array, lengths: jax.Array, *,
                     scale: Optional[float] = None,
                     window: Optional[int] = None,
                     softcap: Optional[float] = None) -> jax.Array:
    """Pure-XLA reference with identical masking semantics: gather pages via
    the table, one softmax over the logical sequence. Same shapes as
    ``paged_attention``; the serving path on non-TPU backends runs this."""
    b, kvh, rep, hd = q.shape
    n_pages, _, page_size, _ = k_pages.shape
    n_lpages = page_tbl.shape[1]
    scale = hd ** -0.5 if scale is None else scale

    idx = jnp.clip(page_tbl, 0)                       # (B, P); mask kills -1
    kg = k_pages[idx]                                 # (B, P, KV, ps, hd)
    vg = v_pages[idx]
    t_total = n_lpages * page_size
    kg = kg.transpose(0, 2, 1, 3, 4).reshape(b, kvh, t_total, hd)
    vg = vg.transpose(0, 2, 1, 3, 4).reshape(b, kvh, t_total, hd)

    s = jnp.einsum("bgrd,bgtd->bgrt", q.astype(jnp.float32),
                   kg.astype(jnp.float32),
                   preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    t = jnp.arange(t_total, dtype=jnp.int32)[None]        # (1, T)
    ln = lengths.astype(jnp.int32)[:, None]               # (B, 1)
    valid = (t < ln) & jnp.repeat(page_tbl >= 0, page_size, axis=1)
    if window is not None:
        valid &= (ln - 1 - t) < window
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)

    m = s.max(axis=-1, keepdims=True)
    pr = jnp.exp(s - m)
    denom = jnp.maximum(pr.sum(axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bgrt,bgtd->bgrd", (pr / denom),
                     vg.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


def paged_mixed_xla(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                    page_tbl: jax.Array, row_pos: jax.Array,
                    row_len: jax.Array, *,
                    scale: Optional[float] = None,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None) -> jax.Array:
    """Mixed-row (multi-query) paged attention, pure XLA: each slot
    carries up to W new tokens at absolute positions ``row_pos[b] + i``
    (valid while ``i < row_len[b]``; ``row_len 0`` = inactive row) and
    token i attends logical positions ``[0, row_pos[b] + i]`` of its
    slot's sequence — write-before-attend puts the in-chunk keys in the
    pages, so the per-query causal mask alone gives exact chunk
    semantics. ONE page gather per SLOT feeds a dense masked softmax
    (the W queries share the gathered keys as a GEMM), which is what
    makes a wide chunk row cost prefill-like compute instead of W
    separate decode gathers.

    q: (B, KV, rep, W, hd); k_pages/v_pages: (n_pages, KV, page_size,
    hd); page_tbl: (B, n_lpages) int32, -1 = unallocated; row_pos /
    row_len: (B,) int32. Returns (B, KV, rep, W, hd); invalid query
    positions come back all-zero (denominator-guarded, finite)."""
    b, kvh, rep, w, hd = q.shape
    n_pages, _, page_size, _ = k_pages.shape
    n_lpages = page_tbl.shape[1]
    scale = hd ** -0.5 if scale is None else scale

    idx = jnp.clip(page_tbl, 0)                       # (B, P); mask kills -1
    t_total = n_lpages * page_size
    kg = k_pages[idx].transpose(0, 2, 1, 3, 4).reshape(b, kvh, t_total, hd)
    vg = v_pages[idx].transpose(0, 2, 1, 3, 4).reshape(b, kvh, t_total, hd)

    s = jnp.einsum("bgrwd,bgtd->bgrwt", q.astype(jnp.float32),
                   kg.astype(jnp.float32),
                   preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    t = jnp.arange(t_total, dtype=jnp.int32)[None, None]        # (1, 1, T)
    qpos = (row_pos.astype(jnp.int32)[:, None]
            + jnp.arange(w, dtype=jnp.int32)[None, :])          # (B, W)
    qvalid = jnp.arange(w, dtype=jnp.int32)[None, :] \
        < row_len.astype(jnp.int32)[:, None]                    # (B, W)
    valid = (t <= qpos[:, :, None]) \
        & jnp.repeat(page_tbl >= 0, page_size, axis=1)[:, None, :]
    if window is not None:
        valid &= (qpos[:, :, None] - t) < window
    valid &= qvalid[:, :, None]
    s = jnp.where(valid[:, None, None, :, :], s, NEG_INF)

    m = s.max(axis=-1, keepdims=True)
    pr = jnp.exp(s - m)
    denom = jnp.maximum(pr.sum(axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bgrwt,bgtd->bgrwd", (pr / denom),
                     vg.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


def paged_mixed(q, k_pages, v_pages, page_tbl, row_pos, row_len, *,
                scale: Optional[float] = None, window: Optional[int] = None,
                softcap: Optional[float] = None,
                use_kernel: Optional[bool] = None) -> jax.Array:
    """Backend dispatcher for the mixed-row step attention: on TPU the
    W queries run as B*W virtual single-token rows through the Mosaic
    ``paged_attention`` kernel (the page sweep's BlockSpec gather keeps
    that cheap on-device); elsewhere the dense-gather XLA path, whose
    shared per-slot gather is the fast shape for the serving loop."""
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if not use_kernel:
        return paged_mixed_xla(q, k_pages, v_pages, page_tbl, row_pos,
                               row_len, scale=scale, window=window,
                               softcap=softcap)
    b, kvh, rep, w, hd = q.shape
    qv = q.transpose(0, 3, 1, 2, 4).reshape(b * w, kvh, rep, hd)
    tpos = (row_pos.astype(jnp.int32)[:, None]
            + jnp.arange(w, dtype=jnp.int32)[None, :])
    valid = jnp.arange(w, dtype=jnp.int32)[None, :] \
        < row_len.astype(jnp.int32)[:, None]
    lens = jnp.where(valid, tpos + 1, 0).reshape(-1)
    out = paged_attention(qv, k_pages, v_pages,
                          jnp.repeat(page_tbl, w, axis=0), lens,
                          scale=scale, window=window, softcap=softcap)
    return out.reshape(b, w, kvh, rep, hd).transpose(0, 2, 3, 1, 4)


def paged_decode(q, k_pages, v_pages, page_tbl, lengths, *,
                 scale: Optional[float] = None, window: Optional[int] = None,
                 softcap: Optional[float] = None,
                 use_kernel: Optional[bool] = None) -> jax.Array:
    """Backend dispatcher: Mosaic kernel on TPU, XLA gather reference
    elsewhere (interpret-mode kernel execution is test-only — it is far
    slower than the XLA path for the serving loop)."""
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if use_kernel:
        return paged_attention(q, k_pages, v_pages, page_tbl, lengths,
                               scale=scale, window=window, softcap=softcap)
    return paged_decode_xla(q, k_pages, v_pages, page_tbl, lengths,
                            scale=scale, window=window, softcap=softcap)
