"""Covariance-accumulation kernel: C += Xᵀ X (the calibration hot spot).

The paper's calibration cost is O(s·t·d²), dominated by the Gram updates
ΣXᵀX / ΣYXᵀ / ΣY₊Y₊ᵀ (App. D). On TPU we tile the token dimension through
VMEM: grid (D/bi, D/bj, T/bt) with tokens innermost, an f32 VMEM accumulator
per (bi, bj) output tile, and the running HBM accumulator added once at the
final token block (input_output_aliased so the (d, d) buffer is updated in
place, not reallocated per batch).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(xi_ref, xj_ref, acc_ref, o_ref, scr, *, n_tblocks: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        scr[...] = jnp.zeros_like(scr)

    xi = xi_ref[...].astype(jnp.float32)       # (bt, bi)
    xj = xj_ref[...].astype(jnp.float32)       # (bt, bj)
    scr[...] += jax.lax.dot_general(xi, xj, (((0,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)

    @pl.when(ti == n_tblocks - 1)
    def _finish():
        o_ref[...] = acc_ref[...] + scr[...]


def cov_accum(acc: jax.Array, x: jax.Array, y: jax.Array | None = None, *,
              block_d: int = 256, block_t: int = 512,
              interpret: bool = False) -> jax.Array:
    """acc: (Dy, Dx) f32 running sum; x: (T, Dx). Returns acc + yᵀx
    (y defaults to x → Gram update acc + xᵀx)."""
    y = x if y is None else y
    t, dx = x.shape
    dy = y.shape[1]
    assert y.shape[0] == t and acc.shape == (dy, dx)
    bi = min(block_d, dy)
    bj = min(block_d, dx)
    bt = min(block_t, t)
    assert dy % bi == 0 and dx % bj == 0 and t % bt == 0
    nt = t // bt

    kern = functools.partial(_kernel, n_tblocks=nt)
    return pl.pallas_call(
        kern,
        grid=(dy // bi, dx // bj, nt),
        in_specs=[
            pl.BlockSpec((bt, bi), lambda i, j, ti: (ti, i)),
            pl.BlockSpec((bt, bj), lambda i, j, ti: (ti, j)),
            pl.BlockSpec((bi, bj), lambda i, j, ti: (i, j)),
        ],
        out_specs=pl.BlockSpec((bi, bj), lambda i, j, ti: (i, j)),
        out_shape=jax.ShapeDtypeStruct((dy, dx), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bi, bj), jnp.float32)],
        input_output_aliases={2: 0},
        interpret=interpret,
    )(y, x, acc)
