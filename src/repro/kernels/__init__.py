"""Pallas TPU kernels for the paper's compute hot-spots.

  flash_attention  the prefill cost NBL removes (O(S²) baseline layer)
  nbl_linear       the fused replacement block NBL inserts (x@W+b+x)
  cov_accum        the calibration Gram-update hot spot (C += XᵀX)
  ssd_chunk        Mamba2 intra-chunk SSD tile (the H1 memory-bound fix)

Each has a pure-jnp oracle in ref.py and jit'd shape-safe wrappers in
ops.py; validated with interpret=True on CPU, targeted at TPU Mosaic.
"""
from repro.kernels.cov_accum import cov_accum  # noqa: F401
from repro.kernels.flash_attention import flash_attention  # noqa: F401
from repro.kernels.nbl_linear import nbl_linear  # noqa: F401
from repro.kernels.ssd_chunk import ssd_chunk  # noqa: F401
from repro.kernels import ops, ref  # noqa: F401
