"""Config system: model configs, block/stack plans, shapes, registry.

A model is described by a *stack plan*: an ordered tuple of ``StackGroup``s,
each repeating a short ``unit`` of ``Block`` descriptors. Homogeneous repeated
units are executed with ``lax.scan`` over stacked params, keeping HLO size
(and therefore compile time and code size on a 512-way dry-run) O(1) in depth.

NBL surgery (repro/core/surgery.py) rewrites the stack plan: the attention
sub-block of selected layers becomes ``kind="nbl"`` (a single linear layer with
retained residual, per Algorithm 2 of the paper) and params are re-sliced so
every group stays homogeneous and scannable.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Optional


# --------------------------------------------------------------------------
# Block / stack plan
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class Block:
    """One residual block (mixer + optional ffn) in the stack.

    kind:
      "attn"       self-attention (GQA; optional sliding window / softcap)
      "cross_attn" cross-attention over encoder/frontend embeddings (VLM)
      "mamba"      Mamba2 SSD block (attention-free; has no separate ffn)
      "nbl"        NBL-linearized attention: y = W x + b (+ x residual kept)
      "drop"       attention removed entirely (Attn DROP baseline): y = x
    ffn:
      "dense" | "moe" | "none"
    window: sliding-window size for local attention (None = global).
    shared: params for this block are shared across all repeats of the group
      (Zamba2 shared attention block).
    """
    kind: str = "attn"
    ffn: str = "dense"
    window: Optional[int] = None
    shared: bool = False

    def replace(self, **kw) -> "Block":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class StackGroup:
    unit: tuple[Block, ...]
    repeat: int = 1

    @property
    def n_blocks(self) -> int:
        return len(self.unit) * self.repeat


# --------------------------------------------------------------------------
# Sub-configs
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int               # ffn hidden size per routed expert
    n_shared: int = 0           # always-on shared experts (DeepSeek-MoE)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    dense_ff: int = 0           # ffn size of leading dense layers (0 = d_ff)


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2             # d_inner = expand * d_model
    conv_kernel: int = 4
    chunk: int = 256            # SSD chunk length (training/prefill)

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


# --------------------------------------------------------------------------
# Model config
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | hybrid | ssm | vlm | audio
    d_model: int
    vocab_size: int
    stack: tuple[StackGroup, ...]
    # attention geometry (ignored by pure-SSM archs)
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    # features
    mlp_act: str = "silu"       # silu | geglu
    norm_eps: float = 1e-6
    rope_theta: float = 10_000.0
    attn_logit_softcap: Optional[float] = None
    final_logit_softcap: Optional[float] = None
    attn_scale: Optional[float] = None     # None -> 1/sqrt(head_dim)
    tie_embeddings: bool = True
    # sub-configs
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # modality frontend stub: None | "vision" | "audio"
    frontend: Optional[str] = None
    n_frontend_tokens: int = 0  # e.g. image patch tokens fed to cross-attn
    # long-context capability: True iff every attention block is windowed or
    # the arch is SSM/hybrid (bounded state). Gates the long_500k shape.
    sub_quadratic: bool = False
    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # NBL bookkeeping: indices of attention blocks already linearized (used to
    # build compressed configs for dry-runs without running calibration).
    nbl_layers: tuple[int, ...] = ()
    # training
    max_seq_len: int = 8192

    # -- derived ---------------------------------------------------------
    @property
    def n_blocks(self) -> int:
        return sum(g.n_blocks for g in self.stack)

    def blocks(self) -> list[Block]:
        """Flattened per-position block descriptors."""
        out: list[Block] = []
        for g in self.stack:
            out.extend(list(g.unit) * g.repeat)
        return out

    def attn_layer_indices(self) -> list[int]:
        """Global block indices whose mixer is self-attention (NBL candidates).

        Cross-attention blocks are excluded (bimodal inputs, see DESIGN.md);
        shared blocks are excluded (linearizing one invocation would have to
        linearize all); mamba blocks are excluded from the *default* candidate
        set but can be targeted with core.nbl(block_kinds=("mamba",)).
        """
        return [i for i, b in enumerate(self.blocks())
                if b.kind == "attn" and not b.shared]

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def n_params(self) -> int:
        """Analytic parameter count (matches init exactly; asserted in tests)."""
        from repro.models.transformer import count_params
        return count_params(self)

    def n_active_params(self) -> int:
        from repro.models.transformer import count_params
        return count_params(self, active_only=True)


# --------------------------------------------------------------------------
# Stack-plan builders
# --------------------------------------------------------------------------

def dense_stack(n_layers: int, *, window: Optional[int] = None,
                pattern: tuple[Optional[int], ...] = ()) -> tuple[StackGroup, ...]:
    """Uniform dense stack; ``pattern`` gives a cycle of per-layer windows
    (e.g. (4096, None) for Gemma-2 local/global alternation)."""
    if pattern:
        period = len(pattern)
        assert n_layers % period == 0, (n_layers, pattern)
        unit = tuple(Block(kind="attn", ffn="dense", window=w) for w in pattern)
        return (StackGroup(unit=unit, repeat=n_layers // period),)
    unit = (Block(kind="attn", ffn="dense", window=window),)
    return (StackGroup(unit=unit, repeat=n_layers),)


def moe_stack(n_layers: int, n_dense_lead: int = 1) -> tuple[StackGroup, ...]:
    groups = []
    if n_dense_lead:
        groups.append(StackGroup(unit=(Block(kind="attn", ffn="dense"),),
                                 repeat=n_dense_lead))
    groups.append(StackGroup(unit=(Block(kind="attn", ffn="moe"),),
                             repeat=n_layers - n_dense_lead))
    return tuple(groups)


def mamba_stack(n_layers: int) -> tuple[StackGroup, ...]:
    return (StackGroup(unit=(Block(kind="mamba", ffn="none"),),
                       repeat=n_layers),)


def zamba_stack(n_mamba: int, attn_every: int) -> tuple[StackGroup, ...]:
    """Zamba2: mamba backbone with a *shared* full transformer block applied
    after every ``attn_every`` mamba blocks. Trailing mamba layers form a
    second group."""
    n_groups = n_mamba // attn_every
    trailing = n_mamba - n_groups * attn_every
    unit = tuple(Block(kind="mamba", ffn="none") for _ in range(attn_every))
    unit = unit + (Block(kind="attn", ffn="dense", shared=True),)
    groups = [StackGroup(unit=unit, repeat=n_groups)]
    if trailing:
        groups.append(StackGroup(unit=(Block(kind="mamba", ffn="none"),),
                                 repeat=trailing))
    return tuple(groups)


def vlm_stack(n_self: int, cross_every: int) -> tuple[StackGroup, ...]:
    """Llama-3.2-Vision-style: a cross-attention block after every
    ``cross_every`` self-attention blocks."""
    n_groups = n_self // cross_every
    unit = tuple(Block(kind="attn", ffn="dense") for _ in range(cross_every))
    unit = unit + (Block(kind="cross_attn", ffn="dense"),)
    return (StackGroup(unit=unit, repeat=n_groups),)


# --------------------------------------------------------------------------
# Input shapes (assigned)
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str            # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeConfig] = {
    "train_4k":    ShapeConfig("train_4k",    "train",   4_096,   256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768,  32),
    "decode_32k":  ShapeConfig("decode_32k",  "decode",  32_768,  128),
    # continuous-batching engine decode: 128 serving slots, per-slot pos
    "serve_32k":   ShapeConfig("serve_32k",   "serve",   32_768,  128),
    # paged engine decode: page-pool cache + per-slot page table
    "serve_paged_32k": ShapeConfig("serve_paged_32k", "serve_paged",
                                   32_768, 128),
    # prefix-sharing partial prefill: 32 suffixes behind one shared
    # 32k-token prompt prefix resident in the paged pools
    "prefill_shared_32k": ShapeConfig("prefill_shared_32k",
                                      "prefill_shared", 32_768, 32),
    # chunked prefill: one 4k page-aligned chunk per request resuming
    # behind 28k already-prefilled tokens of its OWN prompt (the engine's
    # chunked_prefill jit — same partial-prefill signature as
    # prefill_shared; only the prefix table's provenance differs)
    "prefill_chunked_4k": ShapeConfig("prefill_chunked_4k",
                                      "prefill_chunked", 4_096, 32),
    # speculative verify: one candidate block (page tail + γ draft tokens)
    # scored behind the slot's committed pages — batch=1, the engine's
    # per-slot cache-extend (launch/engine._run_spec_verify)
    "spec_verify_4k": ShapeConfig("spec_verify_4k", "spec_verify",
                                  4_096, 1),
    # fused engine step: the plan->execute->commit pipeline's ONE mixed
    # dispatch — 128 serving slots at row width 4k (decode rows valid at
    # width 1, chunk rows up to the full width; launch/engine._step_fused)
    "fused_step_4k": ShapeConfig("fused_step_4k", "fused_step", 4_096, 128),
    "long_500k":   ShapeConfig("long_500k",   "decode",  524_288, 1),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """(applicable, reason-if-not). long_500k needs sub-quadratic attention;
    prefill_shared needs a resumable (non-SSM) stack with paged KV."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("full-attention arch: 524k dense KV cache/attention is "
                       "the quadratic regime this shape excludes (DESIGN.md)")
    if shape.kind in ("prefill_shared", "prefill_chunked", "spec_verify",
                      "fused_step"):
        if any(b.kind == "mamba" for b in cfg.blocks()):
            return False, ("SSM stack: partial prefill cannot resume scanned "
                           "state mid-sequence (models/transformer.prefill)")
        if any(b.kind == "cross_attn" for b in cfg.blocks()):
            return False, ("cross-attention stack: prefill needs per-request "
                           "enc embeddings this shape does not carry (and "
                           "prefix KV is not shareable by prompt tokens — "
                           "launch/engine.py)")
        if not any(b.kind == "attn" for b in cfg.blocks()):
            return False, ("no caching attention layer: nothing to resume "
                           "through the page table")
    return True, ""


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------

_REGISTRY: dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn: Callable[[], ModelConfig]):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str, **overrides: Any) -> ModelConfig:
    import repro.configs  # noqa: F401  (triggers per-arch module imports)
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    cfg = _REGISTRY[name]()
    return cfg.replace(**overrides) if overrides else cfg


def list_configs() -> list[str]:
    import repro.configs  # noqa: F401
    return sorted(_REGISTRY)


# --------------------------------------------------------------------------
# Reduced (smoke-test) configs
# --------------------------------------------------------------------------

def reduced(cfg: ModelConfig, *, d_model: int = 64, layers_cap: int = 4,
            vocab: int = 512) -> ModelConfig:
    """Shrink any config to a CPU-smoke-testable size while preserving its
    family features (alternation patterns, MoE routing, shared blocks,
    softcaps, GeGLU, cross-attn, SSD...)."""
    head_dim = 16
    n_heads = max(2, d_model // (2 * head_dim))   # leave room for q dim > d
    n_kv = max(1, n_heads // 2) if cfg.n_kv_heads < cfg.n_heads else n_heads

    # shrink stack: cap repeats, keep unit structure
    stack = []
    for g in cfg.stack:
        rep = min(g.repeat, max(1, layers_cap // max(1, len(g.unit))))
        stack.append(StackGroup(unit=g.unit, repeat=rep))
    moe = None
    if cfg.moe is not None:
        moe = MoEConfig(n_experts=8, top_k=min(cfg.moe.top_k, 2), d_expert=32,
                        n_shared=min(cfg.moe.n_shared, 1),
                        capacity_factor=2.0, dense_ff=128)
    ssm = None
    if cfg.ssm is not None:
        ssm = SSMConfig(d_state=16, head_dim=16, expand=2, conv_kernel=4,
                        chunk=32)
    return cfg.replace(
        d_model=d_model, vocab_size=vocab, stack=tuple(stack),
        n_heads=n_heads, n_kv_heads=n_kv, head_dim=head_dim,
        d_ff=4 * d_model, moe=moe, ssm=ssm,
        n_frontend_tokens=min(cfg.n_frontend_tokens, 8) or cfg.n_frontend_tokens,
        max_seq_len=128, param_dtype="float32", compute_dtype="float32",
    )
