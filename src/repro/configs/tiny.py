"""Tiny configs for tests/examples: small but structurally faithful."""
from repro.configs.base import (
    ModelConfig, MoEConfig, SSMConfig, dense_stack, moe_stack, mamba_stack,
    register, vlm_stack, zamba_stack,
)


@register("tiny-dense")
def tiny_dense() -> ModelConfig:
    return ModelConfig(
        name="tiny-dense", family="dense", d_model=64, vocab_size=512,
        stack=dense_stack(6), n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=256, mlp_act="silu", tie_embeddings=True, sub_quadratic=False,
        param_dtype="float32", compute_dtype="float32", max_seq_len=128,
    )


@register("tiny-gemma")
def tiny_gemma() -> ModelConfig:
    return tiny_dense().replace(
        name="tiny-gemma", stack=dense_stack(4, pattern=(32, None)),
        mlp_act="geglu", attn_logit_softcap=50.0, final_logit_softcap=30.0,
    )


@register("tiny-swa")
def tiny_swa() -> ModelConfig:
    return tiny_dense().replace(
        name="tiny-swa", stack=dense_stack(4, window=32), sub_quadratic=True)


@register("tiny-moe")
def tiny_moe() -> ModelConfig:
    return tiny_dense().replace(
        name="tiny-moe", family="moe", stack=moe_stack(4, n_dense_lead=1),
        moe=MoEConfig(n_experts=8, top_k=2, d_expert=64, n_shared=1,
                      capacity_factor=2.0, dense_ff=256),
    )


@register("tiny-mamba")
def tiny_mamba() -> ModelConfig:
    return ModelConfig(
        name="tiny-mamba", family="ssm", d_model=64, vocab_size=512,
        stack=mamba_stack(4), d_ff=0, tie_embeddings=True,
        ssm=SSMConfig(d_state=16, head_dim=16, expand=2, conv_kernel=4,
                      chunk=16),
        sub_quadratic=True, param_dtype="float32", compute_dtype="float32",
        max_seq_len=128,
    )


@register("tiny-zamba")
def tiny_zamba() -> ModelConfig:
    return tiny_mamba().replace(
        name="tiny-zamba", family="hybrid", stack=zamba_stack(5, attn_every=2),
        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=256,
    )


@register("tiny-vlm")
def tiny_vlm() -> ModelConfig:
    return tiny_dense().replace(
        name="tiny-vlm", family="vlm", stack=vlm_stack(n_self=4, cross_every=2),
        frontend="vision", n_frontend_tokens=8, tie_embeddings=False,
    )


@register("tiny-audio")
def tiny_audio() -> ModelConfig:
    return tiny_dense().replace(
        name="tiny-audio", family="audio", vocab_size=64, frontend="audio",
        tie_embeddings=False,
    )
