"""MusicGen-medium [arXiv:2306.05284; hf].

48L decoder-only transformer over EnCodec tokens: d_model=1536, 24H (MHA
kv=24), d_ff=6144, vocab=2048. The EnCodec frontend is a STUB per spec:
input_specs() provides precomputed frame embeddings / codebook token ids.
"""
from repro.configs.base import ModelConfig, dense_stack, register


@register("musicgen-medium")
def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium",
        family="audio",
        d_model=1536,
        vocab_size=2048,
        stack=dense_stack(48),
        n_heads=24,
        n_kv_heads=24,
        head_dim=64,
        d_ff=6144,
        mlp_act="silu",
        tie_embeddings=False,
        frontend="audio",
        n_frontend_tokens=0,
        param_dtype="bfloat16",  # bf16 master weights + f32 Adam moments
        sub_quadratic=False,
    )
