"""Llama-3.2-Vision-11B backbone [hf:meta-llama/Llama-3.2-11B-Vision; unverified].

40 self-attn layers, d_model=4096, 32H (GQA kv=8), d_ff=14336, vocab=128256,
with cross-attention image layers inserted every 5 self-attn layers (8 total).
The vision frontend is a STUB per spec: input_specs() provides precomputed
patch embeddings (batch, n_patches, d_model) consumed by the cross-attn blocks.
"""
from repro.configs.base import ModelConfig, register, vlm_stack


@register("llama-3.2-vision-11b")
def config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-11b",
        family="vlm",
        d_model=4096,
        vocab_size=128_256,
        stack=vlm_stack(n_self=40, cross_every=5),
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14_336,
        mlp_act="silu",
        rope_theta=500_000.0,
        tie_embeddings=False,
        frontend="vision",
        n_frontend_tokens=1600,   # precomputed patch embeddings
        param_dtype="bfloat16",  # bf16 master weights + f32 Adam moments
        sub_quadratic=False,
    )
