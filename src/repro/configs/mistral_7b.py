"""Mistral-7B [arXiv:2310.06825] -- one of the paper's own eval models.

32L, d_model=4096, 32H (GQA kv=8), d_ff=14336, vocab=32000, SWA window 4096.
"""
from repro.configs.base import ModelConfig, dense_stack, register


@register("mistral-7b")
def config() -> ModelConfig:
    return ModelConfig(
        name="mistral-7b",
        family="dense",
        d_model=4096,
        vocab_size=32_000,
        stack=dense_stack(32, window=4096),
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14_336,
        mlp_act="silu",
        tie_embeddings=False,
        param_dtype="bfloat16",  # bf16 master weights + f32 Adam moments
        sub_quadratic=True,
    )
