"""Gemma-7B [arXiv:2403.08295; hf].

28L, d_model=3072, 16H (kv=16, MHA), head_dim=256, d_ff=24576 (GeGLU),
vocab=256000, tied embeddings.
"""
from repro.configs.base import ModelConfig, dense_stack, register


@register("gemma-7b")
def config() -> ModelConfig:
    return ModelConfig(
        name="gemma-7b",
        family="dense",
        d_model=3072,
        vocab_size=256_000,
        stack=dense_stack(28),
        n_heads=16,
        n_kv_heads=16,
        head_dim=256,
        d_ff=24_576,
        mlp_act="geglu",
        tie_embeddings=True,
        param_dtype="bfloat16",  # bf16 master weights + f32 Adam moments
        sub_quadratic=False,
    )
