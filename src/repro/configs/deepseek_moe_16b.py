"""DeepSeek-MoE-16B [arXiv:2401.06066; hf].

28L, d_model=2048, 16H (MHA kv=16), routed-expert d_ff=1408, vocab=102400.
Fine-grained MoE: 64 routed experts top-6 + 2 shared experts; first layer has
a dense FFN (d_ff=10944).
"""
from repro.configs.base import ModelConfig, MoEConfig, moe_stack, register


@register("deepseek-moe-16b")
def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b",
        family="moe",
        d_model=2048,
        vocab_size=102_400,
        stack=moe_stack(28, n_dense_lead=1),
        n_heads=16,
        n_kv_heads=16,
        head_dim=128,
        d_ff=1408,
        mlp_act="silu",
        tie_embeddings=False,
        moe=MoEConfig(n_experts=64, top_k=6, d_expert=1408, n_shared=2,
                      capacity_factor=1.25, dense_ff=10_944),
        param_dtype="bfloat16",  # bf16 master weights + f32 Adam moments
        sub_quadratic=False,
    )
