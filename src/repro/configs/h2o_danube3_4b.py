"""H2O-Danube3-4B [arXiv:2401.16818; unverified].

24L, d_model=3840, 32H (GQA kv=8), d_ff=10240, vocab=32000. Llama+Mistral mix
with sliding-window attention (window 4096) -> sub-quadratic, long_500k runs.
"""
from repro.configs.base import ModelConfig, dense_stack, register


@register("h2o-danube-3-4b")
def config() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-3-4b",
        family="dense",
        d_model=3840,
        vocab_size=32_000,
        stack=dense_stack(24, window=4096),
        n_heads=32,
        n_kv_heads=8,
        head_dim=120,
        d_ff=10_240,
        mlp_act="silu",
        tie_embeddings=False,
        param_dtype="bfloat16",  # bf16 master weights + f32 Adam moments
        sub_quadratic=True,  # every layer windowed: KV bounded by window
    )
