"""MiniCPM-2B [arXiv:2404.06395; hf].

40L, d_model=2304, 36H (GQA kv=36 -> MHA), d_ff=5760, vocab=122753.
Llama-like architecture; trained with the WSD (warmup-stable-decay) schedule,
which is implemented in repro/optim/schedules.py and selected by this config.
"""
from repro.configs.base import ModelConfig, dense_stack, register


@register("minicpm-2b")
def config() -> ModelConfig:
    return ModelConfig(
        name="minicpm-2b",
        family="dense",
        d_model=2304,
        vocab_size=122_753,
        stack=dense_stack(40),
        n_heads=36,
        n_kv_heads=36,
        head_dim=64,
        d_ff=5760,
        mlp_act="silu",
        tie_embeddings=True,
        param_dtype="bfloat16",  # bf16 master weights + f32 Adam moments
        sub_quadratic=False,
    )


# training-schedule hint consumed by launch/train.py
SCHEDULE = "wsd"
