"""Gemma-2 2B [arXiv:2408.00118; hf].

26L, d_model=2304, 8 heads (GQA kv=4), head_dim=256, d_ff=9216 (GeGLU),
vocab=256000. Local(4096)/global alternating attention, attn logit softcap 50,
final logit softcap 30, tied embeddings.
"""
from repro.configs.base import ModelConfig, dense_stack, register


@register("gemma2-2b")
def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-2b",
        family="dense",
        d_model=2304,
        vocab_size=256_000,
        stack=dense_stack(26, pattern=(4096, None)),  # local, global, ...
        n_heads=8,
        n_kv_heads=4,
        head_dim=256,
        d_ff=9216,
        mlp_act="geglu",
        attn_logit_softcap=50.0,
        final_logit_softcap=30.0,
        tie_embeddings=True,
        param_dtype="bfloat16",  # bf16 master weights + f32 Adam moments
        sub_quadratic=False,  # global layers every other block
    )
