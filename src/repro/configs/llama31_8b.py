"""Llama-3.1-8B [arXiv:2407.21783] -- one of the paper's own eval models.

32L, d_model=4096, 32H (GQA kv=8), d_ff=14336, vocab=128256.
"""
from repro.configs.base import ModelConfig, dense_stack, register


@register("llama-3.1-8b")
def config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.1-8b",
        family="dense",
        d_model=4096,
        vocab_size=128_256,
        stack=dense_stack(32),
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14_336,
        mlp_act="silu",
        rope_theta=500_000.0,
        tie_embeddings=False,
        param_dtype="bfloat16",  # bf16 master weights + f32 Adam moments
        sub_quadratic=False,
    )
