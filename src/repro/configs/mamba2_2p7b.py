"""Mamba2-2.7B [arXiv:2405.21060; unverified].

64L, d_model=2560, attention-free, ssm_state=128, vocab=50280. SSD
(state-space duality) blocks; d_inner=5120, 80 heads of dim 64.

NBL applicability: the arch has no self-attention layers, so the paper's
default target set is empty (DESIGN.md §Arch-applicability). The arch is
implemented WITHOUT the technique; the generic block-NBL path can still
linearize SSD mixers via core.nbl(block_kinds=("mamba",)) as an ablation.
"""
from repro.configs.base import ModelConfig, SSMConfig, mamba_stack, register


@register("mamba2-2.7b")
def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-2.7b",
        family="ssm",
        d_model=2560,
        vocab_size=50_280,
        stack=mamba_stack(64),
        d_ff=0,
        tie_embeddings=True,
        ssm=SSMConfig(d_state=128, head_dim=64, expand=2, conv_kernel=4,
                      chunk=256),
        param_dtype="bfloat16",  # bf16 master weights + f32 Adam moments
        sub_quadratic=True,
    )
