"""Zamba2-1.2B [arXiv:2411.15242; hf].

38 Mamba2 layers, d_model=2048, ssm_state=64, plus a *shared* full transformer
block (32H MHA kv=32, d_ff=8192) applied after every 6 mamba blocks (6
invocations + 2 trailing mamba layers). Hybrid -> sub-quadratic, long_500k runs
(each shared-attn invocation keeps its own KV cache; decode is O(1) state for
mamba blocks and O(n) reads for the 6 attention caches).
"""
from repro.configs.base import ModelConfig, SSMConfig, register, zamba_stack


@register("zamba2-1.2b")
def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b",
        family="hybrid",
        d_model=2048,
        vocab_size=32_000,
        stack=zamba_stack(n_mamba=38, attn_every=6),
        n_heads=32,
        n_kv_heads=32,
        head_dim=64,
        d_ff=8192,
        mlp_act="silu",
        tie_embeddings=True,
        ssm=SSMConfig(d_state=64, head_dim=64, expand=2, conv_kernel=4,
                      chunk=256),
        param_dtype="bfloat16",  # bf16 master weights + f32 Adam moments
        sub_quadratic=True,
    )
