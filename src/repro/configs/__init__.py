"""Arch config registry. Importing this package registers all configs."""
from repro.configs.base import (  # noqa: F401
    Block, MoEConfig, ModelConfig, SSMConfig, ShapeConfig, SHAPES, StackGroup,
    dense_stack, get_config, list_configs, mamba_stack, moe_stack, reduced,
    register, shape_applicable, vlm_stack, zamba_stack,
)

# per-arch modules (each registers itself)
from repro.configs import (  # noqa: F401
    gemma2_2b, h2o_danube3_4b, minicpm_2b, gemma_7b, llama32_vision_11b,
    kimi_k2_1t, deepseek_moe_16b, zamba2_1p2b, mamba2_2p7b, musicgen_medium,
    mistral_7b, llama31_8b, tiny,
)

ASSIGNED_ARCHS = (
    "gemma2-2b", "h2o-danube-3-4b", "minicpm-2b", "gemma-7b",
    "llama-3.2-vision-11b", "kimi-k2-1t-a32b", "deepseek-moe-16b",
    "zamba2-1.2b", "mamba2-2.7b", "musicgen-medium",
)
PAPER_ARCHS = ("mistral-7b", "llama-3.1-8b")
