"""Kimi-K2 1T-A32B [arXiv:2501.kimi2; unverified, paper-table].

61L, d_model=7168, 64H (GQA kv=8), routed-expert d_ff=2048, vocab=163840,
MoE 384 experts top-8 + 1 shared expert, first layer dense.
"""
from repro.configs.base import ModelConfig, MoEConfig, moe_stack, register


@register("kimi-k2-1t-a32b")
def config() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b",
        family="moe",
        d_model=7168,
        vocab_size=163_840,
        stack=moe_stack(61, n_dense_lead=1),
        n_heads=64,
        n_kv_heads=8,
        head_dim=112,
        d_ff=2048,
        mlp_act="silu",
        rope_theta=50_000.0,
        tie_embeddings=False,
        moe=MoEConfig(n_experts=384, top_k=8, d_expert=2048, n_shared=1,
                      capacity_factor=1.25, dense_ff=18_432),
        sub_quadratic=False,
        # 1T params: bf16 master weights + int8-EF Adam moments (see
        # optim/ and EXPERIMENTS.md kimi memory note)
        param_dtype="bfloat16",
    )
