"""Host-side step planning for the fused engine pipeline (plan → execute
→ commit; see docs/architecture.md).

One engine step executes exactly the work a :class:`StepPlan` selects
under a single decode-priority TOKEN budget (``Engine(step_tokens=...)``,
replacing ``max_prefill_tokens_per_step`` as the only pacing knob on the
fused path):

1. every decoding slot is charged 1 token FIRST — decode rows are never
   displaced by prefill work (the starvation guarantee the budget tests
   assert);
2. the remaining budget goes to chunk-prefill rows, oldest admission
   first, each granted a page-aligned span via :func:`chunk_span`;
3. whatever is left paces ADMISSION (`Scheduler.admit(budget=...)`).

A selected chunk row with budget remaining always makes progress — at
least one page, or the final partial tail — so a budget smaller than one
page cannot livelock a mid-prompt slot (min-progress rule). All of this
is pure host arithmetic over ints: no jax arrays, no device syncs.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional


def pow2_ceil(n: int) -> int:
    """Smallest power of two >= n (n >= 1)."""
    return 1 << max(0, (int(n) - 1).bit_length())


@dataclass(frozen=True)
class ChunkRow:
    """One prefill-chunk row of a fused step: prompt tokens
    [start, end) of ``slot``'s request, executed at absolute positions
    start..end-1. ``final`` marks the chunk that completes the prompt
    (its last-token logits seed decoding)."""
    slot: int
    start: int
    end: int
    final: bool

    @property
    def length(self) -> int:
        return self.end - self.start


@dataclass
class StepPlan:
    """The work ONE engine step will execute in a single fused dispatch.

    ``decode_slots`` decode one token each; ``chunk_rows`` prefill their
    page-aligned spans; ``budget`` echoes the step's token budget (None =
    unbounded). Spec slots run their draft/verify bursts before the fused
    dispatch and ride the fused batch as inactive rows (row_len 0)."""
    budget: Optional[int] = None
    decode_slots: List[int] = field(default_factory=list)
    chunk_rows: List[ChunkRow] = field(default_factory=list)

    @property
    def tokens_planned(self) -> int:
        return len(self.decode_slots) + sum(c.length for c in self.chunk_rows)

    @property
    def width(self) -> int:
        """Row width W of the fused batch: the longest span, bucketed to a
        power of two so one jit serves every chunk size in the bucket
        (decode-only steps compile the W=1 variant)."""
        w = 1
        for c in self.chunk_rows:
            w = max(w, c.length)
        return pow2_ceil(w)

    @property
    def utilization(self) -> float:
        """tokens_planned / budget — the per-step budget-pressure signal
        (obs gauge ``nbl_step_budget_utilization``). 0.0 when unbounded:
        with no budget there is no pressure to report."""
        if not self.budget:
            return 0.0
        return self.tokens_planned / self.budget

    def has_work(self) -> bool:
        return bool(self.decode_slots or self.chunk_rows)


def decode_first_budget(budget: Optional[int], n_decode: int) -> Optional[int]:
    """Token budget left for chunk rows after every decode row is charged
    first. Decode rows themselves are NEVER trimmed: with budget <=
    n_decode the step still decodes every slot and chunks get nothing."""
    if budget is None:
        return None
    return max(0, budget - n_decode)


def chunk_span(filled: int, plen: int, chunk_tokens: int,
               remaining: Optional[int], page_size: int) -> int:
    """End (exclusive) of the page-aligned span one chunk row may prefill
    this step: resume at ``filled`` (a page multiple), bounded by the
    per-row cap ``chunk_tokens``, the prompt length ``plen``, and the
    step's ``remaining`` token budget (None = unbounded).

    Returns ``filled`` itself (an empty span — the row waits) only when
    the remaining budget is exhausted; any positive remainder grants at
    least one page or the final partial tail (min-progress), so sub-page
    budgets still drain the prompt one page per step."""
    left = plen - filled
    span = min(chunk_tokens, left)
    if remaining is not None:
        if remaining <= 0:
            return filled
        if remaining < span:
            span = (remaining // page_size) * page_size
            if span == 0:
                span = min(page_size, left)
    return filled + span
