"""Continuous-batching serving engine over slot-indexed or PAGED KV caches.

Architecture (frontend → scheduler → engine → cache):

  AsyncEngine (this module) + launch/server.py
      The serving HOST LOOP: a background thread drives ``Engine.step()``
      while client threads submit streaming requests (per-request token
      queues fed straight from ``_emit``), cancel them in any lifecycle
      state, and get reject-with-error backpressure past a bounded pending
      count. launch/server.py puts a newline-JSON TCP socket in front of
      it. Works over every engine layout below — it adds no model code.
  Scheduler (launch/scheduler.py)
      FIFO queue + NBL-aware admission budget: a fixed HBM byte budget
      divided by the per-request footprint. NBL-linearized layers carry no
      cache, so a compressed model admits more concurrent requests on the
      same budget (paper §4.2).
  Engine (this module)
      Owns params + ONE cache in one of two layouts:

      ring (default)   models/kv_cache.init_slot_cache — a full max_len
                       ring reserved per slot. Budget unit: bytes/slot.
      paged            models/paging.init_paged_cache — per-layer page
                       pools + a host-side refcounted PageAllocator and
                       page table. A request REFERENCES only the pages its
                       tokens cover; pages are allocated ON DEMAND as
                       decode crosses a page boundary, and when the pool
                       runs dry, unreferenced prefix-index entries are
                       evicted (LRU) first and only then is the YOUNGEST
                       in-flight request preempted (pages unref'd, request
                       requeued — it restarts from its prompt) so the
                       oldest requests always finish. Budget unit: pages
                       referenced, shared pages billed once
                       (scheduler.nbl_page_budget) — short requests stop
                       stranding max_len-sized rings, which converts
                       directly into admitted traffic.

      PREFIX SHARING (``prefix_sharing=True``, paged only): a host-side
      PrefixIndex maps full pages of previously-served prompt prefixes to
      the physical pages already caching them. Admission looks up the
      longest page-aligned cached prefix, bumps those pages' refcounts,
      points the new slot's page-table row at them, and prefills ONLY the
      suffix from the first divergent page (a partial prefill attending
      the shared KV through the table — the decode kernel needs no change,
      sharing is invisible below the table). Retirement/preemption only
      unref; the index holds its own reference per published page, so hot
      prefixes survive their publisher. Requires a stack with no SSM
      blocks (partial prefill cannot resume scanned state).

      CHUNKED PREFILL (``chunked_prefill=True``, paged only): a prompt is
      split into page-aligned chunks of ``prefill_chunk_tokens`` and at
      most ONE chunk is prefilled per ``step()``, interleaved with the
      batched decode of everything in flight — a long prompt no longer
      monopolizes a step, so active decodes keep emitting between chunks
      instead of stalling for the whole prefill. Each chunk reuses the
      partial-prefill path below: the request's OWN earlier chunks play
      the role of the "shared prefix" (prefix_tbl points at the slot's
      already-written pages), so no new model code path exists below the
      page table. Chunk pages are allocated chunk-by-chunk; under pool
      pressure a mid-prompt request SUSPENDS between chunks holding its
      pages (resuming when the pool recovers) and is torn down only by
      preemption. Composes with prefix sharing (lookup once at admission,
      then chunk only the suffix); gated off for SSM stacks like the
      other partial-prefill paths.

      SPECULATIVE DECODING (``drafts={m: (draft_cfg, draft_params)}`` +
      per-request ``submit(..., spec_gamma=k, draft_m=m)``, paged only):
      NBL hands the engine a free self-drafter — the SAME weights under a
      deeper linearization plan (launch/speculative.make_nbl_draft).
      Because the draft linearizes the DEEPEST layers, its surviving
      attention layers are the target's shallow ones, so the draft
      attends the target's own paged KV through the slot's page table
      (no draft cache exists). Each spec step runs one per-slot draft
      burst (γ greedy tokens from one scanned jit over a trace-time view
      of the target pools) and ONE verifier cache-extend — the PR 3/4
      partial-prefill jit re-run from the slot's last page boundary with
      γ+1 logits rows, the slot's own pages as the prefix; no new model
      code exists below the page table. The longest agreeing prefix plus
      one corrected token is emitted; rejection ROLLBACK is a pure
      per-slot length decrement (pages are position-aligned — no kpos to
      repair) plus returning the surplus candidate-span pages
      (models/paging.release_tail_pages). Greedy acceptance is EXACT:
      spec output is token-identical to ``generate()`` regardless of
      draft quality (the fuzz harness asserts it). Composes with prefix
      sharing and chunked prefill; requires temperature 0, an unsharded
      engine, and ``prompt + max_new + spec_gamma <= max_len`` (the
      candidate span must fit the page table). Sliding-window stacks
      keep ALL of a spec slot's pages resident (window page release is
      skipped: the verifier's prefix gather reads from page 0) — spec
      trades the SWA page saving for the draft/verify speedup.

      ``step()`` interleaves: (1) admission — for every free slot (and, when
      paged, enough free pages), pop a request, prefill it at batch=1,
      assign its cache (slot row / prompt pages), emit its first token
      (chunked: only record the slot as chunking — no prefill yet);
      (1b) chunked only: prefill ONE page-aligned chunk of the oldest
      chunking slot; the final chunk emits the first token and flips the
      slot to decoding within the same step; (2) one *batched* decode over
      all decoding slots with a per-slot position vector — retired/empty/
      chunking rows ride along masked (kpos = -1, an unallocated
      page-table row, or pos = -1); (3) retirement — EOS or max-token
      requests release their slot (and pages, copy-free: isolation under
      reuse is positional, see models/paging.py).

      FUSED STEP PIPELINE (``fused_step=True``, the default on paged
      SSM-free, cross-attn-free engines; docs/architecture.md): the step
      is restructured PLAN -> EXECUTE -> COMMIT. A host-side
      :class:`~repro.launch.stepplan.StepPlan` selects the step's work
      under one decode-priority TOKEN budget (``step_tokens``): every
      decoding slot is charged 1 token first, the remainder grants
      page-aligned chunk spans to mid-prompt slots (oldest admission
      first — possibly SEVERAL per step, unlike the legacy one-chunk
      rule), and the leftover paces admission
      (``Scheduler.admit(budget=...)``). Execution then launches ONE
      ``transformer.fused_step`` jit over a mixed (n_slots, W) batch —
      decode rows at width 1, chunk rows at their span width, W bucketed
      to a power of two, all sharing the live page table; per-row
      ``row_len`` masks inactive rows (0) and extracts each row's
      last-valid-token logits. Commit performs the step's single logits
      readback, emits decode tokens and final-chunk seed tokens,
      advances chunk progress (publishing prefix pages progressively)
      and retires. ``fused_step=False`` keeps the legacy two-dispatch
      path (chunk prefill + batched decode) as the parity oracle; the
      fuzz harness replays every mode through both, token-exactly.

      Slot state machine (per request)::

          admitted ──(chunked)──> chunking(pos) ──last chunk──> decoding
             │                        │   ▲                        │
             └──(non-chunked: full ───┼───┘ suspend/resume          │
                 prefill at admission)│     between steps           │
                                      ▼                             ▼
                             preempted: pages unref'd,      retired: EOS or
                             requeued, restarts from        max_new; slot +
                             its prompt                     pages recycled

      Mode compatibility (engine layout x stack family)::

          layout \\ stack      dense  SWA    SSM/hybrid  cross-attn (VLM)
          ring (default)       yes    yes    yes         yes
          paged                yes    yes    yes (slot   yes
                                             state rows)
          prefix_sharing       yes    yes    no (scan    no (enc-
                                             resume)     conditioned KV)
          chunked_prefill      yes    yes    no (scan    yes (enc rides
                                             resume)     every chunk)
          speculative          yes    yes    no (verify  no (the draft
          (drafts= + per-      (all pages    is a        must be a pure
          request spec_gamma)  stay          partial     attn/nbl plan;
                               resident)     prefill)    enc-conditioned
                                                         KV cannot be
                                                         drafted) —
                               unsharded engines only; greedy (temp 0)
          fused step           yes    yes    no (scan    no (the fused
          (fused_step=True,                  state needs batch carries
          the default; paged                 the scanned no enc rows)
          engines only)                      decode jit)
                               gates are SILENT fallbacks, not errors:
                               ring engines and SSM / cross-attn stacks
                               keep the legacy two-dispatch step path;
                               Engine(fused_step=False) forces ANY
                               engine onto it (the parity oracle the
                               fuzz harness replays against)
          async / server       yes    yes    yes*        yes*
                               (*inherits the WRAPPED layout's gates
                                verbatim: AsyncEngine/launch.server drive
                                step() from a thread and add no model code,
                                so e.g. async+chunked still refuses SSM
                                stacks and async+prefix_sharing refuses
                                SSM and cross-attn — the Engine
                                constructor raises before the host loop
                                ever starts)
          observability        yes    yes    yes         yes
          (obs=Observability)  (host-side hooks only — every layout above,
                                sync or async, carries the same metrics/
                                trace/timeline instrumentation; obs=None
                                (the default) reduces every hook site to
                                one predictable branch and the step path
                                issues ZERO additional device dispatches
                                either way)
          analysis             yes    yes    yes         yes
          (repro.analysis)     (static rules are layout-independent:
                                guarded-by/lock-order cover the Engine/
                                AsyncEngine/server locks in every mode;
                                jit-discipline covers the shared-jit
                                registry all UNSHARDED layouts route
                                through — sharded jits are allowlisted
                                per-instance by design; host-sync walks
                                _step_impl's call graph, so admission,
                                chunking, paging, decode and the spec
                                draft/verify path are all in scope, every
                                readback sanctioned per line; obs-hygiene
                                keeps the
                                observability row's zero-overhead
                                promise structural)
  Cache
      (L, n_slots, ...) slot rows, or (L, n_pages, KV, page_size, hd)
      pools + host page table (models/paging.py).

Prompt-length BUCKETING bounds the per-length prefill jits: prompts are
right-padded to the next power-of-two bucket and prefill takes a traced
``valid_len`` (logits read at valid_len-1; padded cache positions are
masked unattendable), so the jit cache holds O(log max_len) entries instead
of one per distinct length. Bucketing is auto-disabled for stacks it cannot
serve exactly: SSM/hybrid (padding corrupts the scanned state) and, in ring
mode only, sliding-window attention (padding evicts in-window ring slots;
the paged layout is position-aligned, so windows and bucketing compose).

The decode jit compiles ONCE (shapes are (n_slots, 1) regardless of how
many requests are in flight). Under a mesh the same engine runs sharded:
params/caches take their production PartitionSpecs (distributed/
sharding.py), batch/slot dims shard over "dp".
"""
from __future__ import annotations

import queue as _queue
import threading
import time
from collections import deque
from contextlib import nullcontext
from typing import TYPE_CHECKING, Callable, Optional

if TYPE_CHECKING:   # the obs layer stays an optional, import-light dep
    from repro.obs import Observability

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed.api import jit_shardings, mesh_axes, shaped_spec
from repro.distributed.sharding import cache_specs, param_specs
from repro.jitcache import SHARED_JITS as _SHARED_JITS, shared_jit as _shared_jit
from repro.launch.scheduler import (
    Request, Scheduler, latency_stats, nbl_page_budget, nbl_slot_budget,
)
from repro.launch.stepplan import (
    ChunkRow, StepPlan, chunk_span, decode_first_budget,
)
from repro.models import decode_step, fused_step, prefill
from repro.models.kv_cache import assign_slot, init_slot_cache
from repro.launch.speculative import (
    accept_greedy, build_draft_cache_view, draft_burst, validate_draft,
)
from repro.models.paging import (
    DEFAULT_PAGE_SIZE, PageAllocator, PrefixIndex, assign_pages,
    build_page_table, init_paged_cache, n_caching_attn_layers,
    pages_per_seq, pool_pages_for_budget, pow2_ceil, release_tail_pages,
    span_pages,
)

_NULLCTX = nullcontext()     # shared no-op ctx for un-annotated jit calls


# The shared jit cache for UNSHARDED engines lives in repro.jitcache (so
# eval/calibrate/serve share the same registry without importing the
# engine); `_SHARED_JITS` / `_shared_jit` above are the historical local
# names. Engine closures capture only the (hashable, value-equal)
# ModelConfig plus static plan constants, so two engines over equal
# configs lower to identical jaxprs — handing them the SAME callable lets
# jax's trace cache reuse compilations across Engine instances
# (tests/benchmarks/the fuzz harness construct engines by the hundred;
# per-instance closures would recompile every one). Sharded engines keep
# per-instance jits: their in/out shardings are captured from the ambient
# mesh at construction and must not leak across meshes — those sites are
# allowlisted for the jit-discipline pass (repro.analysis) where built.


class Engine:
    """Request-level continuous-batching decode engine.

    Either ``n_slots`` or ``cache_budget_bytes`` (NBL-aware: converted via
    ``nbl_slot_budget`` / ``nbl_page_budget``) fixes the concurrency; given
    both, the budget is a ceiling. ``max_len`` bounds prompt + generated
    tokens per request.

    ``paged=True`` switches to the page-pool cache layout; ``page_size``
    must then be a power of two. ``expected_len`` is the page budget's
    per-request billing length (default ``max_len`` — conservative; pass
    the workload's typical prompt+generation length to admit more).
    ``prefix_sharing=True`` (paged, non-SSM stacks) enables copy-on-write
    prompt-prefix reuse through a PrefixIndex; ``shared_prefix_len`` is
    the billing hint for it — the prompt-prefix length (tokens) the
    workload shares, billed ONCE across the fleet instead of per request
    (scheduler.nbl_page_budget). ``chunked_prefill=True`` (paged,
    non-SSM) splits every prompt into page-aligned chunks of
    ``prefill_chunk_tokens`` (rounded up to a page multiple; default one
    page) and prefills at most one chunk per step, interleaved with the
    batched decode — see the module docstring for the slot state machine
    and the mode-compatibility table.

    ``fused_step=True`` (the default) routes paged SSM-free cross-attn-
    free engines through the plan -> execute -> commit pipeline: ONE
    fused jit per step executes the mixed decode + chunk-row batch
    (docs/architecture.md); other layouts silently keep the legacy
    two-dispatch path, and ``fused_step=False`` forces it everywhere
    (the fuzz harness's parity oracle). ``step_tokens`` (fused path
    only; default None = unbounded) is the per-step decode-priority
    token budget: decode rows are charged first, the remainder grants
    chunk spans and paces admission — it replaces the scheduler's
    ``max_prefill_tokens_per_step`` as the single pacing knob.

    Sharding is captured at CONSTRUCTION time: build the engine inside
    ``use_mesh(mesh)`` to get sharded params/caches — an engine built
    un-meshed stays fully replicated even if later driven under a mesh.

    ``obs`` (an ``repro.obs.Observability``, default None = off) threads
    the metrics registry / request tracer / step timeline through every
    lifecycle transition; the registry is labeled ``engine_mode`` (ring /
    paged / prefix / chunked / chunked_shared) and ``nbl_m`` (linearized
    block count) at construction. All hooks are host-side — no device
    dispatch is ever added — and with ``obs=None`` each site costs one
    branch. ``stats_window`` (default 1024, None = unbounded) bounds the
    ``stats()`` percentile set to the most recently finished requests so a
    long-running server's stats call stops re-sorting its whole history;
    lifetime counts (``n``, counters) are unaffected.
    """

    def __init__(self, cfg: ModelConfig, params, *, max_len: int,
                 n_slots: Optional[int] = None,
                 cache_budget_bytes: Optional[int] = None,
                 eos_id: Optional[int] = None,
                 temperature: float = 0.0, seed: int = 0,
                 scheduler: Optional[Scheduler] = None,
                 donate: bool = True,
                 paged: bool = False,
                 page_size: int = DEFAULT_PAGE_SIZE,
                 expected_len: Optional[int] = None,
                 bucket_prompts: bool = True,
                 prefix_sharing: bool = False,
                 shared_prefix_len: int = 0,
                 chunked_prefill: bool = False,
                 prefill_chunk_tokens: Optional[int] = None,
                 fused_step: bool = True,
                 step_tokens: Optional[int] = None,
                 obs: Optional["Observability"] = None,
                 stats_window: Optional[int] = 1024,
                 drafts: Optional[dict] = None):
        self.paged = bool(paged)
        self.page_size = int(page_size)
        if self.paged and self.page_size & (self.page_size - 1):
            raise ValueError(f"page_size must be a power of two, "
                             f"got {page_size}")
        self.chunked = bool(chunked_prefill)
        if self.chunked:
            if not self.paged:
                raise ValueError("chunked_prefill requires paged=True "
                                 "(chunks are page-aligned and resume "
                                 "through the page table)")
            if any(b.kind == "mamba" for b in cfg.blocks()):
                raise ValueError("chunked_prefill cannot serve SSM stacks "
                                 "(the partial prefill cannot resume "
                                 "scanned state mid-prompt)")
            ct = self.page_size if prefill_chunk_tokens is None \
                else int(prefill_chunk_tokens)
            if ct < 1:
                raise ValueError(f"prefill_chunk_tokens must be >= 1, "
                                 f"got {prefill_chunk_tokens}")
            # chunks must END on page boundaries so the next chunk's prefix
            # table covers whole pages: round UP to a page multiple
            self.chunk_tokens = -(-ct // self.page_size) * self.page_size
        else:
            self.chunk_tokens = 0
        self.prefix_sharing = bool(prefix_sharing)
        if self.prefix_sharing:
            if not self.paged:
                raise ValueError("prefix_sharing requires paged=True")
            if any(b.kind in ("mamba", "cross_attn") for b in cfg.blocks()):
                # mamba: partial prefill cannot resume scanned state.
                # cross_attn: prefix KV downstream of a cross-attn block is
                # conditioned on the request's enc embeddings, but the
                # index keys on prompt TOKENS only — sharing would reuse
                # another request's enc-contaminated KV.
                raise ValueError("prefix_sharing cannot serve SSM or "
                                 "cross-attention stacks (prefix KV is not "
                                 "a pure function of prompt tokens)")
        # speculative decoding: {draft_m: (draft_cfg, draft_params)}.
        # Registered at construction so the draft-burst jits can be keyed
        # and shared; per-request opt-in via submit(spec_gamma=, draft_m=).
        self.drafts: dict = dict(drafts) if drafts else {}
        if self.drafts:
            if not self.paged:
                raise ValueError("speculative decoding requires paged=True "
                                 "(the verifier re-prefills through the "
                                 "slot's page table)")
            if any(b.kind in ("mamba", "cross_attn") for b in cfg.blocks()):
                # mamba: the verifier is a partial prefill (cannot resume
                # scanned state). cross_attn: the draft plan has no enc
                # conditioning path, so drafted KV would diverge.
                raise ValueError("speculative decoding cannot serve SSM or "
                                 "cross-attention stacks")
            for m, (dcfg, _dp) in self.drafts.items():
                validate_draft(cfg, dcfg)
        expected_len = int(expected_len or max_len)

        n_pages = None
        if cache_budget_bytes is not None:
            if self.paged:
                n_pages = pool_pages_for_budget(cfg, cache_budget_bytes,
                                                self.page_size)
                budget_slots = nbl_page_budget(
                    cfg, cache_budget_bytes, page_size=self.page_size,
                    expected_len=expected_len,
                    shared_prefix_len=(shared_prefix_len
                                       if self.prefix_sharing else 0))
            else:
                budget_slots = nbl_slot_budget(cfg, cache_budget_bytes,
                                               max_len)
            # an explicit n_slots may narrow the budget, never exceed it
            n_slots = budget_slots if n_slots is None \
                else min(n_slots, budget_slots)
        elif n_slots is None:
            raise ValueError("need n_slots or cache_budget_bytes")
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        self.cfg = cfg
        self.params = params
        self.max_len = int(max_len)
        self.n_slots = int(n_slots)
        self.eos_id = eos_id
        self.temperature = float(temperature)
        self._rng = np.random.default_rng(seed)
        self.scheduler = scheduler or Scheduler()

        blocks = cfg.blocks()
        has_mamba = any(b.kind == "mamba" for b in blocks)
        has_window = any(b.kind == "attn" and b.window is not None
                         for b in blocks)
        has_cross = any(b.kind == "cross_attn" for b in blocks)
        # exactness gates (see module docstring): SSM state is corrupted by
        # padded tokens; ring compaction evicts in-window slots on padding.
        self.bucket_prompts = (bool(bucket_prompts) and not has_mamba
                               and (self.paged or not has_window))
        # fused plan->execute->commit pipeline: a SILENT fast-path gate,
        # not an error — ring engines, SSM stacks (the fused batch cannot
        # resume scanned state mid-sequence) and cross-attn stacks (no enc
        # rows in the fused batch) keep the legacy two-dispatch step path.
        self.fused = bool(fused_step) and self.paged \
            and not has_mamba and not has_cross
        if step_tokens is not None and int(step_tokens) < 1:
            raise ValueError(f"step_tokens must be >= 1, got {step_tokens}")
        self.step_tokens = int(step_tokens) if step_tokens is not None \
            else None

        if self.paged:
            # pure sliding-window stacks can retire pages that fall out of
            # the window (the paged analogue of the ring's compaction): a
            # page is dead once it is below EVERY layer's window, so the
            # horizon is the widest window — and one global layer pins
            # everything (no release).
            windows = [b.window for b in blocks if b.kind == "attn"]
            self._page_window = (max(windows) if windows
                                 and all(w is not None for w in windows)
                                 else None)
            self._pps = pages_per_seq(self.max_len, self.page_size)
            if n_pages is None:
                n_pages = self.n_slots * self._pps   # full-reservation pool
            # a lone request must always be able to run to max_len
            if n_caching_attn_layers(cfg) > 0:
                n_pages = max(int(n_pages), self._pps)
            self.n_pages = int(n_pages)
            self.allocator = PageAllocator(self.n_pages)
            self.page_tbl = build_page_table(self.n_slots, self.max_len,
                                             self.page_size)
            self.slot_pages: list[list[int]] = [[] for _ in
                                                range(self.n_slots)]
            self.prefix_index = PrefixIndex(self.page_size) \
                if self.prefix_sharing else None
            self.cache = init_paged_cache(cfg, self.n_slots, self.max_len,
                                          page_size=self.page_size,
                                          n_pages=self.n_pages)
        else:
            self.n_pages = 0
            self.cache = init_slot_cache(cfg, self.n_slots, self.max_len)
        self.slot_req: list[Optional[Request]] = [None] * self.n_slots
        self.slot_pos = np.zeros(self.n_slots, np.int32)   # pos of last tok
        self.slot_tok = np.zeros(self.n_slots, np.int32)   # last emitted tok
        # chunked-prefill progress: -1 = not chunking (free or decoding);
        # >= 0 = prompt tokens already cached (always a page multiple
        # mid-prompt — only the FINAL chunk may end off a page boundary,
        # and it transitions the slot to decoding)
        self.slot_chunk_pos = np.full(self.n_slots, -1, np.int32)
        self.finished: dict[int, Request] = {}   # guarded-by: _finished_lock
        self.n_decode_steps = 0
        self.n_prefills = 0
        self.n_chunks = 0              # chunked-prefill chunks processed
        # steps whose batched decode emitted tokens WHILE a prompt was
        # still mid-chunking — the interleaving claim, counted natively so
        # smokes/benchmarks need not re-derive it from slot state
        self.n_interleaved_decode_steps = 0
        self.n_prefill_tokens = 0      # valid (unpadded) tokens prefilled
        self.n_preemptions = 0
        self.n_rejected = 0   # reject-with-error drops # guarded-by: _count_lock
        self.n_cancelled = 0           # cancel() terminal retirements
        # emission hooks (AsyncEngine installs these): on_token(req, tok)
        # fires for every generated token the moment _emit records it;
        # on_finish(req) fires exactly once when a request reaches ANY
        # terminal state (finished / rejected / cancelled); on_submit(req)
        # fires after a servable request is queued — AsyncEngine uses it to
        # wake its event-driven idle loop, so DIRECT submit() on a wrapped
        # engine is served without waiting for an unrelated wake. All run
        # on whichever thread drives the engine — keep them cheap.
        self.on_token: Optional[Callable] = None
        self.on_finish: Optional[Callable] = None
        self.on_submit: Optional[Callable] = None
        self._count_lock = threading.Lock()    # see n_rejected's guarded-by
        self._admit_seq = 0            # monotone admission counter (age)
        self.n_prefix_hits = 0         # admissions served a cached prefix
        self.n_shared_prompt_tokens = 0  # prompt tokens skipped via sharing
        # speculative counters — mirrored 1:1 by obs.on_spec_burst so the
        # fuzz harness can assert registry == engine state in lockstep
        self.n_spec_bursts = 0         # draft+verify rounds run
        self.n_spec_draft_tokens = 0   # gamma per burst (always full)
        self.n_spec_accepted_tokens = 0  # draft-origin tokens EMITTED
        self.n_spec_tokens = 0         # all spec-path tokens emitted
        # step-path dispatch split (the PR 6 "dispatch-count machinery"
        # consumer): fused counts ONE per fused-step jit launch; legacy
        # counts the dispatches the fused jit replaces — the batched
        # decode plus each chunk-prefill jit. Admission prefills and spec
        # draft/verify launches are identical on both paths and excluded.
        self.n_fused_dispatches = 0
        self.n_legacy_dispatches = 0
        self._budget_util_sum = 0.0    # per planned step, for stats()
        self._n_planned_steps = 0      # fused steps that planned any work
        self._pool_in_use_sum = 0      # allocator occupancy, per decode step
        self.n_finished = 0   # lifetime served count # guarded-by: _finished_lock
        # guards the finished dict + the stats window deque: _emit/_reject/
        # _finish_cancelled write on the step thread while stats() snapshots
        # (and AsyncEngine's retain_results=False pops) from client threads
        self._finished_lock = threading.Lock()
        self.stats_window = stats_window
        self._recent_done = (deque(maxlen=int(stats_window))  # guarded-by: _finished_lock
                             if stats_window else None)
        self.obs = obs
        if obs is not None:
            obs.bind(engine_mode=self.mode_name,
                     nbl_m=sum(1 for b in blocks if b.kind == "nbl"))
            obs.g_slots.set(self.n_slots)

        sharded = bool(mesh_axes())
        pspecs = param_specs(jax.eval_shape(lambda: params)) \
            if sharded else None
        cspecs = cache_specs(jax.eval_shape(lambda: self.cache)) \
            if sharded else None

        dkw = dict(donate_argnums=(2,)) if donate else {}
        akw = dict(donate_argnums=(0,)) if donate else {}
        if self.paged:
            def _decode(p, token, cache, pos, tbl):
                return decode_step(cfg, p, token, cache, pos, page_tbl=tbl)
        else:
            def _decode(p, token, cache, pos):
                return decode_step(cfg, p, token, cache, pos)

        def _assign(slot_cache, pcache, slot):
            return assign_slot(slot_cache, pcache, slot)

        if sharded:
            tok_spec = shaped_spec((self.n_slots, 1), "dp", None)
            pos_spec = shaped_spec((self.n_slots,), "dp")
            din = (pspecs, tok_spec, cspecs, pos_spec)
            if self.paged:
                din += (shaped_spec((self.n_slots, self._pps), "dp", None),)
            self._decode_jit = jax.jit(  # nbl: disable=jit-discipline -- sharded, per-instance by design
                _decode, in_shardings=jit_shardings(din),
                out_shardings=jit_shardings((None, cspecs)), **dkw)
            self._assign_jit = jax.jit(  # nbl: disable=jit-discipline -- sharded, per-instance by design
                _assign, in_shardings=jit_shardings((cspecs, None, None)),
                out_shardings=jit_shardings(cspecs), **akw)
        else:
            self._decode_jit = _shared_jit(
                ("decode", cfg, self.paged, donate),
                lambda: jax.jit(_decode, **dkw))
            self._assign_jit = _shared_jit(
                ("assign_slot", donate), lambda: jax.jit(_assign, **akw))
        self._akw, self._cspecs = akw, cspecs
        self._donate = bool(donate)
        # under a mesh the batch=1 prefill cache must come out in the same
        # production layout the slot cache uses, so assignment never
        # reshards on admission.
        self._pspecs = pspecs
        self._sharded = sharded
        if self.drafts and sharded:
            # the draft-burst view gathers raw pool leaves at trace time;
            # it has no sharding specs, so spec stays unsharded-only
            raise ValueError("speculative decoding requires an unsharded "
                             "engine (the draft cache view carries no "
                             "sharding specs)")
        self._prefill_jits: dict = {}   # (bucket, with_enc) -> jit fn
        self._assign_paged_jits: dict = {}   # prefill cache_len -> jit fn
        self._spec_draft_jits: dict = {}     # (draft_m, gamma) -> burst jit
        self._fused_jits: dict = {}          # row width W -> fused-step jit

    # ------------------------------------------------------------- admin --

    @property
    def mode_name(self) -> str:
        """Canonical mode label (the ``engine_mode`` metrics label and the
        benchmark scenario axis): ring / paged / prefix / chunked /
        chunked_shared."""
        if self.chunked:
            return "chunked_shared" if self.prefix_sharing else "chunked"
        if self.prefix_sharing:
            return "prefix"
        return "paged" if self.paged else "ring"

    def _spec_guard(self, plen: int, max_new: int, spec_gamma: int,
                    draft_m) -> Optional[str]:
        """Why a ``spec_gamma > 0`` submission cannot be served, or None.
        Centralized so ``submit`` and the admission-time guard (direct
        scheduler submissions bypass ``submit``) reject identically."""
        if spec_gamma <= 0:
            return None
        if not self.drafts:
            return ("spec_gamma set but no drafts registered "
                    "(pass drafts= to the Engine constructor)")
        if draft_m is not None and draft_m not in self.drafts:
            return (f"draft_m={draft_m} not registered "
                    f"(have {sorted(self.drafts)})")
        if self.temperature > 0.0:
            return ("speculative decoding requires temperature 0 "
                    "(greedy acceptance)")
        if plen + max_new + spec_gamma > self.max_len:
            return (f"prompt({plen}) + max_new({max_new}) + spec_gamma"
                    f"({spec_gamma}) exceeds max_len={self.max_len} "
                    f"(the candidate span must fit the page table)")
        return None

    def submit(self, prompt, max_new: int, *, enc=None,
               spec_gamma: int = 0, draft_m: Optional[int] = None,
               strict: bool = False) -> int:
        """Queue a request; returns its id. ``prompt`` 1-D int tokens.

        ``spec_gamma > 0`` opts this request into speculative decoding
        (γ drafted tokens per step through the ``drafts`` registry;
        ``draft_m`` picks the linearization depth, default the first
        registered). Spec requests must satisfy
        ``prompt + max_new + spec_gamma <= max_len``.

        An unservable submission (empty prompt, ``max_new < 1``,
        prompt + max_new > max_len, or an unservable spec request) is
        REJECTED-WITH-ERROR: the request is
        recorded terminally (``Request.error`` set, surfaced in
        ``finished`` / ``n_rejected``, excluded from latency percentiles)
        and its rid still returned — the SAME surface the admission-time
        guard uses for direct scheduler submissions, so a serving frontend
        handles every rejection by reading one field instead of catching
        an exception that would kill its host loop mid-request.
        ``strict=True`` restores the raising behavior for direct/test
        use."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            err = "empty prompt"
        elif max_new < 1:
            err = f"max_new must be >= 1, got {max_new}"
        elif prompt.size + max_new > self.max_len:
            err = (f"prompt({prompt.size}) + max_new({max_new}) exceeds "
                   f"engine max_len={self.max_len}")
        elif (serr := self._spec_guard(prompt.size, max_new, spec_gamma,
                                       draft_m)) is not None:
            err = serr
        else:
            req = self.scheduler.make_request(prompt, max_new, enc=enc,
                                              spec_gamma=spec_gamma,
                                              draft_m=draft_m)
            self.scheduler.submit_request(req)
            if self.obs is not None:
                self.obs.on_submit(req, len(self.scheduler))
            if self.on_submit is not None:
                self.on_submit(req)
            return req.rid
        if strict:
            raise ValueError(err)
        return self._submit_rejected(prompt, max_new, err, enc=enc)

    def _submit_rejected(self, prompt, max_new: int, reason: str, *,
                         enc=None) -> int:
        """Record a request as rejected WITHOUT queueing it (unservable
        submission, or AsyncEngine backpressure); returns its rid."""
        req = self.scheduler.make_request(prompt, max_new, enc=enc)
        self._reject(req, reason)
        return req.rid

    @property
    def active_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slot_req) if r is not None]

    @property
    def has_work(self) -> bool:
        return bool(self.active_slots) or len(self.scheduler) > 0

    # ----------------------------------------------------------- serving --

    def _prefill_plan(self, prompt_len: int) -> tuple[int, int, bool]:
        """(token_len, cache_len, masked) for a prompt. Bucketing pads the
        TOKENS to a power-of-two bucket and masks with valid_len; without
        it, tokens stay exact (mamba-safe) and only the paged CACHE length
        rounds up to a page multiple (pages tile the cache)."""
        if self.bucket_prompts:
            b = pow2_ceil(prompt_len)
            if self.paged:
                b = min(max(b, self.page_size), self._pps * self.page_size)
            else:
                b = min(b, self.max_len)
            return b, (b if self.paged else self.max_len), True
        if self.paged:
            cl = pages_per_seq(prompt_len, self.page_size) * self.page_size
            return prompt_len, cl, False
        return prompt_len, self.max_len, False

    def _prefill_fn(self, token_len: int, cache_len: int, masked: bool,
                    with_enc: bool, prefix_pages: int = 0,
                    n_logits: int = 1):
        """Jit cache keyed on the full prefill plan — the plan is computed
        once per admission in ``_admit`` and passed through, so the cached
        function can never disagree with the caller about cache width or
        padding masking. ``prefix_pages`` > 0 selects the PARTIAL prefill
        (prefix sharing): the jit additionally takes the engine's paged
        cache, a (prefix_pages,) physical-page table and the traced prefix
        token count, and the tokens are the suffix only; the bucket count
        is a power of two so the jit cache stays O(log²) in the plan.
        ``n_logits`` > 1 is the speculative VERIFIER: the last n_logits
        valid rows come back (oldest first) so one cache-extend scores a
        whole candidate block."""
        key = (token_len, cache_len, masked, with_enc, prefix_pages,
               n_logits)
        fn = self._prefill_jits.get(key)
        if fn is None:
            cfg, paged = self.cfg, self.paged

            if prefix_pages:
                def _prefill(p, tokens, valid_len, pool, ptbl, plen0,
                             enc=None):
                    return prefill(cfg, p, tokens, enc=enc,
                                   cache_len=cache_len, paged=paged,
                                   valid_len=valid_len if masked else None,
                                   prefix_cache=pool, prefix_tbl=ptbl,
                                   prefix_len=plen0, n_logits=n_logits)
            else:
                def _prefill(p, tokens, valid_len, enc=None):
                    return prefill(cfg, p, tokens, enc=enc,
                                   cache_len=cache_len, paged=paged,
                                   valid_len=valid_len if masked else None,
                                   n_logits=n_logits)

            if self._sharded:
                from repro.launch.specs import cache_shapes
                # prefill returns the POSITION-ALIGNED batch=1 layout even
                # when paged; its specs are the plain cache ones
                pcspecs = cache_specs(cache_shapes(cfg, 1, cache_len))
                ins = (self._pspecs, None, None)
                if prefix_pages:
                    ins += (self._cspecs, None, None)
                ins += (None,) if with_enc else ()
                kw = dict(in_shardings=jit_shardings(ins),
                          out_shardings=jit_shardings((None, pcspecs)))
                fn = jax.jit(_prefill, **kw)  # nbl: disable=jit-discipline -- sharded, per-instance by design
            else:
                fn = _shared_jit(("prefill", cfg, paged) + key,
                                 lambda: jax.jit(_prefill))
            self._prefill_jits[key] = fn
        return fn

    def _assign_paged_fn(self, cache_len: int):
        fn = self._assign_paged_jits.get(cache_len)
        if fn is None:
            cfg, ps = self.cfg, self.page_size

            def _assign(cache, pcache, slot, page_ids):
                return assign_pages(cfg, cache, pcache, slot, page_ids,
                                    page_size=ps)

            kw = dict(self._akw)
            if self._sharded:
                from repro.launch.specs import cache_shapes
                pcspecs = cache_specs(cache_shapes(cfg, 1, cache_len))
                kw.update(in_shardings=jit_shardings(
                    (self._cspecs, pcspecs, None, None)),
                    out_shardings=jit_shardings(self._cspecs))
                fn = jax.jit(_assign, **kw)  # nbl: disable=jit-discipline -- sharded, per-instance by design
            else:
                fn = _shared_jit(("assign_paged", cfg, ps, bool(kw)),
                                 lambda: jax.jit(_assign, **kw))
            self._assign_paged_jits[cache_len] = fn
        return fn

    def _fused_fn(self, width: int):
        """Fused-step jit for row width ``width`` (a power of two — the
        StepPlan buckets spans, so the cache stays O(log chunk_tokens)):
        ONE dispatch executes the whole (n_slots, W) mixed batch of
        decode rows (len 1), chunk rows (their span) and inactive rows
        (len 0) against the live page table. Donated like the decode jit:
        the old cache buffers are dead once the step's pages are
        written."""
        fn = self._fused_jits.get(width)
        if fn is None:
            cfg = self.cfg

            def _fused(p, tokens, cache, row_pos, row_len, tbl):
                return fused_step(cfg, p, tokens, cache, row_pos, row_len,
                                  tbl)

            dkw = dict(donate_argnums=(2,)) if self._donate else {}
            if self._sharded:
                tok_spec = shaped_spec((self.n_slots, width), "dp", None)
                vec_spec = shaped_spec((self.n_slots,), "dp")
                din = (self._pspecs, tok_spec, self._cspecs, vec_spec,
                       vec_spec,
                       shaped_spec((self.n_slots, self._pps), "dp", None))
                fn = jax.jit(  # nbl: disable=jit-discipline -- sharded, per-instance by design
                    _fused, in_shardings=jit_shardings(din),
                    out_shardings=jit_shardings((None, self._cspecs)),
                    **dkw)
            else:
                fn = _shared_jit(("fused_step", cfg, width, self._donate),
                                 lambda: jax.jit(_fused, **dkw))
            self._fused_jits[width] = fn
        return fn

    def _sample(self, logits_row: np.ndarray) -> int:
        """logits_row: (V,) float32."""
        if self.temperature <= 0.0:
            return int(np.argmax(logits_row))
        z = logits_row / self.temperature
        z = z - z.max()
        p = np.exp(z)
        return int(self._rng.choice(z.shape[0], p=p / p.sum()))

    def _emit(self, req: Request, slot: int, tok: int, now: float) -> None:
        """Record one generated token; retire the slot when done."""
        req.tokens.append(tok)
        first = not req.t_first
        if first:
            req.t_first = now
        self.slot_tok[slot] = tok
        if self.obs is not None:
            self.obs.on_token(req, first, now)
        if self.on_token is not None:
            self.on_token(req, tok)
        done = (len(req.tokens) >= req.max_new
                or (self.eos_id is not None and tok == self.eos_id))
        if done:
            # no cache scrub needed: ring rows are overwritten wholesale at
            # the next tenancy; freed pages are position-masked until the
            # next owner overwrites them (models/paging.py).
            req.t_finish = now
            with self._finished_lock:
                self.finished[req.rid] = req
                self.n_finished += 1
                if self._recent_done is not None:
                    self._recent_done.append(req)
            self.slot_req[slot] = None
            if self.paged:
                self._release_pages(slot)
            if self.obs is not None:
                self.obs.on_retire(req, now)
            if self.on_finish is not None:
                self.on_finish(req)

    def _release_pages(self, slot: int) -> None:
        """Drop this slot's references; a page leaves the pool only when no
        other slot and no prefix-index entry still references it."""
        if self.slot_pages[slot]:
            self.allocator.unref(self.slot_pages[slot])
            self.slot_pages[slot] = []
        self.page_tbl[slot, :] = -1

    def _preempt(self, slot: int) -> None:
        """Evict the request in ``slot`` mid-decode: unref its pages and
        send it back to the queue front. It restarts from its prompt —
        generated tokens are discarded and the TTFT clock rewinds to
        unserved; the restart is counted on the request so latency_stats
        can split preempted from clean TTFT."""
        req = self.slot_req[slot]
        assert req is not None
        if self.obs is not None:
            self.obs.on_preempt(req, time.monotonic(), len(req.tokens))
        self._release_pages(slot)
        self.slot_req[slot] = None
        self.slot_chunk_pos[slot] = -1      # mid-prompt progress discarded
        req.tokens = []
        req.t_first = 0.0
        req.t_admit = 0.0
        req.n_preemptions += 1
        self.scheduler.requeue(req)
        self.n_preemptions += 1

    def _reclaim_pages(self, need: int) -> bool:
        """Free pool capacity without touching in-flight work: evict LRU
        unreferenced prefix-index entries until ``need`` pages are free.
        Runs BEFORE any preemption — cached-but-idle prefixes are the
        cheapest pages to give back. If eviction provably cannot reach
        ``need`` (an oversized ask), nothing is evicted at all: a request
        that will defer anyway must not wipe everyone else's warm cache."""
        if self.allocator.free_pages >= need:
            return True
        if not self.prefix_sharing:
            return False
        if self.allocator.free_pages + \
                self.prefix_index.evictable_pages(self.allocator) < need:
            return False
        while self.allocator.free_pages < need:
            if not self.prefix_index.evict_lru(
                    self.allocator, need - self.allocator.free_pages):
                return False   # unreachable: the evictable bound is exact
        return True

    def _youngest_active(self) -> int:
        return max(self.active_slots,
                   key=lambda s: self.slot_req[s].admit_seq)

    def _release_window_pages(self, slot: int, pos: int) -> None:
        """Free this slot's pages that sit entirely below the attention
        horizon (positions < pos - window + 1): the decode mask can provably
        never read them, so the -1 table entry and the window predicate
        coincide — token output is unchanged (asserted by the paged SWA
        parity test) while the pool stops pinning O(len) pages per
        request."""
        horizon = pos - self._page_window + 1
        n_dead = max(0, min(horizon // self.page_size, self._pps))
        dead = [int(p) for p in self.page_tbl[slot, :n_dead] if p >= 0]
        if dead:
            self.allocator.unref(dead)
            self.page_tbl[slot, :n_dead] = -1
            gone = set(dead)
            self.slot_pages[slot] = [p for p in self.slot_pages[slot]
                                     if p not in gone]

    def _ensure_decode_pages(self) -> None:
        """Allocate the page each active slot's next write lands in; on a
        dry pool, evict unreferenced prefix-index entries (LRU) first, then
        preempt the youngest request until the fault is served (each round
        frees >= 1 page, so this terminates). Decode writes always land at
        or past a slot's first divergent page, so a faulted page is never
        a shared one — sharing needs no copy here."""
        for slot in range(self.n_slots):
            req = self.slot_req[slot]
            if req is None:
                continue
            if self.slot_chunk_pos[slot] >= 0:
                continue   # mid-prompt: the chunk path owns these pages
            g = req.spec_gamma if self.drafts else 0
            if self._page_window is not None and not g:
                # spec slots keep ALL pages resident: the verifier's prefix
                # gather reads the table row from page 0 and a released
                # (-1) entry would clip to physical page 0 — garbage KV
                self._release_window_pages(slot, int(self.slot_pos[slot]))
            pos = int(self.slot_pos[slot])
            # a spec slot's verify writes positions [pos, pos + g]; plain
            # decode writes only position pos (g = 0)
            first_pg = pos // self.page_size
            last_pg = (pos + g) // self.page_size
            for lp in range(first_pg, last_pg + 1):
                if self.page_tbl[slot, lp] >= 0:
                    continue
                while self.slot_req[slot] is not None:
                    ids = self.allocator.alloc(1)
                    if ids is not None:
                        self.page_tbl[slot, lp] = ids[0]
                        self.slot_pages[slot].append(ids[0])
                        break
                    if self._reclaim_pages(1):
                        continue
                    self._preempt(self._youngest_active())
                if self.slot_req[slot] is None:
                    break   # this slot itself got preempted mid-fault

    def _prefix_lookup(self, req: Request) -> tuple[int, list[int]]:
        """Longest page-aligned cached prefix of the prompt; the hit pages
        are ref'd (pinned) IMMEDIATELY so a subsequent reclaim pass can
        never evict them between lookup and admission. The pin becomes the
        slot's reference on admission; the caller must unref on deferral."""
        if not self.prefix_sharing:
            return 0, []
        k, ids = self.prefix_index.lookup(req.prompt)
        if k:
            self.allocator.ref(ids)
        return k, ids

    def _reject(self, req: Request, reason: str) -> None:
        """Drop an unservable request at admission (the engine-level guard
        behind Scheduler.submit, which cannot know this engine's max_len):
        marked errored + finished so run() terminates, excluded from
        latency percentiles."""
        req.error = reason
        req.t_finish = time.monotonic()
        with self._finished_lock:
            self.finished[req.rid] = req
        # the one counter two threads can bump (a client thread rejecting
        # in submit vs the step thread rejecting at admission): += is a
        # non-atomic read-modify-write
        with self._count_lock:
            self.n_rejected += 1
        if self.obs is not None:
            self.obs.on_reject(req, req.t_finish)
        if self.on_finish is not None:
            self.on_finish(req)

    def cancel(self, rid: int) -> bool:
        """Terminally retire request ``rid`` in ANY lifecycle state —
        queued (never admitted), chunking mid-prompt, or decoding — with
        allocator invariants intact: the slot's page references are
        dropped wholesale (``slot_pages`` covers prompt, decode AND
        pinned shared-prefix pages, so one unref releases every reference
        this request holds; pages another slot or the prefix index still
        references survive, exactly like retirement), the slot and its
        chunking progress are recycled, and the request is recorded
        cancelled-with-partial-tokens (generated-so-far tokens KEPT;
        ``latency_stats`` excludes it from percentiles so a 0.0 t_first
        sentinel can never become a garbage TTFT). Prefix-index entries
        this request published are NOT torn down — the index holds its own
        reference per page and hot prefixes outlive their publisher.

        Returns True if the request was found live and cancelled; False if
        it is already terminal (or unknown). NOT thread-safe: call from
        the thread driving ``step()`` — the async host loop routes client
        cancellations through an inbox drained between steps."""
        # terminal check under the lock: a client thread's reject-with-error
        # (submit on a wrapped engine) can be writing finished concurrently
        with self._finished_lock:
            if rid in self.finished:
                return False
        for slot, req in enumerate(self.slot_req):
            if req is not None and req.rid == rid:
                if self.paged:
                    self._release_pages(slot)
                self.slot_req[slot] = None
                self.slot_chunk_pos[slot] = -1
                return self._finish_cancelled(req)
        req = self.scheduler.remove(rid)
        if req is not None:
            return self._finish_cancelled(req)
        return False

    def _finish_cancelled(self, req: Request) -> bool:
        req.cancelled = True
        req.t_finish = time.monotonic()
        with self._finished_lock:
            self.finished[req.rid] = req
        self.n_cancelled += 1
        if self.obs is not None:
            self.obs.on_cancel(req, req.t_finish)
        if self.on_finish is not None:
            self.on_finish(req)
        return True

    def partials(self) -> dict[int, np.ndarray]:
        """Generated-so-far tokens of every request still IN FLIGHT
        (admitted slots mid-generation, plus queued requests as empty
        arrays). ``run(max_steps)`` returning only ``finished`` used to
        silently discard these partial generations — a bounded drain now
        reads them here explicitly."""
        out = {}
        for req in self.slot_req:
            if req is not None:
                out[req.rid] = np.asarray(req.tokens, np.int32)
        for req in list(self.scheduler.queue):
            out[req.rid] = np.asarray(req.tokens, np.int32)
        return out

    def _admit(self, req: Request, slot: int, n_shared: int = 0,
               shared_ids=()) -> None:
        now = time.monotonic()
        req.t_admit = now
        self._admit_seq += 1
        req.admit_seq = self._admit_seq
        if self.obs is not None:
            self.obs.on_admit(req, now, self.chunked)
        plen = len(req.prompt)
        ps = self.page_size
        start = n_shared * ps                    # first suffix position
        if n_shared:
            self.page_tbl[slot, :n_shared] = shared_ids
            self.slot_pages[slot] = list(shared_ids)   # pin -> slot ref
        if n_shared:
            self.n_prefix_hits += 1
            self.n_shared_prompt_tokens += start
            if self.obs is not None:
                self.obs.on_prefix_hit(req, start)
        if self.chunked:
            # admitted -> chunking(start): no prefill here — _chunk_step
            # prefills one page-aligned chunk per step, starting past any
            # shared prefix (sharing composes: lookup once, chunk the
            # suffix only).
            self.slot_req[slot] = req
            self.slot_chunk_pos[slot] = start
            return
        if self.paged:
            npg = pages_per_seq(plen, ps)
            ids = self.allocator.alloc(npg - n_shared)
            assert ids is not None, "admission checked page availability"
            self.page_tbl[slot, n_shared:npg] = ids
            self.slot_pages[slot].extend(ids)    # [] or the shared prefix
            logits = self._run_partial_prefill(slot, req, start, plen)
        else:                                    # ring: n_shared is 0
            token_len, cache_len, masked = self._prefill_plan(plen)
            tokens = np.zeros(token_len, np.int32)
            tokens[:plen] = req.prompt
            fn = self._prefill_fn(token_len, cache_len, masked,
                                  req.enc is not None)
            args = (self.params, jnp.asarray(tokens)[None],
                    jnp.int32(plen))
            args += (jnp.asarray(req.enc)[None],) \
                if req.enc is not None else ()
            with (self.obs.annotate("nbl.prefill")
                  if self.obs is not None else _NULLCTX):
                logits, pcache = fn(*args)
            self.n_prefills += 1
            self.n_prefill_tokens += plen
            if self.obs is not None:
                self.obs.on_prefill(plen)
            self.cache = self._assign_jit(self.cache, pcache,
                                          jnp.int32(slot))
        self.slot_req[slot] = req
        self.slot_pos[slot] = plen               # position of its 1st token
        if self.obs is not None:
            self.obs.on_prefill_done(req, time.monotonic(), plen)
        # host-sync: readback -- the admission prefill's last-token logits
        # row: one deliberate device->host fetch per admitted request
        tok = self._sample(np.asarray(logits[0, -1], np.float32))
        self._emit(req, slot, tok, time.monotonic())

    def _run_partial_prefill(self, slot: int, req: Request,
                             start: int, end: int):
        """Prefill prompt[start:end) of ``slot``'s request into the PAGED
        cache (``start`` page-aligned; the span's table entries already
        allocated): pad/bucket the span, hand pages [0, start/ps) from the
        slot's own table row to the partial-prefill jit as the prefix,
        page-assign the returned cache, and publish full pages to the
        prefix index. BOTH partial-prefill callers run through here — the
        shared-prefix suffix at admission (_admit) and the chunked
        engine's per-step chunk (_chunk_step) — so their call conventions
        cannot drift apart. Returns the span's last-token logits."""
        ps = self.page_size
        span = req.prompt[start:end]
        token_len, cache_len, masked = self._prefill_plan(len(span))
        tokens = np.zeros(token_len, np.int32)
        tokens[:len(span)] = span
        start_pg = start // ps
        pb = pow2_ceil(start_pg) if start_pg else 0
        fn = self._prefill_fn(token_len, cache_len, masked,
                              req.enc is not None, prefix_pages=pb)
        args = (self.params, jnp.asarray(tokens)[None],
                jnp.int32(len(span)))
        if pb:
            ptbl = np.full(pb, -1, np.int32)
            ptbl[:start_pg] = self.page_tbl[slot, :start_pg]
            args += (self.cache, jnp.asarray(ptbl), jnp.int32(start))
        args += (jnp.asarray(req.enc)[None],) if req.enc is not None else ()
        with (self.obs.annotate("nbl.prefill")
              if self.obs is not None else _NULLCTX):
            logits, pcache = fn(*args)
        self.n_prefills += 1
        self.n_prefill_tokens += len(span)
        if self.obs is not None:
            self.obs.on_prefill(len(span))
        afn = self._assign_paged_fn(cache_len)
        # span tiles map to logical pages [start_pg, ...): hand the assign
        # jit the table row from there, right-padded back to the (static)
        # full row width
        row = np.full(self._pps, -1, np.int32)
        row[:self._pps - start_pg] = self.page_tbl[slot, start_pg:]
        self.cache = afn(self.cache, pcache, jnp.int32(slot),
                         jnp.asarray(row))
        if self.prefix_sharing and end // ps:
            # publish every FULL page written so far — PROGRESSIVELY for
            # chunks, so later admissions can share a long prompt's head
            # while its tail still chunks (earlier/shared pages are
            # already indexed; new nodes take the index's own reference)
            self.prefix_index.insert(req.prompt[:end],
                                     self.page_tbl[slot, :end // ps],
                                     self.allocator)
        return logits

    # ------------------------------------------------------- speculative --

    def _spec_draft_fn(self, m: int, gamma: int):
        """Draft-burst jit for registry entry ``m`` at width ``gamma``:
        builds the target-pool cache view at trace time and scans γ greedy
        decode steps. NOT donated — the target cache must survive the
        burst untouched (the view's in-burst KV writes die with the
        trace)."""
        key = (m, gamma)
        fn = self._spec_draft_jits.get(key)
        if fn is None:
            cfg = self.cfg
            dcfg, _dp = self.drafts[m]

            def _burst(dp, cache, token, pos, tbl):
                view = build_draft_cache_view(cfg, dcfg, cache)
                return draft_burst(dcfg, dp, view, token, pos, tbl, gamma)

            fn = _shared_jit(("spec_draft", cfg, dcfg, gamma),
                             lambda: jax.jit(_burst))
            self._spec_draft_jits[key] = fn
        return fn

    def _run_spec_verify(self, slot: int, req: Request, span: np.ndarray,
                         start: int, gamma: int):
        """Score a candidate block with ONE cache-extend: re-prefill
        ``span`` (the slot's tokens from its last page boundary ``start``
        plus the γ draft tokens) with the slot's own pages [0, start/ps)
        as the prefix, page-assign the result, and return the last γ+1
        logits rows (oldest first — rows for positions pos..pos+γ).
        The partial-prefill twin of ``_run_partial_prefill`` minus its
        prompt bookkeeping: no prefix-index publication, no n_prefills /
        prefill-token accounting — verify work is counted on the spec
        counters so the fuzz harness's prefill oracles stay exact."""
        ps = self.page_size
        token_len, cache_len, masked = self._prefill_plan(len(span))
        tokens = np.zeros(token_len, np.int32)
        tokens[:len(span)] = span
        start_pg = start // ps
        pb = pow2_ceil(start_pg) if start_pg else 0
        # enc is structurally None here: spec refuses cross-attn stacks
        fn = self._prefill_fn(token_len, cache_len, masked, False,
                              prefix_pages=pb, n_logits=gamma + 1)
        args = (self.params, jnp.asarray(tokens)[None],
                jnp.int32(len(span)))
        if pb:
            ptbl = np.full(pb, -1, np.int32)
            ptbl[:start_pg] = self.page_tbl[slot, :start_pg]
            args += (self.cache, jnp.asarray(ptbl), jnp.int32(start))
        with (self.obs.annotate("nbl.spec_verify")
              if self.obs is not None else _NULLCTX):
            logits, pcache = fn(*args)
        afn = self._assign_paged_fn(cache_len)
        row = np.full(self._pps, -1, np.int32)
        row[:self._pps - start_pg] = self.page_tbl[slot, start_pg:]
        self.cache = afn(self.cache, pcache, jnp.int32(slot),
                         jnp.asarray(row))
        return logits

    def _spec_slot_step(self, slot: int) -> int:
        """One draft-and-verify round for a spec slot: γ greedy draft
        tokens from the burst jit, one verifier cache-extend, per-row
        greedy acceptance, then ROLLBACK — the slot's committed length is
        whatever was emitted (a pure ``slot_pos`` bookkeeping fact; the
        rejected tail's KV is dead by the write-before-attend invariant)
        and surplus candidate-span pages go back to the pool. Returns
        #tokens emitted."""
        req = self.slot_req[slot]
        assert req is not None and req.spec_gamma > 0
        gamma = req.spec_gamma
        m = req.draft_m if req.draft_m is not None else next(iter(self.drafts))
        _dcfg, dparams = self.drafts[m]
        ps = self.page_size
        pos = int(self.slot_pos[slot])
        t0 = time.monotonic()
        fn = self._spec_draft_fn(m, gamma)
        with (self.obs.annotate("nbl.spec_draft")
              if self.obs is not None else _NULLCTX):
            prop = fn(dparams, self.cache,
                      jnp.asarray(self.slot_tok[slot:slot + 1, None]),
                      jnp.asarray(self.slot_pos[slot:slot + 1]),
                      jnp.asarray(self.page_tbl[slot:slot + 1]))
        # host-sync: readback -- the γ draft tokens must come host-side to
        # build the verify span (and the burst must complete before the
        # verifier's assign donates the cache)
        draft = np.asarray(prop[0], np.int32)               # (gamma,)
        # committed history covers positions [0, pos]; the verify span
        # restarts from the slot's last PAGE boundary so the prefix table
        # covers whole pages (span length >= gamma+1 since pos >= aligned)
        hist = np.concatenate([req.prompt,                  # host-only:
                               np.fromiter(req.tokens, np.int32,
                                           len(req.tokens))])
        aligned = (pos // ps) * ps
        span = np.concatenate([hist[aligned:], draft]).astype(np.int32)
        logits = self._run_spec_verify(slot, req, span, aligned, gamma)
        # host-sync: readback -- the verifier's γ+1 argmax rows drive
        # host-side acceptance (greedy: temperature 0 by construction)
        want = np.argmax(np.asarray(logits[0], np.float32),
                         axis=-1).astype(np.int32)          # (gamma+1,)
        n = int(accept_greedy(draft[None], want[None])[0])
        block = [int(t) for t in draft[:n]] + [int(want[n])]
        # the emission PLAN (post-truncation: max_new budget, first EOS)
        # is computed before any _emit so the burst's obs record lands
        # before a final token retires the request's trace
        remaining = req.max_new - len(req.tokens)
        plan: list[int] = []
        acc = 0
        for i, t in enumerate(block[:remaining]):
            plan.append(t)
            if i < n:
                acc += 1
            if self.eos_id is not None and t == self.eos_id:
                break
        self.n_spec_bursts += 1
        self.n_spec_draft_tokens += gamma
        self.n_spec_accepted_tokens += acc
        self.n_spec_tokens += len(plan)
        if self.obs is not None:
            self.obs.on_spec_burst(req, t0, time.monotonic(), gamma, acc,
                                   len(plan))
        now = time.monotonic()
        for t in plan:
            self.slot_pos[slot] += 1
            self._emit(req, slot, t, now)
        if self.slot_req[slot] is not None:
            # rollback: drop pages strictly beyond the one the slot's next
            # write (position slot_pos) lands in — the rejected tail's
            # pages are always private (aligned/ps >= any shared page)
            freed = release_tail_pages(self.page_tbl[slot],
                                       int(self.slot_pos[slot]), ps,
                                       self.allocator)
            if freed:
                gone = set(freed)
                self.slot_pages[slot] = [p for p in self.slot_pages[slot]
                                         if p not in gone]
        return len(plan)

    def _fault_pages(self, req: Request) -> int:
        """Worst-case pages this request can fault in ONE step once
        decoding: 1 (the next boundary crossing), plus the candidate-span
        pages a spec request's verifier may need (γ extra positions)."""
        if req.spec_gamma > 0 and self.drafts:
            return 1 + pages_per_seq(req.spec_gamma, self.page_size)
        return 1

    def _fault_reserve(self) -> int:
        """Headroom pages for everything in flight (the per-request fault
        bound summed) — spec requests reserve their candidate span, so
        admission cannot trade itself for a next-step preemption."""
        return sum(self._fault_pages(self.slot_req[s])
                   for s in self.active_slots)

    def _can_admit(self, req: Request, n_shared: int = 0) -> bool:
        """Paged admission gate, in REFERENCED pages (shared prefix pages
        are already referenced and bill nothing here): the prompt's NEW
        pages must be free, plus fault headroom per in-flight request
        (each may fault a page on the next boundary, γ+1 candidate-span
        pages for spec requests — admitting into that
        reserve would just trade the admission for a preemption). A
        page-aligned prompt faults a fresh page on its very first decode
        write, so it counts in the reserve too. Under pressure, LRU
        unreferenced prefix-index entries are reclaimed before giving up."""
        if not self.paged:
            return True
        plen = len(req.prompt)
        if self.chunked:
            # chunk-granular admission: only the FIRST chunk's new pages
            # must be free (later chunks allocate as they run, suspending
            # under pressure), plus the usual fault reserve per in-flight
            # request — chunked admission paces by actual page demand, not
            # the whole prompt.
            first_end = min(n_shared * self.page_size + self.chunk_tokens,
                            plen)
            need = (pages_per_seq(first_end, self.page_size) - n_shared
                    + self._fault_reserve())
            return (self.allocator.free_pages >= need
                    or self._reclaim_pages(need))
        npg = pages_per_seq(plen, self.page_size)
        own_fault = self._fault_pages(req) \
            if plen % self.page_size == 0 else self._fault_pages(req) - 1
        need = (npg - n_shared) + own_fault + self._fault_reserve()
        return self.allocator.free_pages >= need or self._reclaim_pages(need)

    def _chunk_step(self) -> int:
        """LEGACY path only (the fused pipeline plans chunk rows into its
        one dispatch instead — _plan_chunks): prefill ONE page-aligned
        chunk of the oldest chunking slot's prompt (FIFO over admission
        time), allocating only that chunk's pages. Non-final chunks leave the slot SUSPENDED until the next
        step — its pages are retained, its table row's tail stays
        unallocated so the batched decode masks it. The final chunk's
        logits seed decoding: the slot flips chunking -> decoding, its
        first token is emitted and it joins this same step's decode.
        Returns #tokens emitted (0 or 1)."""
        chunking = [s for s in self.active_slots
                    if self.slot_chunk_pos[s] >= 0]
        if not chunking:
            return 0
        slot = min(chunking, key=lambda s: self.slot_req[s].admit_seq)
        req = self.slot_req[slot]
        ps = self.page_size
        filled = int(self.slot_chunk_pos[slot])
        plen = len(req.prompt)
        end = min(filled + self.chunk_tokens, plen)
        start_pg, end_pg = span_pages(filled, end, ps)
        need = end_pg - start_pg                   # >= 1: end > filled
        t0 = time.monotonic()
        while True:
            ids = self.allocator.alloc(need)
            if ids is not None:
                break
            if self._reclaim_pages(need):
                continue
            # a chunking slot may steal pages only from slots YOUNGER than
            # itself (admit_seq order — tie-free where t_admit need not
            # be); with none to evict it SUSPENDS (pages retained) until
            # older requests finish. Preempting an older slot here would
            # break the oldest-always-finishes invariant and can livelock:
            # two part-prefilled requests ping-ponging each other's pages
            # forever (found by the serving-oracle fuzz harness).
            younger = [s for s in self.active_slots
                       if self.slot_req[s].admit_seq > req.admit_seq]
            if not younger:
                if self.obs is not None:
                    self.obs.on_suspend(req, time.monotonic())
                return 0
            self._preempt(max(younger,
                              key=lambda s: self.slot_req[s].admit_seq))
        self.page_tbl[slot, start_pg:end_pg] = ids
        self.slot_pages[slot].extend(ids)
        # the request's OWN earlier chunks are the "shared prefix"
        logits = self._run_partial_prefill(slot, req, filled, end)
        self.n_chunks += 1
        self.n_legacy_dispatches += 1      # the chunk's own prefill jit
        final = end >= plen
        if self.obs is not None:
            self.obs.on_chunk(req, t0, time.monotonic(), filled, end, final)
        if not final:
            self.slot_chunk_pos[slot] = end        # suspended till next step
            return 0
        # final chunk: chunking -> decoding
        self.slot_chunk_pos[slot] = -1
        self.slot_pos[slot] = plen
        # host-sync: readback -- final-chunk logits seed decoding: one
        # deliberate fetch when a prompt finishes chunking
        tok = self._sample(np.asarray(logits[0, -1], np.float32))
        self._emit(req, slot, tok, time.monotonic())
        return 1

    def step(self) -> int:
        """One engine iteration: admit into free slots, then one batched
        decode of everything in flight. Returns #tokens emitted (admission
        first-tokens included).

        With obs attached, the step is timed (host wall + the decode
        dispatch/readback split) and rolled up into one StepRecord +
        engine-track trace span; all of that is host-side bookkeeping —
        the device sees the exact same dispatch sequence either way."""
        if self.obs is None:
            return self._step_impl(None)
        t0 = time.monotonic()
        st = {"dispatch_s": 0.0, "n_decoding": 0, "n_chunking": 0,
              "chunk_tokens": 0, "prefill_tokens0": self.n_prefill_tokens,
              "tokens_planned": 0, "budget_utilization": 0.0}
        emitted = self._step_impl(st)
        self.obs.on_step(
            self, t0=t0, t1=time.monotonic(), dispatch_s=st["dispatch_s"],
            n_decoding=st["n_decoding"], n_chunking=st["n_chunking"],
            tokens_emitted=emitted,
            prefill_tokens=self.n_prefill_tokens - st["prefill_tokens0"],
            chunk_tokens=st["chunk_tokens"],
            tokens_planned=st["tokens_planned"],
            budget_utilization=st["budget_utilization"])
        return emitted

    def _step_impl(self, st: Optional[dict]) -> int:
        """One step, as plan -> execute -> commit: admission planning is
        shared; the fused path then plans chunk rows under the token
        budget and launches ONE fused dispatch, while the legacy path
        keeps the historical two-dispatch sequence (at most one chunk
        prefill jit, then the batched decode jit) as the parity
        oracle."""
        emitted = self._plan_admission()
        if self.fused:
            return emitted + self._step_fused(st)
        return emitted + self._step_legacy(st)

    def _plan_admission(self) -> int:
        """PLAN, phase 1 — admission: pop queued requests into free slots
        (FIFO, page-gated). On the fused path the scheduler's pull is
        paced by what the step's token budget leaves after charging every
        decoding slot 1 token — decode priority extends to admission —
        while the queue HEAD is always admitted (Scheduler.admit), so an
        over-budget prompt cannot livelock."""
        free = [i for i, r in enumerate(self.slot_req) if r is None]
        emitted = 0
        budget = None
        if self.fused and self.step_tokens is not None:
            n_dec = sum(1 for s in self.active_slots
                        if self.slot_chunk_pos[s] < 0)
            budget = decode_first_budget(self.step_tokens, n_dec)
        pending = self.scheduler.admit(len(free), budget=budget)
        while pending:
            req = pending.pop(0)
            if len(req.prompt) + req.max_new > self.max_len:
                # length guard at ADMISSION: requests submitted directly to
                # the scheduler bypass Engine.submit's check and would
                # otherwise index past the page table mid-decode
                self._reject(req, f"prompt({len(req.prompt)}) + max_new"
                             f"({req.max_new}) exceeds max_len"
                             f"={self.max_len}")
                continue
            serr = self._spec_guard(len(req.prompt), req.max_new,
                                    req.spec_gamma, req.draft_m)
            if serr is not None:
                # same guard submit() runs: direct scheduler submissions
                # must not reach the spec path unservable
                self._reject(req, serr)
                continue
            n_shared, shared_ids = self._prefix_lookup(req)
            if not self._can_admit(req, n_shared):
                if n_shared:
                    self.allocator.unref(shared_ids)   # drop the pin
                for r in reversed([req] + pending):   # restore FIFO order
                    self.scheduler.requeue(r)
                break
            self._admit(req, free.pop(), n_shared, shared_ids)
            if not self.chunked:
                emitted += 1                   # prefill emits a first token
        return emitted

    def _spec_rounds(self, active: list[int]) -> tuple[int, list[int]]:
        """Shared by both paths: one draft+verify round per live spec slot
        (they decode on their OWN jits, then sit out the step's batched /
        fused dispatch as masked rows). Returns (#tokens emitted, the
        slots still eligible for this step's dispatch) — a spec round can
        retire or preempt slots mid-list, so the survivors are
        re-filtered."""
        if not self.drafts:
            return 0, active
        emitted = 0
        spec = [s for s in active if self.slot_req[s].spec_gamma > 0]
        for slot in spec:
            emitted += self._spec_slot_step(slot)
        sset = set(spec)
        return emitted, [s for s in active
                         if s not in sset and self.slot_req[s] is not None]

    # ------------------------------------------------ fused step pipeline --

    def _plan_chunks(self, plan: StepPlan) -> dict[int, Request]:
        """PLAN, phase 2 (fused path) — chunk-row selection: grant
        page-aligned prompt spans to mid-chunking slots, OLDEST admission
        first, under what the token budget leaves after every decoding
        slot's 1-token charge (stepplan.decode_first_budget — decode rows
        are never displaced). Unlike the legacy one-chunk-per-step rule,
        several rows may be granted when the budget allows. Each granted
        row's pages are allocated here with the legacy discipline —
        reclaim LRU prefix entries, then preempt strictly-younger slots,
        else stop granting (the oldest suspended row must not be jumped
        by younger ones). Returns {slot: request} at grant time so commit
        can drop rows whose slot was preempted before execution."""
        row_req: dict[int, Request] = {}
        if not self.chunked:
            return row_req
        n_dec = sum(1 for s in self.active_slots
                    if self.slot_chunk_pos[s] < 0)
        remaining = decode_first_budget(self.step_tokens, n_dec)
        chunking = sorted(
            (s for s in self.active_slots if self.slot_chunk_pos[s] >= 0),
            key=lambda s: self.slot_req[s].admit_seq)
        ps = self.page_size
        for slot in chunking:
            req = self.slot_req[slot]
            if req is None or self.slot_chunk_pos[slot] < 0:
                continue   # preempted while an older row evicted youngers
            filled = int(self.slot_chunk_pos[slot])
            plen = len(req.prompt)
            end = chunk_span(filled, plen, self.chunk_tokens, remaining, ps)
            if end <= filled:
                break      # budget exhausted — younger rows wait too
            start_pg, end_pg = span_pages(filled, end, ps)
            need = end_pg - start_pg
            granted = True
            while True:
                ids = self.allocator.alloc(need)
                if ids is not None:
                    break
                if self._reclaim_pages(need):
                    continue
                younger = [s for s in self.active_slots
                           if self.slot_req[s].admit_seq > req.admit_seq]
                if not younger:
                    if self.obs is not None:
                        self.obs.on_suspend(req, time.monotonic())
                    granted = False
                    break
                self._preempt(max(younger,
                                  key=lambda s:
                                  self.slot_req[s].admit_seq))
            if not granted:
                break      # pool dry for the oldest row: stop granting
            self.page_tbl[slot, start_pg:end_pg] = ids
            self.slot_pages[slot].extend(ids)
            plan.chunk_rows.append(ChunkRow(slot, filled, end,
                                            final=end >= plen))
            row_req[slot] = req
            if remaining is not None:
                remaining -= end - filled
        return row_req

    def _step_fused(self, st: Optional[dict]) -> int:
        """Fused path: plan chunk rows, fault decode pages, run spec
        rounds, then EXECUTE one fused dispatch and COMMIT."""
        emitted = 0
        plan = StepPlan(budget=self.step_tokens)
        row_req = self._plan_chunks(plan)
        self._ensure_decode_pages()          # fused implies paged
        active = self.active_slots
        if self.chunked:
            active = [s for s in active if self.slot_chunk_pos[s] < 0]
        se, active = self._spec_rounds(active)
        emitted += se
        # paging faults / spec rounds above may have preempted slots the
        # plan selected: keep decode rows from the survivors and chunk
        # rows whose slot still holds the request they were granted for
        # (an evicted row's pages were released with its slot).
        plan.decode_slots = active
        plan.chunk_rows = [c for c in plan.chunk_rows
                           if self.slot_req[c.slot] is row_req[c.slot]]
        if st is not None:
            # "still mid-chunking after this step's chunk progress": rows
            # whose final chunk rides this step flip to decoding at commit
            st["n_chunking"] = (
                int(np.sum(self.slot_chunk_pos >= 0))
                - sum(1 for c in plan.chunk_rows if c.final))
            st["n_decoding"] = len(plan.decode_slots)
        if not plan.has_work():
            return emitted
        self._budget_util_sum += plan.utilization
        self._n_planned_steps += 1
        if st is not None:
            st["tokens_planned"] = plan.tokens_planned
            st["budget_utilization"] = plan.utilization
        logits, td0 = self._execute_fused(plan)
        return emitted + self._commit_fused(plan, logits, td0, st)

    def _execute_fused(self, plan: StepPlan):
        """EXECUTE: build the (n_slots, W) mixed batch and launch the
        step's ONE device dispatch. Decode rows carry their last token at
        width 1; chunk rows carry their page-aligned prompt span;
        everything else (free slots, spec slots, suspended chunkers)
        rides with row_len 0 — the fused attention's explicit write mask
        drops their KV writes and a 0 length attends nothing, so the LIVE
        page table is shared with the dispatch as-is (no defensive
        copy)."""
        w = plan.width
        tokens = np.zeros((self.n_slots, w), np.int32)
        row_pos = np.zeros(self.n_slots, np.int32)
        row_len = np.zeros(self.n_slots, np.int32)
        for s in plan.decode_slots:
            tokens[s, 0] = self.slot_tok[s]
            row_pos[s] = self.slot_pos[s]
            row_len[s] = 1
        for c in plan.chunk_rows:
            tokens[c.slot, :c.length] = \
                self.slot_req[c.slot].prompt[c.start:c.end]
            row_pos[c.slot] = c.start
            row_len[c.slot] = c.length
        td0 = time.monotonic()
        with (self.obs.annotate("nbl.fused_step")
              if self.obs is not None else _NULLCTX):
            logits, self.cache = self._fused_fn(w)(
                self.params, jnp.asarray(tokens), self.cache,
                jnp.asarray(row_pos), jnp.asarray(row_len),
                jnp.asarray(self.page_tbl))
        self.n_fused_dispatches += 1
        if plan.decode_slots:
            self.n_decode_steps += 1
            self._pool_in_use_sum += self.allocator.in_use
        return logits, td0

    def _commit_fused(self, plan: StepPlan, logits, td0: float,
                      st: Optional[dict]) -> int:
        """COMMIT: the step's single logits readback, then every host
        transition — chunk progress (+ progressive prefix publication),
        final-chunk seed emission, decode emission, retirement — all
        through the same _emit the legacy path uses."""
        # host-sync: readback -- THE per-step readback: every row's last-
        # valid-token logits row comes host-side once; decode sampling
        # AND final-chunk seed tokens are both served from this one fetch
        rows = np.asarray(logits[:, -1], np.float32)
        if st is not None:
            # dispatch + the logits device->host readback the sample needs
            st["dispatch_s"] = time.monotonic() - td0
        emitted = 0
        now = time.monotonic()
        ps = self.page_size
        ctoks = 0
        for c in plan.chunk_rows:
            req = self.slot_req[c.slot]
            self.n_chunks += 1
            # same per-chunk accounting as the legacy _run_partial_prefill
            # path, so counters stay path-independent per chunk
            self.n_prefills += 1
            self.n_prefill_tokens += c.length
            ctoks += c.length
            if self.obs is not None:
                self.obs.on_prefill(c.length)
                self.obs.on_chunk(req, td0, now, c.start, c.end, c.final)
            if self.prefix_sharing and c.end // ps:
                # publish full pages PROGRESSIVELY (see
                # _run_partial_prefill): later admissions can share a long
                # prompt's head while its tail still chunks
                self.prefix_index.insert(
                    req.prompt[:c.end],
                    self.page_tbl[c.slot, :c.end // ps], self.allocator)
            if c.final:
                # chunking -> decoding: the row's last-token logits seed
                # the request's first generated token
                self.slot_chunk_pos[c.slot] = -1
                self.slot_pos[c.slot] = len(req.prompt)
                self._emit(req, c.slot, self._sample(rows[c.slot]), now)
                emitted += 1
            else:
                self.slot_chunk_pos[c.slot] = c.end
        if st is not None:
            st["chunk_tokens"] = ctoks
        if plan.decode_slots and np.any(self.slot_chunk_pos >= 0):
            self.n_interleaved_decode_steps += 1   # decode BETWEEN chunks
        for slot in plan.decode_slots:
            req = self.slot_req[slot]
            assert req is not None             # snapshot taken post-preempt
            self.slot_pos[slot] += 1
            self._emit(req, slot, self._sample(rows[slot]), now)
            emitted += 1
        return emitted

    # ----------------------------------------------------- legacy stepping --

    def _step_legacy(self, st: Optional[dict]) -> int:
        """Legacy two-dispatch path (``fused_step=False``, ring engines,
        SSM / cross-attn stacks): at most ONE prefill chunk on its own
        jit, then the batched decode jit — the fused pipeline's parity
        oracle, kept token-exact with the pre-fused engine."""
        emitted = 0
        if self.chunked:
            if st is not None:
                ct0 = self.n_prefill_tokens
            emitted += self._chunk_step()
            if st is not None:
                st["chunk_tokens"] = self.n_prefill_tokens - ct0
        if self.paged:
            self._ensure_decode_pages()
        active = self.active_slots
        if self.chunked:
            decoding = [s for s in active if self.slot_chunk_pos[s] < 0]
            if st is not None:
                st["n_chunking"] = len(active) - len(decoding)
            active = decoding
        se, active = self._spec_rounds(active)
        emitted += se
        if not active:
            return emitted
        if st is not None:
            st["n_decoding"] = len(active)
        token = jnp.asarray(self.slot_tok[:, None])
        live_spec = [s for s in self.active_slots
                     if self.slot_req[s].spec_gamma > 0] \
            if self.drafts else []
        if self.chunked and np.any(self.slot_chunk_pos >= 0) or live_spec:
            # chunking and spec slots ride the batched decode fully
            # masked: pos -1 gives them valid length 0 and the decode
            # scatter's EXPLICIT write mask (decode_paged_attention)
            # routes a dead row's KV write out of bounds — so the LIVE
            # page table is handed to the dispatch as-is. (Historically
            # pos -1 wrapped the write to the row's last table column and
            # spec rows needed a defensive per-step table copy; the mask
            # retired both.)
            posv = self.slot_pos.copy()
            if self.chunked:
                posv[self.slot_chunk_pos >= 0] = -1
            posv[live_spec] = -1
            pos = jnp.asarray(posv)
        else:
            pos = jnp.asarray(self.slot_pos)
        tbl = self.page_tbl if self.paged else None
        if st is not None:
            td0 = time.monotonic()
        with (self.obs.annotate("nbl.decode")
              if self.obs is not None else _NULLCTX):
            if self.paged:
                logits, self.cache = self._decode_jit(
                    self.params, token, self.cache, pos,
                    jnp.asarray(tbl))
                self._pool_in_use_sum += self.allocator.in_use
            else:
                logits, self.cache = self._decode_jit(self.params, token,
                                                      self.cache, pos)
        self.n_decode_steps += 1
        self.n_legacy_dispatches += 1
        if self.chunked and np.any(self.slot_chunk_pos >= 0):
            self.n_interleaved_decode_steps += 1   # decode BETWEEN chunks
        # host-sync: readback -- THE per-step readback: every slot's logits
        # row comes host-side once so sampling stays off-device
        rows = np.asarray(logits[:, -1], np.float32)
        if st is not None:
            # dispatch + the logits device->host readback the sample needs
            st["dispatch_s"] = time.monotonic() - td0
        now = time.monotonic()
        for slot in active:
            req = self.slot_req[slot]
            assert req is not None             # snapshot taken post-preempt
            self.slot_pos[slot] += 1
            self._emit(req, slot, self._sample(rows[slot]), now)
            emitted += 1
        return emitted

    def run(self, max_steps: Optional[int] = None) -> dict[int, np.ndarray]:
        """Drain the queue; returns {rid: generated tokens (np.int32)} of
        TERMINAL requests only — a ``max_steps``-bounded run may stop with
        work in flight, whose partial generations are exposed via
        ``partials()`` (they are not silently dropped, just not final)."""
        steps = 0
        while self.has_work:
            self.step()
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break
        with self._finished_lock:
            done = sorted(self.finished.items())
        return {rid: np.asarray(r.tokens, np.int32) for rid, r in done}

    def _drop_finished(self, rid: int) -> None:
        """Forget a terminal request's record (AsyncEngine's
        retain_results=False memory knob) without racing a concurrent
        ``stats()`` snapshot of the finished dict."""
        with self._finished_lock:
            self.finished.pop(rid, None)

    def stats(self) -> dict:
        """End-of-run / live summary: latency percentiles + engine
        counters. Thread-safe against the step loop (the finished-dict
        snapshot is taken under the same lock every terminal transition
        writes under). With ``stats_window`` set (the default), the
        percentiles cover the most recently finished ``stats_window``
        served requests — O(window) per call instead of O(lifetime), and
        immune to AsyncEngine's retain_results=False dropping records —
        while ``n`` stays the lifetime served count (``window_n`` reports
        the percentile subset size when it clipped)."""
        with self._finished_lock:
            if self._recent_done is not None:
                reqs = list(self._recent_done)
                n_finished = self.n_finished
            else:
                reqs = list(self.finished.values())
                n_finished = None
        with self._count_lock:
            # += on the client reject path is a non-atomic RMW; read the
            # counter under the same lock both writers take
            n_rejected = self.n_rejected
        s = latency_stats(reqs)
        if n_finished is not None:
            if s["n"] < n_finished:
                s["window_n"] = s["n"]
            s["n"] = n_finished
        s.update(n_slots=self.n_slots, n_decode_steps=self.n_decode_steps,
                 n_prefills=self.n_prefills,
                 n_prefill_tokens=self.n_prefill_tokens,
                 n_rejected=n_rejected, n_cancelled=self.n_cancelled,
                 # fused plan->execute->commit pipeline: the dispatch
                 # split and the average planned-tokens/budget pressure
                 # (0.0 when unbudgeted or fully legacy)
                 n_fused_dispatches=self.n_fused_dispatches,
                 n_legacy_dispatches=self.n_legacy_dispatches,
                 step_tokens=self.step_tokens,
                 step_budget_utilization=(self._budget_util_sum
                                          / max(1, self._n_planned_steps)))
        if self.paged:
            s.update(
                n_pages=self.n_pages,
                n_preemptions=self.n_preemptions,
                pages_in_use=self.allocator.in_use,
                peak_pages_in_use=self.allocator.peak_in_use,
                pool_utilization=(self._pool_in_use_sum
                                  / max(1, self.n_decode_steps)
                                  / max(1, self.n_pages)))
        if self.prefix_sharing:
            s.update(n_prefix_hits=self.n_prefix_hits,
                     n_shared_prompt_tokens=self.n_shared_prompt_tokens,
                     prefix_index_entries=self.prefix_index.n_entries)
        if self.chunked:
            s.update(n_chunks=self.n_chunks,
                     prefill_chunk_tokens=self.chunk_tokens,
                     n_interleaved_decode_steps=
                     self.n_interleaved_decode_steps)
        if self.drafts:
            s.update(
                n_spec_bursts=self.n_spec_bursts,
                n_spec_draft_tokens=self.n_spec_draft_tokens,
                n_spec_accepted_tokens=self.n_spec_accepted_tokens,
                n_spec_tokens=self.n_spec_tokens,
                # emitted tokens per verifier call — the speculative win
                spec_tokens_per_burst=(self.n_spec_tokens
                                       / max(1, self.n_spec_bursts)),
                spec_acceptance_rate=(self.n_spec_accepted_tokens
                                      / max(1, self.n_spec_draft_tokens)))
        return s


# --------------------------------------------------------------------------
# Async serving host loop
# --------------------------------------------------------------------------

_END = object()     # stream-queue sentinel: the request reached a terminal


class Stream:
    """One request's live token feed out of an :class:`AsyncEngine`.

    Iterating yields ints the moment the engine emits them and stops when
    the request reaches a terminal state (``status`` is then one of
    ``"finished"`` / ``"cancelled"`` / ``"rejected"`` / ``"aborted"``,
    with ``error`` carrying the reject/abort reason). ``result()`` blocks
    for the final token array instead. The feed is SINGLE-consumer: one
    iterator owns the queue (``tokens`` always holds everything delivered
    so far regardless).

    Preemption safety: when the engine preempts a request it discards and
    later REGENERATES its tokens from the prompt. The stream de-duplicates
    by token index, and greedy decoding regenerates an identical prefix,
    so a consumer never sees a token twice and the streamed sequence
    stays token-exact with ``generate()``. With ``temperature > 0`` the
    regenerated prefix may diverge from what was already streamed; at the
    terminal transition the stream ADOPTS the engine's final token list,
    so ``result()`` (and the server's "done" event) always return the
    sequence the model actually committed — only the live-iterated feed
    can contain stale pre-preemption samples.
    """

    def __init__(self, rid: int):
        self.rid = rid
        self.tokens: list[int] = []
        self.status: Optional[str] = None
        self.error: Optional[str] = None
        self._q: _queue.Queue = _queue.Queue()
        self._done = threading.Event()

    def _push(self, tok: int, index: int) -> None:
        if index < len(self.tokens):
            return          # preemption replay: this index already streamed
        self.tokens.append(int(tok))
        self._q.put(int(tok))

    def _end(self, status: str, error: Optional[str],
             final_tokens=None) -> None:
        if self._done.is_set():
            return          # first terminal transition wins
        if final_tokens is not None:
            # authoritative: under temperature > 0 a preemption replay may
            # have resampled, and the streamed prefix then disagrees with
            # what the engine committed — result() must not splice rollouts
            self.tokens = [int(t) for t in final_tokens]
        self.status, self.error = status, error
        self._done.set()
        self._q.put(_END)

    @property
    def done(self) -> bool:
        return self._done.is_set()

    def __iter__(self):
        while True:
            item = self._q.get()
            if item is _END:
                self._q.put(_END)   # stay terminal for any later iteration
                return
            yield item

    def result(self, timeout: Optional[float] = None) -> np.ndarray:
        """Block until terminal; returns the (possibly partial, if
        cancelled) generated tokens as np.int32."""
        if not self._done.wait(timeout):
            raise TimeoutError(f"request {self.rid} still in flight")
        return np.asarray(self.tokens, np.int32)


class AsyncEngine:
    """Async serving host loop: a background thread drives ``Engine.step()``
    while client threads stream, cancel, and get backpressure.

    The wrapped :class:`Engine` is NOT thread-safe, so every engine
    mutation that touches slots/pages happens on ONE background step
    thread; the client-facing surface is confined to operations that are
    safe from other threads:

      submit_stream()  validates + queues through ``Engine.submit``
                       (scheduler append is single-consumer-safe, rid
                       allocation is locked) and returns a :class:`Stream`
                       fed straight from the engine's ``on_token`` hook —
                       tokens arrive mid-step, not at step boundaries.
                       Every rejection (oversize, backpressure past
                       ``max_pending`` live requests) comes back as a
                       Stream already ended with ``status="rejected"`` —
                       never an exception that could kill a socket
                       handler's loop.
      cancel(rid)      enqueues the rid into an inbox the step loop drains
                       BETWEEN steps, where ``Engine.cancel`` retires it
                       from any lifecycle state with allocator invariants
                       intact (pages + shared-prefix pins unref'd).
      shutdown()       stops the loop — ``drain=True`` serves all pending
                       work first, ``drain=False`` (or a drain timeout)
                       cancels everything live so no pages leak — and
                       re-raises any exception the step loop died on.

    A step-loop exception does not vanish into the thread: it is captured,
    every live request is cancelled (pages unref'd), open streams end with
    ``status="aborted"``, and the exception re-raises at ``shutdown()``
    (or the next ``submit_stream``). ``step_cb(engine)``, if given, runs
    after every step on the step thread — the fuzz harness hangs allocator
    invariant checks there.
    """

    def __init__(self, engine: Engine, *, max_pending: int = 64,
                 step_cb: Optional[Callable] = None,
                 retain_results: bool = True):
        if engine.on_token is not None or engine.on_finish is not None:
            raise ValueError("engine already has emission hooks installed "
                             "(wrapped by another AsyncEngine?)")
        if max_pending < 1:
            raise ValueError(f"max_pending must be >= 1, got {max_pending}")
        self.engine = engine
        self.max_pending = int(max_pending)
        self.step_cb = step_cb
        # retain_results=False drops each terminal request from
        # engine.finished once its stream has the result — the memory
        # knob for a long-running server (stats percentiles then cover
        # only retained requests; the scalar counters keep counting)
        self.retain_results = bool(retain_results)
        # RLock on purpose: _on_finish re-enters under submit_stream's hold
        # when engine.submit rejects inline (see _expect_early)
        self._lock = threading.RLock()
        self._streams: dict[int, Stream] = {}    # guarded-by: _lock
        self._live: set[int] = set()             # guarded-by: _lock
        self._early_end: dict[int, tuple] = {}   # guarded-by: _lock
        # True only while submit_stream's own engine.submit call is on
        # this stack (under _lock): the ONLY legitimate window in which a
        # terminal _on_finish may precede stream registration. Gating the
        # _early_end stash on it keeps terminals of requests submitted
        # OUTSIDE submit_stream (engine.submit / direct Scheduler.submit
        # on a wrapped engine) from accumulating stashes forever.
        self._expect_early = False               # guarded-by: _lock
        self._cancels: deque = deque()
        self._wake = threading.Event()
        self._stop = False
        self._dead = False      # teardown's last act # guarded-by: _lock
        self._drain_on_stop = True
        self._exc: Optional[BaseException] = None
        engine.on_token = self._on_token
        engine.on_finish = self._on_finish
        # wake the idle loop on ANY servable submission — including a
        # DIRECT engine.submit() on the wrapped engine, which otherwise
        # sits queued until an unrelated wake (submit_stream sets _wake
        # itself, so this is belt-and-braces there)
        engine.on_submit = lambda req: self._wake.set()
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="nbl-engine-step-loop")
        self._thread.start()

    # ------------------------------------------------------ client surface

    def submit_stream(self, prompt, max_new: int, *, enc=None,
                      spec_gamma: int = 0,
                      draft_m: Optional[int] = None) -> Stream:
        """Queue a request and return its live token :class:`Stream`.
        Thread-safe. ``spec_gamma``/``draft_m`` opt the request into
        speculative decoding (see :meth:`Engine.submit`). Unservable or
        over-capacity submissions return a
        stream already ended with ``status="rejected"`` (reject-with-error
        backpressure; ``stream.error`` says why)."""
        if self._stop:
            raise RuntimeError("AsyncEngine is shut down")
        if self._exc is not None:
            raise RuntimeError("engine step loop died") from self._exc
        with self._lock:
            self._expect_early = True
            try:
                if len(self._live) >= self.max_pending:
                    rid = self.engine._submit_rejected(
                        np.asarray(prompt, np.int32).reshape(-1), max_new,
                        f"engine at capacity "
                        f"(max_pending={self.max_pending} requests live)",
                        enc=enc)
                else:
                    rid = self.engine.submit(prompt, max_new, enc=enc,
                                             spec_gamma=spec_gamma,
                                             draft_m=draft_m)
            finally:
                self._expect_early = False
            s = Stream(rid)
            if rid in self._early_end:      # rejected inside submit()
                s._end(*self._early_end.pop(rid))
                # rejections never retain engine-side: sustained overload
                # is exactly what max_pending bounds, and pinning every
                # rejected prompt in engine.finished would unbound it
                self.engine._drop_finished(rid)
            elif self._dead:
                # lost the race with shutdown: the step thread already tore
                # down (its final act, under this lock, was _dead = True),
                # so nothing will ever serve or end this stream — end it
                # here rather than leave result()/iteration hanging forever
                s._end("aborted", "engine shut down before admission")
            else:
                # only LIVE streams are registered: a terminal stream is
                # never looked up again, and leaving it in _streams would
                # grow the wrapper by one entry per rejection — exactly
                # the overload path backpressure exists for
                self._streams[rid] = s
                self._live.add(rid)
        self._wake.set()
        return s

    def cancel(self, rid: int) -> None:
        """Request cancellation of ``rid``, whatever its state (queued /
        chunking mid-prompt / decoding). Applied by the step loop between
        steps so allocator invariants hold; a no-op if the request is
        already terminal. The stream ends with ``status="cancelled"`` and
        keeps its partial tokens."""
        self._cancels.append(rid)
        self._wake.set()

    def stats(self) -> dict:
        return self.engine.stats()

    def shutdown(self, *, drain: bool = True,
                 timeout: Optional[float] = None) -> None:
        """Stop the step loop. ``drain=True`` finishes all queued and
        in-flight work first; ``drain=False`` — or a drain that outlives
        ``timeout`` — cancels everything still live (pages unref'd,
        streams ended) before stopping. Idempotent. Re-raises the step
        loop's exception if it died."""
        self._drain_on_stop = drain
        self._stop = True
        self._wake.set()
        self._thread.join(timeout)
        if self._thread.is_alive():         # drain overran: abort the rest
            self._drain_on_stop = False
            self._wake.set()
            self._thread.join()
        if self._exc is not None:
            raise RuntimeError("engine step loop died") from self._exc

    def __enter__(self) -> "AsyncEngine":
        return self

    def __exit__(self, etype, evalue, tb) -> None:
        # on a client-side error, abort rather than serve out the backlog
        self.shutdown(drain=etype is None)

    # ---------------------------------------------------------- step loop

    def _loop(self) -> None:
        eng = self.engine
        try:
            while True:
                while self._cancels:
                    eng.cancel(self._cancels.popleft())
                if self._stop and (not self._drain_on_stop
                                   or not eng.has_work):
                    break
                if eng.has_work:
                    eng.step()
                    if self.step_cb is not None:
                        self.step_cb(eng)
                else:
                    # purely event-driven idle: every producer mutates its
                    # state (scheduler append / cancel inbox / stop flags)
                    # BEFORE setting the wake event, and the loop re-derives
                    # everything from that state after clear() — so a set
                    # raced away by clear() is never a lost wakeup, and an
                    # idle server burns zero CPU instead of polling
                    self._wake.wait()
                    self._wake.clear()
        except BaseException as e:          # surfaced at shutdown/submit
            self._exc = e
        finally:
            self._teardown()

    def _teardown(self) -> None:
        """Last act of the step thread: cancel whatever is still live (so
        pages/pins are released even on abort or a step crash), close any
        stream that survived that, and uninstall the engine hooks."""
        with self._lock:
            live = list(self._live)
        for rid in live:
            try:
                self.engine.cancel(rid)     # ends its stream "cancelled"
            except BaseException:
                pass                        # engine already broken: below
        msg = (f"engine step loop died: {self._exc!r}"
               if self._exc is not None else "shutdown before completion")
        with self._lock:
            leftovers = [self._streams[r] for r in self._live]
            self._live.clear()
            self._dead = True   # submit_stream self-ends from here on
        for s in leftovers:
            s._end("aborted", msg)
        self.engine.on_token = None
        self.engine.on_finish = None
        self.engine.on_submit = None

    # ------------------------------------------------------- engine hooks

    def _on_token(self, req: Request, tok: int) -> None:
        with self._lock:
            s = self._streams.get(req.rid)
        if s is not None:
            s._push(tok, len(req.tokens) - 1)

    def _on_finish(self, req: Request) -> None:
        status = ("cancelled" if req.cancelled
                  else "rejected" if req.error is not None else "finished")
        with self._lock:
            self._live.discard(req.rid)
            # a terminal stream is never looked up again (no further
            # tokens, teardown walks _live only) — drop it here or a
            # long-running server grows O(total requests)
            s = self._streams.pop(req.rid, None)
            if s is None:
                if self._expect_early:
                    # terminal before the stream registered (rejection
                    # inside submit_stream's own engine.submit call, which
                    # holds _lock around us): hand the end state back
                    self._early_end[req.rid] = (status, req.error)
                # else: a request submitted outside submit_stream (direct
                # engine/scheduler use on a wrapped engine) — no stream
                # will ever claim it; its record lives in engine.finished
                return
        s._end(status, req.error, final_tokens=req.tokens)
        if not self.retain_results or req.error is not None:
            # the stream carries the result to its consumer; the engine's
            # finished dict (and with it latency_stats history) would
            # otherwise also grow without bound under continuous traffic.
            # Rejections are dropped UNCONDITIONALLY — overload must not
            # grow memory per rejected request (see submit_stream)
            self.engine._drop_finished(req.rid)
