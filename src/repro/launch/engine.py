"""Continuous-batching serving engine over a slot-indexed KV cache.

Architecture (scheduler → engine → slot cache):

  Scheduler (launch/scheduler.py)
      FIFO queue + NBL-aware slot budget: a fixed HBM byte budget divided
      by the per-request cache footprint. NBL-linearized layers carry no
      cache, so a compressed model admits more concurrent requests on the
      same budget (paper §4.2).
  Engine (this module)
      Owns params + one slot cache (models/kv_cache.init_slot_cache).
      ``step()`` interleaves: (1) admission — for every free slot, pop a
      request, prefill it at batch=1, ``assign_slot`` its cache into the
      free row, emit its first token; (2) one *batched* decode over all
      slots with a per-slot position vector — retired/empty rows ride
      along masked by their kpos = -1 (models/attention.decode_attention);
      (3) retirement — EOS or max-token requests release their slot.
      Reassignment (``assign_slot``) overwrites every cache leaf's slot
      row wholesale, so a recycled slot can never read stale KV; between
      tenancies the dead row's decode output is simply discarded.
      ``models/kv_cache.reset_slot`` remains available for explicitly
      scrubbing a retired slot's state.
  Slot cache (models/kv_cache.py)
      (L, n_slots, ...) leaves; per-slot `kpos` position rows.

The decode jit compiles ONCE (shapes are (n_slots, 1) regardless of how
many requests are in flight); prefill compiles once per distinct prompt
length (bucket prompts client-side if that matters). Under a mesh the same
engine runs sharded: params/caches take their production PartitionSpecs
(distributed/sharding.py), batch/slot dims shard over "dp".
"""
from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed.api import jit_shardings, mesh_axes, shaped_spec
from repro.distributed.sharding import cache_specs, param_specs
from repro.launch.scheduler import (
    Request, Scheduler, latency_stats, nbl_slot_budget,
)
from repro.models import decode_step, prefill
from repro.models.kv_cache import assign_slot, init_slot_cache


class Engine:
    """Request-level continuous-batching decode engine.

    Either ``n_slots`` or ``cache_budget_bytes`` (NBL-aware: converted via
    ``nbl_slot_budget``) fixes the concurrency; given both, the budget is a
    ceiling. ``max_len`` bounds prompt + generated tokens per request.

    Sharding is captured at CONSTRUCTION time: build the engine inside
    ``use_mesh(mesh)`` to get sharded params/caches — an engine built
    un-meshed stays fully replicated even if later driven under a mesh.
    """

    def __init__(self, cfg: ModelConfig, params, *, max_len: int,
                 n_slots: Optional[int] = None,
                 cache_budget_bytes: Optional[int] = None,
                 eos_id: Optional[int] = None,
                 temperature: float = 0.0, seed: int = 0,
                 scheduler: Optional[Scheduler] = None,
                 donate: bool = True):
        if cache_budget_bytes is not None:
            budget_slots = nbl_slot_budget(cfg, cache_budget_bytes, max_len)
            # an explicit n_slots may narrow the budget, never exceed it
            n_slots = budget_slots if n_slots is None \
                else min(n_slots, budget_slots)
        elif n_slots is None:
            raise ValueError("need n_slots or cache_budget_bytes")
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        self.cfg = cfg
        self.params = params
        self.max_len = int(max_len)
        self.n_slots = int(n_slots)
        self.eos_id = eos_id
        self.temperature = float(temperature)
        self._rng = np.random.default_rng(seed)
        self.scheduler = scheduler or Scheduler()

        self.cache = init_slot_cache(cfg, self.n_slots, self.max_len)
        self.slot_req: list[Optional[Request]] = [None] * self.n_slots
        self.slot_pos = np.zeros(self.n_slots, np.int32)   # pos of last tok
        self.slot_tok = np.zeros(self.n_slots, np.int32)   # last emitted tok
        self.finished: dict[int, Request] = {}
        self.n_decode_steps = 0
        self.n_prefills = 0

        sharded = bool(mesh_axes())
        pspecs = param_specs(jax.eval_shape(lambda: params)) \
            if sharded else None
        cspecs = cache_specs(jax.eval_shape(lambda: self.cache)) \
            if sharded else None

        def _decode(p, token, cache, pos):
            return decode_step(cfg, p, token, cache, pos)

        def _assign(slot_cache, pcache, slot):
            return assign_slot(slot_cache, pcache, slot)

        dkw = dict(donate_argnums=(2,)) if donate else {}
        akw = dict(donate_argnums=(0,)) if donate else {}
        if sharded:
            tok_spec = shaped_spec((self.n_slots, 1), "dp", None)
            pos_spec = shaped_spec((self.n_slots,), "dp")
            self._decode_jit = jax.jit(
                _decode,
                in_shardings=jit_shardings((pspecs, tok_spec, cspecs,
                                            pos_spec)),
                out_shardings=jit_shardings((None, cspecs)), **dkw)
            self._assign_jit = jax.jit(
                _assign, in_shardings=jit_shardings((cspecs, None, None)),
                out_shardings=jit_shardings(cspecs), **akw)
        else:
            self._decode_jit = jax.jit(_decode, **dkw)
            self._assign_jit = jax.jit(_assign, **akw)
        # under a mesh the batch=1 prefill cache must come out in the same
        # production layout the slot cache uses, so _assign_jit never
        # reshards on admission.
        self._pspecs = pspecs
        self._pcspecs = None
        if sharded:
            from repro.launch.specs import cache_shapes
            self._pcspecs = cache_specs(cache_shapes(cfg, 1, self.max_len))
        self._prefill_jits: dict = {}   # (prompt_len, with_enc) -> jit fn

    # ------------------------------------------------------------- admin --

    def submit(self, prompt, max_new: int, *, enc=None) -> int:
        """Queue a request; returns its id. ``prompt`` 1-D int tokens."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size + max_new > self.max_len:
            raise ValueError(
                f"prompt({prompt.size}) + max_new({max_new}) exceeds "
                f"engine max_len={self.max_len}")
        return self.scheduler.submit(prompt, max_new, enc=enc)

    @property
    def active_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slot_req) if r is not None]

    @property
    def has_work(self) -> bool:
        return bool(self.active_slots) or len(self.scheduler) > 0

    # ----------------------------------------------------------- serving --

    def _prefill_fn(self, prompt_len: int, with_enc: bool):
        key = (prompt_len, with_enc)
        fn = self._prefill_jits.get(key)
        if fn is None:
            cfg, max_len = self.cfg, self.max_len

            def _prefill(p, tokens, enc=None):
                return prefill(cfg, p, tokens, enc=enc, cache_len=max_len)

            kw = {}
            if self._pcspecs is not None:
                ins = (self._pspecs, None) + ((None,) if with_enc else ())
                kw = dict(in_shardings=jit_shardings(ins),
                          out_shardings=jit_shardings((None, self._pcspecs)))
            fn = jax.jit(_prefill, **kw)
            self._prefill_jits[key] = fn
        return fn

    def _sample(self, logits_row: np.ndarray) -> int:
        """logits_row: (V,) float32."""
        if self.temperature <= 0.0:
            return int(np.argmax(logits_row))
        z = logits_row / self.temperature
        z = z - z.max()
        p = np.exp(z)
        return int(self._rng.choice(z.shape[0], p=p / p.sum()))

    def _emit(self, req: Request, slot: int, tok: int, now: float) -> None:
        """Record one generated token; retire the slot when done."""
        req.tokens.append(tok)
        if not req.t_first:
            req.t_first = now
        self.slot_tok[slot] = tok
        done = (len(req.tokens) >= req.max_new
                or (self.eos_id is not None and tok == self.eos_id))
        if done:
            # no cache scrub needed: assign_slot overwrites the full slot
            # row at the next tenancy, and dead rows are never read.
            req.t_finish = now
            self.finished[req.rid] = req
            self.slot_req[slot] = None

    def _admit(self, req: Request, slot: int) -> None:
        now = time.monotonic()
        req.t_admit = now
        fn = self._prefill_fn(len(req.prompt), req.enc is not None)
        tokens = jnp.asarray(req.prompt, jnp.int32)[None]
        args = (self.params, tokens) + (
            (jnp.asarray(req.enc)[None],) if req.enc is not None else ())
        logits, pcache = fn(*args)
        self.n_prefills += 1
        self.cache = self._assign_jit(self.cache, pcache, jnp.int32(slot))
        self.slot_req[slot] = req
        self.slot_pos[slot] = len(req.prompt)     # position of its 1st token
        tok = self._sample(np.asarray(logits[0, -1], np.float32))
        self._emit(req, slot, tok, time.monotonic())

    def step(self) -> int:
        """One engine iteration: admit into free slots, then one batched
        decode of everything in flight. Returns #tokens emitted (admission
        first-tokens included)."""
        free = [i for i, r in enumerate(self.slot_req) if r is None]
        emitted = 0
        for req in self.scheduler.admit(len(free)):
            self._admit(req, free.pop())
            emitted += 1                       # prefill emits a first token

        active = self.active_slots
        if not active:
            return emitted
        token = jnp.asarray(self.slot_tok[:, None])
        pos = jnp.asarray(self.slot_pos)
        logits, self.cache = self._decode_jit(self.params, token,
                                              self.cache, pos)
        self.n_decode_steps += 1
        rows = np.asarray(logits[:, -1], np.float32)
        now = time.monotonic()
        for slot in active:
            req = self.slot_req[slot]
            self.slot_pos[slot] += 1
            self._emit(req, slot, self._sample(rows[slot]), now)
            emitted += 1
        return emitted

    def run(self, max_steps: Optional[int] = None) -> dict[int, np.ndarray]:
        """Drain the queue; returns {rid: generated tokens (np.int32)}."""
        steps = 0
        while self.has_work:
            self.step()
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break
        return {rid: np.asarray(r.tokens, np.int32)
                for rid, r in sorted(self.finished.items())}

    def stats(self) -> dict:
        s = latency_stats(list(self.finished.values()))
        s.update(n_slots=self.n_slots, n_decode_steps=self.n_decode_steps,
                 n_prefills=self.n_prefills)
        return s
