"""Request scheduling for the continuous-batching engine.

Data flow: ``Scheduler`` (this module) holds the waiting-request queue and
decides *how many* requests may be in flight; ``launch/engine.py`` owns the
slot-indexed KV cache (models/kv_cache.py) and moves admitted requests
through prefill → batched decode → retirement, recycling the freed slot.

NBL-aware admission budget
--------------------------
The number of concurrent slots is derived from an HBM byte budget:

    per_slot = cache_bytes(cfg, batch=1, max_len)      # one request's state
    n_slots  = clamp(budget_bytes // per_slot, 1, max_slots)

NBL-linearized layers carry NO cache (kv_cache.py), so compressing m of K
attention layers shrinks ``per_slot`` by ≈ m/K (paper §4.2, Table 21) and
the same budget admits ≈ K/(K−m)× more concurrent requests. This is the
mechanism that converts NBL's freed serve-state into served traffic — the
throughput benchmark (benchmarks/run.py serving_throughput) measures
requests/s rising monotonically with m at a fixed budget.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.models.kv_cache import cache_bytes


@dataclass
class Request:
    """One generation request. ``prompt`` is a 1-D int32 token array."""
    rid: int
    prompt: np.ndarray
    max_new: int
    enc: Optional[np.ndarray] = None          # VLM frontend embeddings (T,d)
    # lifecycle timestamps (engine-filled; time.monotonic seconds)
    t_submit: float = 0.0
    t_admit: float = 0.0
    t_first: float = 0.0
    t_finish: float = 0.0
    tokens: list = field(default_factory=list)

    @property
    def latency(self) -> float:
        return self.t_finish - self.t_submit

    @property
    def ttft(self) -> float:
        """Time to first token."""
        return self.t_first - self.t_submit


def nbl_slot_budget(cfg: ModelConfig, budget_bytes: int, max_len: int,
                    *, max_slots: int = 256) -> int:
    """Concurrent-slot count a byte budget buys at ``max_len`` context.
    Fully-linearized stacks (per-slot state = 0) clamp to ``max_slots``."""
    per_slot = cache_bytes(cfg, 1, max_len)
    if per_slot <= 0:
        return max_slots
    return int(max(1, min(max_slots, budget_bytes // per_slot)))


class Scheduler:
    """FIFO admission queue with a per-step prefill cap.

    ``max_prefill_per_step`` bounds head-of-line blocking: each engine step
    admits at most that many new requests (each admission runs a serial
    prefill) before the batched decode of everything in flight.
    """

    def __init__(self, *, max_prefill_per_step: int = 4):
        if max_prefill_per_step < 1:
            raise ValueError("max_prefill_per_step must be >= 1 (the engine "
                             "drain loop would never admit work)")
        self.queue: deque[Request] = deque()
        self.max_prefill_per_step = max_prefill_per_step
        self._next_rid = 0

    def submit(self, prompt, max_new: int, *, enc=None,
               now: Optional[float] = None) -> int:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError("empty prompt")
        if max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {max_new}")
        req = Request(rid=self._next_rid, prompt=prompt, max_new=max_new,
                      enc=enc, t_submit=time.monotonic() if now is None
                      else now)
        self._next_rid += 1
        self.queue.append(req)
        return req.rid

    def admit(self, free_slots: int) -> list[Request]:
        """Pop up to min(free_slots, max_prefill_per_step) requests, FIFO."""
        n = min(free_slots, self.max_prefill_per_step, len(self.queue))
        return [self.queue.popleft() for _ in range(n)]

    def __len__(self) -> int:
        return len(self.queue)


def latency_stats(requests: list[Request]) -> dict:
    """requests/s + latency percentiles over a finished request set."""
    done = [r for r in requests if r.t_finish > 0]
    if not done:
        return {"n": 0}
    lat = np.array([r.latency for r in done])
    ttft = np.array([r.ttft for r in done])
    span = (max(r.t_finish for r in done)
            - min(r.t_submit for r in done)) or 1e-9
    return {
        "n": len(done),
        "requests_per_s": len(done) / span,
        "tokens_per_s": sum(len(r.tokens) for r in done) / span,
        "p50_latency_s": float(np.percentile(lat, 50)),
        "p99_latency_s": float(np.percentile(lat, 99)),
        "p50_ttft_s": float(np.percentile(ttft, 50)),
    }
