"""Request scheduling for the continuous-batching engine.

Data flow: ``Scheduler`` (this module) holds the waiting-request queue and
decides *how many* requests may be in flight; ``launch/engine.py`` owns the
slot-indexed KV cache (models/kv_cache.py) and moves admitted requests
through prefill → batched decode → retirement, recycling the freed slot.

NBL-aware admission budgets
---------------------------
The number of concurrent requests is derived from an HBM byte budget, in
one of two units:

ring (slot) budget — one full-length cache ring reserved per request:

    per_slot = cache_bytes(cfg, batch=1, max_len)      # one request's state
    n_slots  = clamp(budget_bytes // per_slot, 1, max_slots)

page budget — the paged engine (models/paging.py) reserves nothing up
front; the pool is sized in pages and a request is billed only the pages an
*expected* generation length actually REFERENCES — under prefix sharing the
workload's common prompt-prefix pages are billed once against the pool, not
once per request:

    pool_pages  = budget_bytes // (caching_layers * page_bytes)
    shared      = shared_prefix_len // page_size        # billed ONCE
    per_request = ceil(expected_len / page_size) - shared
    n_requests  = clamp((pool_pages - shared) // per_request, 1, max_slots)

NBL-linearized layers carry NO cache (kv_cache.py) and NO page pool, so
compressing m of K attention layers shrinks the per-request bill by ≈ m/K
(paper §4.2, Table 21) in BOTH units — and in the paged unit it composes
multiplicatively with page granularity: fewer caching layers × only-used
pages. The throughput benchmarks (serving_throughput / paged_throughput in
benchmarks/run.py) measure requests/s rising monotonically with m at a
fixed budget, and paged >= ring on short-prompt mixes.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.models.kv_cache import cache_bytes


@dataclass
class Request:
    """One generation request. ``prompt`` is a 1-D int32 token array."""
    rid: int
    prompt: np.ndarray
    max_new: int
    enc: Optional[np.ndarray] = None          # VLM frontend embeddings (T,d)
    # lifecycle timestamps (engine-filled; time.monotonic seconds)
    t_submit: float = 0.0
    t_admit: float = 0.0
    t_first: float = 0.0
    t_finish: float = 0.0
    tokens: list = field(default_factory=list)
    # engine-filled lifecycle outcomes: preemption restarts (the TTFT clock
    # rewound this many times — latency_stats splits these out so restart
    # latency cannot silently pollute paged-vs-ring comparisons), the
    # rejection reason (None = served; set at submit or admission), and the
    # cancellation terminal state (Engine.cancel — generated-so-far tokens
    # are KEPT as the partial result, but the request is excluded from the
    # latency percentiles: its t_first may still be the 0.0 "unserved"
    # sentinel, which used to yield garbage negative TTFTs).
    n_preemptions: int = 0
    error: Optional[str] = None
    cancelled: bool = False
    # admission ORDER (engine-filled, monotone per admission incl.
    # re-admission after preemption): the engine's age comparisons key on
    # this, not t_admit — two same-step admissions can tie on a coarse
    # monotonic clock, and a tie would turn the chunked engine's
    # steal-only-from-younger rule into a mutual permanent suspend.
    admit_seq: int = 0
    # speculative decoding (launch/engine.py spec mode): spec_gamma > 0
    # opts this request into draft-and-verify decode — the drafter proposes
    # spec_gamma tokens per step and the verifier scores the block in one
    # cache-extend pass. draft_m picks which registered NBL drafter to use
    # (None = the engine's default drafter).
    spec_gamma: int = 0
    draft_m: Optional[int] = None

    @property
    def latency(self) -> float:
        return self.t_finish - self.t_submit

    @property
    def ttft(self) -> float:
        """Time to first token."""
        return self.t_first - self.t_submit


def nbl_slot_budget(cfg: ModelConfig, budget_bytes: int, max_len: int,
                    *, max_slots: int = 256) -> int:
    """Concurrent-slot count a byte budget buys at ``max_len`` context.
    Fully-linearized stacks (per-slot state = 0) clamp to ``max_slots``."""
    per_slot = cache_bytes(cfg, 1, max_len)
    if per_slot <= 0:
        return max_slots
    return int(max(1, min(max_slots, budget_bytes // per_slot)))


def nbl_page_budget(cfg: ModelConfig, budget_bytes: int, *, page_size: int,
                    expected_len: int, max_slots: int = 256,
                    shared_prefix_len: int = 0) -> int:
    """Concurrent-request count a byte budget buys under PAGED allocation.

    The budget is converted to a per-layer pool size (pages) across the
    stack's caching attention layers, then divided by the pages one request
    of ``expected_len`` tokens REFERENCES. Linearized (nbl/drop) layers
    contribute zero to the page bill, so the count is monotone in NBL-m;
    stacks with no caching attention at all clamp to ``max_slots``. Note
    the unit covers attention KV only — O(1)-per-slot SSM/conv/cross state
    is not paged (models/paging.py) and is negligible at serving lengths.

    ``shared_prefix_len`` (prefix sharing) is the workload's common
    prompt-prefix length in tokens: its full pages are billed ONCE against
    the pool — every request references the same physical pages — instead
    of once per request, so a fleet sharing a long system prompt admits
    close to pool/(unique pages per request) concurrent requests.
    """
    from repro.models.paging import pages_per_seq, pool_pages_for_budget
    pool = pool_pages_for_budget(cfg, budget_bytes, page_size)
    if pool is None:
        return max_slots
    shared_pages = min(max(0, shared_prefix_len),
                       max(1, expected_len)) // page_size
    pool = max(0, pool - shared_pages)            # the shared pages, once
    per_req = max(1, pages_per_seq(max(1, expected_len), page_size)
                  - shared_pages)
    return int(max(1, min(max_slots, pool // per_req)))


class Scheduler:
    """FIFO admission queue with per-step prefill caps.

    ``max_prefill_per_step`` bounds head-of-line blocking in REQUESTS: each
    engine step admits at most that many new requests (each admission runs
    a serial prefill) before the batched decode of everything in flight.
    ``max_prefill_tokens_per_step`` bounds it in TOKENS — the unit prefill
    cost actually scales in: a request-count cap happily admits several
    long prompts into one step (minutes of serial prefill while every
    in-flight decode stalls), whereas the token budget stops admission
    before the step's prompt tokens exceed it. The queue's HEAD request is
    always admitted even when it alone busts the budget (an over-budget
    prompt must not starve the queue forever); the engine's chunked
    prefill is the finer-grained cure for that one prompt.
    """

    def __init__(self, *, max_prefill_per_step: int = 4,
                 max_prefill_tokens_per_step: Optional[int] = None):
        if max_prefill_per_step < 1:
            raise ValueError("max_prefill_per_step must be >= 1 (the engine "
                             "drain loop would never admit work)")
        if max_prefill_tokens_per_step is not None \
                and max_prefill_tokens_per_step < 1:
            raise ValueError("max_prefill_tokens_per_step must be >= 1 or "
                             "None (the head request could never admit)")
        self.queue: deque[Request] = deque()
        self.max_prefill_per_step = max_prefill_per_step
        self.max_prefill_tokens_per_step = max_prefill_tokens_per_step
        self._next_rid = 0                   # guarded-by: _lock
        # guards the mutations the async host loop splits across threads:
        # rid allocation (client threads; a counter increment is not
        # atomic) and queue append-vs-remove (client submit appends while
        # the step thread scans in remove() — deque.remove runs a Python-
        # level __eq__ per element, so an append landing mid-scan raises
        # "deque mutated during remove()"). Step-thread-only single ops
        # (admit's popleft, requeue's appendleft) stay lock-free: an
        # individual deque op is atomic and only the step thread pops.
        self._lock = threading.Lock()

    def make_request(self, prompt, max_new: int, *, enc=None,
                     spec_gamma: int = 0, draft_m: Optional[int] = None,
                     now: Optional[float] = None) -> Request:
        """Build a Request with a fresh rid WITHOUT queueing or validating
        it — the engine's reject-with-error paths (oversize submit,
        backpressure) record these terminally instead of serving them."""
        with self._lock:
            rid = self._next_rid
            self._next_rid += 1
        return Request(rid=rid,
                       prompt=np.asarray(prompt, np.int32).reshape(-1),
                       max_new=max_new, enc=enc,
                       spec_gamma=spec_gamma, draft_m=draft_m,
                       t_submit=time.monotonic() if now is None else now)

    def submit(self, prompt, max_new: int, *, enc=None,
               now: Optional[float] = None) -> int:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError("empty prompt")
        if max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {max_new}")
        req = self.make_request(prompt, max_new, enc=enc, now=now)
        self.submit_request(req)
        return req.rid

    def submit_request(self, req: Request) -> None:
        """Queue an already-built Request (the engine's traced submit path
        makes the request first so its rid/t_submit can feed the obs
        hooks, then queues it here)."""
        with self._lock:                     # serialize vs remove()'s scan
            self.queue.append(req)

    def admit(self, free_slots: int,
              budget: Optional[int] = None) -> list[Request]:
        """Pop FIFO requests for this step: at most min(free_slots,
        max_prefill_per_step) of them, stopping early before a prompt that
        would push the step past the token budget — the narrower of the
        standing ``max_prefill_tokens_per_step`` and the caller's
        per-step ``budget`` (the fused engine passes what its
        decode-priority ``step_tokens`` budget left after charging decode
        rows). The head request always admits — see the class
        docstring — so an over-budget prompt cannot livelock."""
        n = min(free_slots, self.max_prefill_per_step, len(self.queue))
        if budget is not None:
            budget = budget if self.max_prefill_tokens_per_step is None \
                else min(budget, self.max_prefill_tokens_per_step)
        else:
            budget = self.max_prefill_tokens_per_step
        out: list[Request] = []
        toks = 0
        while len(out) < n:
            nxt = self.queue[0]
            if out and budget is not None \
                    and toks + len(nxt.prompt) > budget:
                break
            toks += len(nxt.prompt)
            out.append(self.queue.popleft())
        return out

    def requeue(self, req: Request) -> None:
        """Return a request to the FRONT of the queue (admission deferred
        for lack of pages, or preempted mid-decode — it restarts from its
        prompt, so any generated tokens must have been discarded)."""
        self.queue.appendleft(req)

    def remove(self, rid: int) -> Optional[Request]:
        """Pull a still-QUEUED request out of the queue (cancellation of a
        request the engine never admitted). Returns it, or None if ``rid``
        is not waiting here (already admitted, finished, or unknown).
        Holds the scheduler lock for the whole scan+remove: client threads
        append concurrently under the async host loop, and deque.remove's
        per-element Python-level __eq__ can otherwise be interleaved with
        an append, which CPython reports as "deque mutated during
        remove()"."""
        with self._lock:
            for req in self.queue:
                if req.rid == rid:
                    self.queue.remove(req)
                    return req
        return None

    def __len__(self) -> int:
        return len(self.queue)


def latency_stats(requests: list[Request],
                  window: Optional[int] = None) -> dict:
    """requests/s + latency/TTFT percentiles + per-request decode speed over
    a finished request set. Tail TTFT (p99) and per-request decode tokens/s
    are the evidence the paged-vs-ring comparison needs: paging admits more
    requests (better tail TTFT) at the possible cost of preemption restarts.

    Preempted requests (``n_preemptions > 0`` — their TTFT clock was
    rewound and includes at least one full restart) are counted separately:
    ``n_preempted_requests`` plus ``p99_ttft_preempted_s`` over just that
    subset, so restart latency is visible instead of silently skewing the
    headline percentiles' interpretation. Rejected requests (``error`` set)
    never served and are excluded from every percentile; they surface as
    ``n_rejected``. Cancelled requests (``cancelled`` — a terminal state,
    possibly with a 0.0 ``t_first`` sentinel that would otherwise turn into
    a garbage negative TTFT) are likewise excluded and surface as
    ``n_cancelled``. Queue-delay percentiles (submit → admission wait, the
    async host loop's backpressure signal) are reported over requests whose
    admission timestamp survived (preemption rewinds it).

    ``window`` (None = unbounded) restricts the percentile set to the most
    RECENTLY FINISHED ``window`` served requests — the long-running-server
    path: without it every ``stats()`` call re-sorts the entire retained
    history, O(n log n) in server lifetime. Terminal counts (``n``,
    ``n_rejected``, ``n_cancelled``) always cover the full input (the
    engine's counters are lifetime-monotone); only the percentile arrays
    and the throughput span are windowed, and ``window_n`` reports the
    subset size whenever a window actually clipped."""
    rejected = [r for r in requests if r.error is not None]
    cancelled = [r for r in requests if r.cancelled and r.error is None]
    done = [r for r in requests
            if r.t_finish > 0 and r.error is None and not r.cancelled]
    if not done:
        return {"n": 0, "n_rejected": len(rejected),
                "n_cancelled": len(cancelled)}
    n_total_done = len(done)
    if window is not None and len(done) > window:
        # most recently finished subset; selection is O(n), and the
        # percentile sorts below then cost O(window log window)
        done.sort(key=lambda r: r.t_finish)
        done = done[-window:]
    lat = np.array([r.latency for r in done])
    ttft = np.array([r.ttft for r in done])
    # decode rate excludes the prefill-emitted first token; requests that
    # finished at their first token have no decode phase to rate.
    dec = np.array([(len(r.tokens) - 1) / max(r.t_finish - r.t_first, 1e-9)
                    for r in done if len(r.tokens) > 1])
    span = (max(r.t_finish for r in done)
            - min(r.t_submit for r in done)) or 1e-9
    preempted = [r for r in done if r.n_preemptions > 0]
    out = {
        "n": n_total_done,
        "n_rejected": len(rejected),
        "n_cancelled": len(cancelled),
        "requests_per_s": len(done) / span,
        "tokens_per_s": sum(len(r.tokens) for r in done) / span,
        "p50_latency_s": float(np.percentile(lat, 50)),
        "p99_latency_s": float(np.percentile(lat, 99)),
        "p50_ttft_s": float(np.percentile(ttft, 50)),
        "p99_ttft_s": float(np.percentile(ttft, 99)),
        "n_preempted_requests": len(preempted),
    }
    if len(done) < n_total_done:
        out["window_n"] = len(done)
    if preempted:
        pttft = np.array([r.ttft for r in preempted])
        out["p99_ttft_preempted_s"] = float(np.percentile(pttft, 99))
    if dec.size:
        out["decode_tok_s_p50"] = float(np.percentile(dec, 50))
        out["decode_tok_s_min"] = float(dec.min())
    qd = np.array([r.t_admit - r.t_submit for r in done if r.t_admit > 0])
    if qd.size:
        out["p50_queue_delay_s"] = float(np.percentile(qd, 50))
        out["p99_queue_delay_s"] = float(np.percentile(qd, 99))
    return out
