"""ShapeDtypeStruct stand-ins for every model input — the dry-run lowers
against these (weak-type-correct, shardable, no device allocation)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import init_cache, init_params
from repro.models.kv_cache import init_slot_cache


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def param_shapes(cfg: ModelConfig):
    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))


def cache_shapes(cfg: ModelConfig, batch: int, max_len: int):
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len))


def slot_cache_shapes(cfg: ModelConfig, n_slots: int, max_len: int):
    """Slot-indexed serving cache (per-slot kpos) — engine decode state."""
    return jax.eval_shape(lambda: init_slot_cache(cfg, n_slots, max_len))


def paged_cache_shapes(cfg: ModelConfig, n_slots: int, max_len: int, *,
                       page_size: Optional[int] = None,
                       n_pages: Optional[int] = None):
    """Paged serving cache: per-layer page pools + slot-state rows
    (models/paging.py) — the paged engine's decode state."""
    from repro.models.paging import DEFAULT_PAGE_SIZE, init_paged_cache
    ps = page_size or DEFAULT_PAGE_SIZE
    return jax.eval_shape(lambda: init_paged_cache(
        cfg, n_slots, max_len, page_size=ps, n_pages=n_pages))


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Inputs for the step function selected by ``shape.kind``:

      train       -> {"batch": {tokens, labels[, enc]}}
      prefill     -> {"tokens"[, "enc"]}
      decode      -> {"token", "pos", "cache"}   (cache at shape.seq_len)
      serve       -> {"token", "pos", "cache"}   (slot cache; pos is a
                     per-slot (B,) vector — the engine's batched decode)
      serve_paged -> {"token", "pos", "page_tbl", "cache"}   (page-pool
                     cache sized for full reservation; page_tbl maps each
                     slot's logical pages to physical pool pages)
      prefill_shared -> {"tokens", "prefix_tbl", "prefix_len", "cache"}
                     (prefix-sharing partial prefill: a batch of suffixes,
                     each seq_len tokens at absolute positions past a
                     shared seq_len-token prompt prefix whose pages —
                     prefix_tbl — are already resident in the paged pools)
      prefill_chunked -> {"tokens", "prefix_tbl", "prefix_len", "cache"}
                     (chunked prefill: one seq_len-token page-aligned
                     chunk per request resuming behind 7*seq_len tokens
                     of its OWN prompt already in the pools — the same
                     partial-prefill jit as prefill_shared, prefix_tbl
                     pointing at the request's earlier chunks)
      spec_verify -> {"tokens", "prefix_tbl", "prefix_len", "cache"}
                     (speculative VERIFY: the engine's candidate-block
                     cache-extend — a seq_len-token span (page tail +
                     γ draft tokens, batch=1) resuming behind the slot's
                     own committed pages through the same pow2-bucketed
                     partial-prefill jit as prefill_chunked; the γ+1
                     logits rows come from prefill's n_logits window, so
                     the lowered graph matches the serving jit)
      fused_step  -> {"tokens", "row_pos", "row_len", "page_tbl", "cache"}
                     (the fused plan→execute→commit dispatch: a mixed
                     (n_slots, W) batch — decode rows carry 1 valid token,
                     chunk rows a page-aligned span up to W=seq_len,
                     inactive rows length 0 — against the paged pools via
                     the live per-slot page table; per-row last-valid
                     logits come back (B, 1, V))
    """
    b, s = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.compute_dtype)
    if shape.kind == "train":
        batch = {"tokens": sds((b, s), jnp.int32),
                 "labels": sds((b, s), jnp.int32)}
        if cfg.family == "vlm":
            batch["enc"] = sds((b, cfg.n_frontend_tokens, cfg.d_model), dt)
        return {"batch": batch}
    if shape.kind == "prefill":
        out = {"tokens": sds((b, s), jnp.int32)}
        if cfg.family == "vlm":
            out["enc"] = sds((b, cfg.n_frontend_tokens, cfg.d_model), dt)
        return out
    if shape.kind == "decode":
        return {"token": sds((b, 1), jnp.int32),
                "pos": sds((), jnp.int32),
                "cache": cache_shapes(cfg, b, s)}
    if shape.kind == "serve":
        return {"token": sds((b, 1), jnp.int32),
                "pos": sds((b,), jnp.int32),
                "cache": slot_cache_shapes(cfg, b, s)}
    if shape.kind == "serve_paged":
        from repro.models.paging import DEFAULT_PAGE_SIZE, pages_per_seq
        pps = pages_per_seq(s, DEFAULT_PAGE_SIZE)
        return {"token": sds((b, 1), jnp.int32),
                "pos": sds((b,), jnp.int32),
                "page_tbl": sds((b, pps), jnp.int32),
                "cache": paged_cache_shapes(cfg, b, s)}
    if shape.kind == "prefill_shared":
        from repro.models.paging import DEFAULT_PAGE_SIZE, pages_per_seq
        pps = pages_per_seq(s, DEFAULT_PAGE_SIZE)
        # pools hold the shared prefix (s tokens, billed once) plus each
        # suffix's pages — the 2*s max_len sizes the per-slot table rows
        return {"tokens": sds((b, s), jnp.int32),
                "prefix_tbl": sds((pps,), jnp.int32),
                "prefix_len": sds((), jnp.int32),
                "cache": paged_cache_shapes(cfg, b, 2 * s)}
    if shape.kind == "prefill_chunked":
        from repro.models.paging import DEFAULT_PAGE_SIZE, pages_per_seq
        # chunk 8 of 8: s new tokens behind 7*s already-chunked ones; the
        # 8*s max_len sizes the per-slot table rows and the pools. The
        # prefix table is POW2-BUCKETED exactly as the engine compiles it
        # (launch/engine._chunk_step buckets prefix_pages), so the dryrun
        # lowers the jit that actually serves.
        pre = 7 * s
        pb = pages_per_seq(pre, DEFAULT_PAGE_SIZE)
        pb = 1 << max(0, (pb - 1).bit_length())
        return {"tokens": sds((b, s), jnp.int32),
                "prefix_tbl": sds((pb,), jnp.int32),
                "prefix_len": sds((), jnp.int32),
                "cache": paged_cache_shapes(cfg, b, 8 * s)}
    if shape.kind == "fused_step":
        from repro.models.paging import DEFAULT_PAGE_SIZE, pages_per_seq
        # rows resume anywhere inside an 8*s max_len (same sizing rule as
        # prefill_chunked: width-s chunks behind up to 7*s committed
        # tokens); the table row covers the full reservation
        pps = pages_per_seq(8 * s, DEFAULT_PAGE_SIZE)
        return {"tokens": sds((b, s), jnp.int32),
                "row_pos": sds((b,), jnp.int32),
                "row_len": sds((b,), jnp.int32),
                "page_tbl": sds((b, pps), jnp.int32),
                "cache": paged_cache_shapes(cfg, b, 8 * s)}
    if shape.kind == "spec_verify":
        from repro.models.paging import DEFAULT_PAGE_SIZE, pages_per_seq
        # verify resumes behind 3*s committed tokens (prompt + accepted
        # decode) — the 4*s max_len sizes the table rows/pools; batch is
        # 1 per slot (the engine verifies spec slots one at a time)
        pre = 3 * s
        pb = pages_per_seq(pre, DEFAULT_PAGE_SIZE)
        pb = 1 << max(0, (pb - 1).bit_length())
        return {"tokens": sds((1, s), jnp.int32),
                "prefix_tbl": sds((pb,), jnp.int32),
                "prefix_len": sds((), jnp.int32),
                "cache": paged_cache_shapes(cfg, 1, 4 * s)}
    raise ValueError(shape.kind)
