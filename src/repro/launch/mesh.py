"""Mesh builders. Functions, not module constants — importing this module
never touches jax device state (device count is locked at first use)."""
from __future__ import annotations

import jax

try:  # jax >= 0.5: explicit axis types on the mesh
    from jax.sharding import AxisType
except ImportError:  # older jax: meshes are implicitly Auto-typed
    AxisType = None


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16×16 = 256 chips (data, model). Multi-pod: 2 pods of 256
    with a leading "pod" axis (data-parallel across the DCN/ICI boundary).
    Requires 256/512 visible devices (real TPUs or
    --xla_force_host_platform_device_count, see dryrun.py)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist, as a 1-D data mesh (CPU tests/examples)."""
    n = len(jax.devices())
    return make_mesh((n,), ("data",))
