"""Training driver: sharded train step + fault-tolerant loop.

``make_train_step`` builds the jit'd (params, opt, batch, step) → (params,
opt, metrics) update with in/out shardings from distributed.sharding and
donated state buffers. ``train`` is the loop: auto-resume from the newest
checkpoint, periodic atomic saves, deterministic host-sharded data, and a
straggler hook (see data.ShardedLoader.reassign).
"""
from __future__ import annotations

import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.checkpoint import CheckpointManager
from repro.configs.base import ModelConfig
from repro.data import ShardedLoader
from repro.distributed.api import jit_shardings, mesh_axes
from repro.distributed.sharding import batch_specs, param_specs, zero1_specs
from repro.launch.specs import input_specs, param_shapes
from repro.models import init_params, loss_fn
from repro.optim import adamw_init, adamw_update, get_schedule


def make_train_step(cfg: ModelConfig, *, schedule: Callable,
                    zero1: bool = True, remat: bool = True,
                    weight_decay: float = 0.1, donate: bool = True):
    """jit'd sharded train step. Call under `use_mesh(mesh)`."""
    def step_fn(params, opt, batch, step):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch, remat=remat),
            has_aux=True)(params)
        params, opt, om = adamw_update(
            grads, opt, params, lr=schedule(step), zero1=zero1,
            weight_decay=weight_decay)
        metrics = dict(metrics, **om, lr=schedule(step))
        return params, opt, metrics

    meshed = bool(mesh_axes())
    shapes = param_shapes(cfg)
    pspecs = param_specs(shapes) if meshed else None
    if meshed:
        zspecs = (zero1_specs(shapes, pspecs) if zero1 else pspecs)
        ospecs = {"mu": zspecs, "nu": zspecs, "count": P()}
    else:
        ospecs = None

    def shardings_for(batch_shapes):
        if not meshed:
            return jax.jit(step_fn,  # nbl: disable=jit-discipline -- step_fn closes over this run's schedule/loss config; one wrapper per make_train_step
                           donate_argnums=(0, 1) if donate else ())
        bspecs = batch_specs(batch_shapes)
        return jax.jit(  # nbl: disable=jit-discipline -- sharded: shardings captured from the ambient mesh, per-run by design
            step_fn,
            in_shardings=jit_shardings((pspecs, ospecs, bspecs, P())),
            out_shardings=jit_shardings((pspecs, ospecs, None)),
            donate_argnums=(0, 1) if donate else ())
    return step_fn, shardings_for, pspecs, ospecs


def init_state(cfg: ModelConfig, seed: int = 0, *, zero1: bool = True,
               use_specs: bool = True):
    """Sharded init (params materialize directly into their shards)."""
    meshed = bool(mesh_axes())
    shapes = param_shapes(cfg)
    pspecs = param_specs(shapes) if (use_specs and meshed) else None
    zspecs = None
    if pspecs is not None:
        zspecs = zero1_specs(shapes, pspecs) if zero1 else pspecs

    @jax.jit  # nbl: disable=jit-discipline -- init runs once per state; closes over this call's sharding specs
    def _init(key):
        p = init_params(key, cfg)
        opt = adamw_init(p)
        if pspecs is not None:
            p = jax.tree.map(jax.lax.with_sharding_constraint, p, pspecs)
            opt = {"mu": jax.tree.map(jax.lax.with_sharding_constraint,
                                      opt["mu"], zspecs),
                   "nu": jax.tree.map(jax.lax.with_sharding_constraint,
                                      opt["nu"], zspecs),
                   "count": opt["count"]}
        return p, opt

    return _init(jax.random.PRNGKey(seed))


def train(cfg: ModelConfig, *, steps: int, global_batch: int, seq: int,
          peak_lr: float = 3e-3, warmup: int = 20,
          schedule_name: str = "cosine", ckpt_dir: Optional[str] = None,
          ckpt_every: int = 50, seed: int = 0, log_every: int = 10,
          loader: Optional[ShardedLoader] = None,
          log_fn: Callable[[str], None] = print) -> dict:
    """End-to-end loop (works un-meshed on CPU and under a production mesh)."""
    sched = get_schedule(schedule_name, peak_lr, warmup, steps)
    _, shardings_for, pspecs, ospecs = make_train_step(
        cfg, schedule=sched)
    params, opt = init_state(cfg, seed)
    loader = loader or ShardedLoader(cfg.vocab_size, global_batch, seq,
                                     seed=seed)

    start = 0
    mgr = None
    if ckpt_dir:
        mgr = CheckpointManager(ckpt_dir)
        latest = mgr.latest_step()
        if latest is not None:
            state = mgr.restore(latest, {"params": params, "opt": opt})
            params, opt = state["params"], state["opt"]
            start = latest
            log_fn(f"[train] resumed from step {start}")

    step_jit = None
    hist = []
    t0 = time.time()
    for i in range(start, steps):
        batch = loader.batch(i)
        if step_jit is None:
            step_jit = shardings_for(jax.eval_shape(lambda: jax.tree.map(
                lambda a: jnp.asarray(a), batch)))
        params, opt, m = step_jit(params, opt, batch, i)
        if i % log_every == 0 or i == steps - 1:
            loss = float(m["loss"])
            hist.append((i, loss))
            log_fn(f"[train] step {i:5d} loss {loss:.4f} "
                   f"lr {float(m['lr']):.2e} gn {float(m['grad_norm']):.2f}")
        if mgr and (i + 1) % ckpt_every == 0:
            mgr.save(i + 1, {"params": params, "opt": opt})
    if mgr:
        mgr.save(steps, {"params": params, "opt": opt})
    return {"params": params, "opt": opt, "history": hist,
            "wall_s": time.time() - t0}
