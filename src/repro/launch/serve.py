"""Serving driver: batched prefill + autoregressive decode.

NBL-linearized layers carry no KV cache, so a compressed model's serve
state is (K−m)/K of the baseline's — visible directly in the dry-run
memory analysis and in benchmarks/kv_cache.py (paper §4.2 / Table 21).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distributed.sharding import cache_specs, param_specs
from repro.launch.specs import cache_shapes, param_shapes
from repro.models import decode_step, prefill


def make_serve_fns(cfg: ModelConfig, *, batch: int, prompt_len: int,
                   max_new: int, donate: bool = True):
    """Returns (prefill_jit, decode_jit). Call under the serving mesh."""
    cache_len = prompt_len + max_new
    pspecs = param_specs(param_shapes(cfg))
    cspecs = cache_specs(cache_shapes(cfg, batch, cache_len))

    def _prefill(params, tokens, enc=None):
        return prefill(cfg, params, tokens, enc=enc, cache_len=cache_len)

    def _decode(params, token, cache, pos):
        return decode_step(cfg, params, token, cache, pos)

    enc_spec = (P("data", None, None),) if cfg.family == "vlm" else ()
    prefill_jit = jax.jit(
        _prefill,
        in_shardings=(pspecs, P("data", None)) + enc_spec,
        out_shardings=(None, cspecs))
    decode_jit = jax.jit(
        _decode,
        in_shardings=(pspecs, P("data", None), cspecs, P()),
        out_shardings=(None, cspecs),
        donate_argnums=(2,) if donate else ())
    return prefill_jit, decode_jit


def generate(cfg: ModelConfig, params, tokens, *, max_new: int,
             enc=None, greedy: bool = True, seed: int = 0,
             use_jit_fns: Optional[tuple] = None):
    """Batched generation. tokens: (B, S) int32 prompt. Returns (B, max_new)."""
    b, s = tokens.shape
    if use_jit_fns is not None:
        prefill_fn, decode_fn = use_jit_fns
    else:
        prefill_fn = jax.jit(lambda p, t, e=None: prefill(
            cfg, p, t, enc=e, cache_len=s + max_new))
        decode_fn = jax.jit(lambda p, t, c, i: decode_step(cfg, p, t, c, i))

    args = (params, tokens) + ((enc,) if enc is not None else ())
    logits, cache = prefill_fn(*args)
    key = jax.random.PRNGKey(seed)
    out = []
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    for i in range(max_new):
        out.append(tok)
        logits, cache = decode_fn(params, tok, cache, jnp.int32(s + i))
        if greedy:
            tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        else:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits[:, -1])[:, None]
            tok = tok.astype(jnp.int32)
    return jnp.concatenate(out, axis=1)
