"""Serving drivers — thin compatibility layer over the engine.

Production path: ``launch/engine.py`` (continuous batching over a slot
cache, scheduler-driven admission). This module keeps two entry points:

  serve_requests   convenience wrapper: prompts in, tokens out, running the
                   continuous-batching engine under the hood.
  generate         the original fixed-batch, fixed-length decode loop. Kept
                   as the *reference* implementation: the engine parity test
                   asserts per-request engine output == generate output.

NBL-linearized layers carry no KV cache, so a compressed model's serve
state is (K−m)/K of the baseline's — the engine's scheduler converts that
saving into extra concurrent slots (launch/scheduler.nbl_slot_budget).
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distributed.api import jit_shardings
from repro.distributed.sharding import cache_specs, param_specs
from repro.jitcache import shared_jit
from repro.launch.engine import Engine
from repro.launch.specs import cache_shapes, param_shapes
from repro.models import decode_step, prefill


def serve_requests(cfg: ModelConfig, params, prompts: Sequence, *,
                   max_new: int, max_len: Optional[int] = None,
                   n_slots: Optional[int] = None,
                   cache_budget_bytes: Optional[int] = None,
                   eos_id: Optional[int] = None,
                   temperature: float = 0.0, seed: int = 0):
    """Serve a batch of (possibly ragged) prompts through the engine.

    Returns (list of per-request token arrays in submission order, stats).
    """
    prompts = [jnp.asarray(p).reshape(-1) for p in prompts]
    if not prompts:
        raise ValueError("serve_requests needs at least one prompt")
    if max_len is None:
        max_len = max(int(p.shape[0]) for p in prompts) + max_new
    if n_slots is None and cache_budget_bytes is None:
        n_slots = min(len(prompts), 8)
    eng = Engine(cfg, params, max_len=max_len, n_slots=n_slots,
                 cache_budget_bytes=cache_budget_bytes, eos_id=eos_id,
                 temperature=temperature, seed=seed)
    # strict: this batch wrapper has no per-request error channel, so an
    # unservable prompt must raise rather than silently come back as an
    # empty token array (Engine.submit's default records-and-returns for
    # the async serving frontend)
    rids = [eng.submit(p, max_new, strict=True) for p in prompts]
    out = eng.run()
    return [out[r] for r in rids], eng.stats()


def make_serve_fns(cfg: ModelConfig, *, batch: int, prompt_len: int,
                   max_new: int, donate: bool = True):
    """Returns (prefill_jit, decode_jit) for the fixed-batch path. Call
    under the serving mesh. (The engine builds its own sharded fns.)"""
    cache_len = prompt_len + max_new
    pspecs = param_specs(param_shapes(cfg))
    cspecs = cache_specs(cache_shapes(cfg, batch, cache_len))

    def _prefill(params, tokens, enc=None):
        return prefill(cfg, params, tokens, enc=enc, cache_len=cache_len)

    def _decode(params, token, cache, pos):
        return decode_step(cfg, params, token, cache, pos)

    enc_spec = (P("data", None, None),) if cfg.family == "vlm" else ()
    prefill_jit = jax.jit(  # nbl: disable=jit-discipline -- sharded: shardings captured from the caller's mesh, per-mesh by design
        _prefill,
        in_shardings=jit_shardings((pspecs, P("data", None)) + enc_spec),
        out_shardings=jit_shardings((None, cspecs)))
    decode_jit = jax.jit(  # nbl: disable=jit-discipline -- sharded: shardings captured from the caller's mesh, per-mesh by design
        _decode,
        in_shardings=jit_shardings((pspecs, P("data", None), cspecs, P())),
        out_shardings=jit_shardings((None, cspecs)),
        donate_argnums=(2,) if donate else ())
    return prefill_jit, decode_jit


def generate(cfg: ModelConfig, params, tokens, *, max_new: int,
             enc=None, greedy: bool = True, seed: int = 0,
             use_jit_fns: Optional[tuple] = None):
    """Fixed-batch generation (reference loop; all sequences share one
    position). tokens: (B, S) int32 prompt. Returns (B, max_new)."""
    b, s = tokens.shape
    if use_jit_fns is not None:
        prefill_fn, decode_fn = use_jit_fns
    else:
        # shared across calls: generate() is the parity REFERENCE the tests
        # and the fuzz harness call by the hundred — fresh per-call lambdas
        # here used to retrace the whole model every single time
        cache_len = s + max_new
        prefill_fn = shared_jit(
            ("serve.generate_prefill", cfg, cache_len),
            lambda: jax.jit(lambda p, t, e=None: prefill(
                cfg, p, t, enc=e, cache_len=cache_len)))
        decode_fn = shared_jit(
            ("serve.generate_decode", cfg),
            lambda: jax.jit(lambda p, t, c, i: decode_step(cfg, p, t, c, i)))

    args = (params, tokens) + ((enc,) if enc is not None else ())
    logits, cache = prefill_fn(*args)
    key = jax.random.PRNGKey(seed)
    out = []
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    for i in range(max_new):
        out.append(tok)
        logits, cache = decode_fn(params, tok, cache, jnp.int32(s + i))
        if greedy:
            tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        else:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits[:, -1])[:, None]
            tok = tok.astype(jnp.int32)
    return jnp.concatenate(out, axis=1)
