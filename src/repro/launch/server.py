"""Newline-JSON TCP serving frontend over the AsyncEngine host loop.

Run (port 0 picks a free port; the bound port is printed)::

    PYTHONPATH=src python -m repro.launch.server --port 0 \\
        --config tiny-dense --paged --page-size 4 --max-len 48 --n-slots 2

The server prints exactly one ``LISTENING <port>`` line to stdout once it
is accepting connections, then serves until SIGINT/SIGTERM or a client
``shutdown`` op. Params are ``init_params(PRNGKey(--seed), cfg)``, so a
client holding the same (config, seed) pair can recompute ``generate()``
references for token-exact parity checks (the CI smoke does).

PROTOCOL — one UTF-8 JSON object per ``\\n``-terminated line, both ways.
Multiple requests may be in flight per connection; every server event
carries the ``rid`` it belongs to, so streams interleave safely.

client -> server ops::

    {"op": "submit", "prompt": [int, ...], "max_new": int,
     "stream": bool (default true), "tag": any (echoed back),
     "spec_gamma": int (default 0), "draft_m": int | null}
        spec_gamma > 0 opts the request into speculative decoding —
        served only when the server registered a drafter (--draft-m);
        draft_m picks the registered linearization depth. An unservable
        spec submission (no drafter, temperature > 0, span past max_len)
        is rejected-with-error like any other bad submit.
    {"op": "cancel", "rid": int}     cancel in ANY lifecycle state; scoped
                                     to rids submitted on THIS connection
    {"op": "stats"}                  engine stats() + allocator occupancy
    {"op": "metrics"}                live observability scrape (see below)
    {"op": "ping"}
    {"op": "shutdown"}               drain the engine and stop the server

server -> client events::

    {"event": "submitted", "rid": int, "tag": ...}
    {"event": "token", "rid": int, "index": int, "token": int}
        (only when "stream" was true; index is the position in the
         generated sequence — contiguous from 0, preemption-safe)
    {"event": "done", "rid": int, "status": "finished" | "cancelled" |
     "rejected" | "aborted", "tokens": [int, ...], "error": str | null}
        ("tokens" is the full generation — partial if cancelled; a
         rejected submission goes straight to "done" with "error" set:
         rejection is an event, never a dropped connection)
    {"event": "cancelling", "rid": int}     cancel op acknowledged
    {"event": "stats", "stats": {...}}
    {"event": "metrics", "enabled": bool, "metrics": {...},
     "prometheus": str}
        Consistent point-in-time scrape of the engine's observability
        registry (repro.obs): "metrics" is the JSON snapshot — {"labels"
        (engine_mode / nbl_m), "counters", "gauges", "histograms"
        (cumulative [upper_bound, count] pairs), "last_step" (the newest
        step-timeline record)} — and "prometheus" is the SAME scrape in
        Prometheus text exposition format (# HELP / # TYPE / series
        lines), ready to proxy to any Prometheus scraper. Observability
        is ON by default (--no-obs disables it; the scrape then returns
        {"enabled": false} only). --trace-out FILE additionally exports
        the per-request Chrome-trace/Perfetto timeline at shutdown.
    {"event": "pong"} / {"event": "bye"}
    {"event": "error", "error": str}        malformed line; connection
                                            stays up

Disconnect semantics: when a connection drops, every request it submitted
that is not yet terminal is CANCELLED — its pages and shared-prefix pins
are unref'd by the engine's cancel path, so a vanishing client can never
leak pool pages (the lifecycle bug this frontend exists to force out).
"""
from __future__ import annotations

import argparse
import json
import signal
import socket
import sys
import threading
from typing import Optional

import numpy as np

from repro.launch.engine import AsyncEngine, Engine, Stream


def _jsonable(d: dict) -> dict:
    return {k: (v.item() if hasattr(v, "item") else v) for k, v in d.items()}


class NBLServer:
    """Threaded newline-JSON TCP frontend: one handler thread per
    connection, one pump thread per submitted stream (writes are serialized
    per connection). All engine interaction goes through the AsyncEngine's
    thread-safe surface."""

    def __init__(self, aeng: AsyncEngine, host: str = "127.0.0.1",
                 port: int = 0, backlog: int = 16):
        self.aeng = aeng
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, port))
        self._sock.listen(backlog)
        self.host, self.port = self._sock.getsockname()[:2]
        self._stop = threading.Event()
        self._down = False                   # guarded-by: _down_lock
        self._down_lock = threading.Lock()

    def request_stop(self) -> None:
        """Signal-safe stop request: flips the stop flag and closes the
        listening socket WITHOUT taking the shutdown lock — a signal
        handler runs re-entrantly on the main thread's stack, where
        acquiring the non-reentrant lock the interrupted frame may already
        hold would self-deadlock. serve_forever() notices within its
        accept timeout and the caller's normal shutdown() path finishes
        the job."""
        self._stop.set()
        try:
            self._sock.close()
        except OSError:
            pass

    def serve_forever(self) -> None:
        """Accept loop; returns after ``shutdown()`` (any thread). The
        accept blocks with a timeout: closing a listening socket from
        another thread does not wake a blocked accept() on Linux, so the
        loop polls the stop flag instead."""
        self._sock.settimeout(0.2)
        while not self._stop.is_set():
            try:
                conn, _ = self._sock.accept()
            except TimeoutError:             # poll the stop flag
                continue
            except OSError:
                break                        # listening socket closed
            conn.settimeout(None)            # accept() timeout not inherited
            threading.Thread(target=self._serve_conn, args=(conn,),
                             daemon=True).start()

    def shutdown(self, *, drain: bool = True) -> None:
        """Stop accepting, then stop the engine host loop (``drain`` as in
        ``AsyncEngine.shutdown``). SERIALIZED: a concurrent caller blocks
        until the first shutdown completes instead of re-entering with its
        own drain flag — otherwise main()'s abort-on-exit would downgrade
        a client-requested drain mid-flight, cancelling work the protocol
        promised to finish. Idempotent ONLY once the engine stopped
        cleanly: if its step loop died, every call re-raises — so a
        client-triggered shutdown raising in a handler thread does not
        eat the failure; ``main()``'s own shutdown call sees it again and
        exits nonzero."""
        with self._down_lock:
            if self._down:
                return
            self._stop.set()
            try:
                self._sock.close()
            except OSError:
                pass
            self.aeng.shutdown(drain=drain)  # may raise: _down stays False
            self._down = True

    # ------------------------------------------------------ per-connection

    def _serve_conn(self, conn: socket.socket) -> None:
        wlock = threading.Lock()

        def send(obj: dict) -> None:
            data = (json.dumps(obj) + "\n").encode()
            with wlock:
                try:
                    conn.sendall(data)
                except OSError:
                    pass                     # client gone; cleanup below

        owned: list[Stream] = []             # this connection's submissions
        try:
            reader = conn.makefile("r", encoding="utf-8")
            for line in reader:
                line = line.strip()
                if not line:
                    continue
                try:
                    msg = json.loads(line)
                    op = msg["op"]
                except Exception as e:       # malformed line, not fatal
                    send({"event": "error", "error": f"bad request: {e}"})
                    continue
                if op == "submit":
                    self._op_submit(msg, send, owned)
                elif op == "cancel":
                    try:
                        rid = int(msg["rid"])
                    except Exception as e:
                        send({"event": "error",
                              "error": f"bad cancel: {e}"})
                        continue
                    if rid not in {s.rid for s in owned}:
                        # scoped to the submitting connection: rids are
                        # guessable sequential ints, and nothing should
                        # let one client cancel another's request
                        send({"event": "error",
                              "error": f"unknown rid {rid} (cancel is "
                                       f"per-connection)"})
                        continue
                    self.aeng.cancel(rid)
                    send({"event": "cancelling", "rid": rid})
                elif op == "stats":
                    send({"event": "stats",
                          "stats": _jsonable(self.aeng.stats())})
                elif op == "metrics":
                    obs = self.aeng.engine.obs
                    if obs is None:
                        send({"event": "metrics", "enabled": False})
                    else:
                        send({"event": "metrics", "enabled": True,
                              "metrics": obs.snapshot(),
                              "prometheus": obs.render_prometheus()})
                elif op == "ping":
                    send({"event": "pong"})
                elif op == "shutdown":
                    send({"event": "bye"})
                    self.shutdown(drain=True)
                    break
                else:
                    send({"event": "error", "error": f"unknown op {op!r}"})
        finally:
            # disconnect cancels everything this connection still has in
            # flight — pages/pins unref'd, nothing leaks
            for s in owned:
                if not s.done:
                    self.aeng.cancel(s.rid)
            try:
                conn.close()
            except OSError:
                pass

    def _op_submit(self, msg: dict, send, owned: list) -> None:
        try:
            prompt = np.asarray(msg["prompt"], np.int32).reshape(-1)
            max_new = int(msg["max_new"])
            spec_gamma = int(msg.get("spec_gamma", 0))
            draft_m = msg.get("draft_m")
            draft_m = int(draft_m) if draft_m is not None else None
        except Exception as e:
            send({"event": "error", "error": f"bad submit: {e}"})
            return
        want_stream = bool(msg.get("stream", True))
        # prune terminal streams first: a long-lived connection otherwise
        # grows `owned` (each entry holding its full token list) without
        # bound — the disconnect-cancel and cancel-scoping scans only need
        # the live ones, plus whatever finished since the last submit
        owned[:] = [t for t in owned if not t.done]
        try:
            s = self.aeng.submit_stream(prompt, max_new,
                                        spec_gamma=spec_gamma,
                                        draft_m=draft_m)
        except RuntimeError as e:
            # engine shut down / step loop died: still an EVENT (the
            # docstring's promise), never a dropped connection
            send({"event": "error", "error": f"submit failed: {e}"})
            return
        owned.append(s)
        send({"event": "submitted", "rid": s.rid, "tag": msg.get("tag")})

        def pump() -> None:
            for i, tok in enumerate(s):
                if want_stream:
                    send({"event": "token", "rid": s.rid, "index": i,
                          "token": tok})
            send({"event": "done", "rid": s.rid, "status": s.status,
                  "tokens": [int(t) for t in s.tokens], "error": s.error})

        threading.Thread(target=pump, daemon=True).start()


def _build_engine(args) -> Engine:
    import jax

    from repro.configs import get_config
    from repro.models import init_params

    cfg = get_config(args.config)
    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    kw: dict = {}
    if args.paged or args.prefix_sharing or args.chunked_prefill:
        kw.update(paged=True, page_size=args.page_size)
    if args.prefix_sharing:
        kw.update(prefix_sharing=True,
                  shared_prefix_len=args.shared_prefix_len)
    if args.chunked_prefill:
        kw.update(chunked_prefill=True)
        if args.prefill_chunk_tokens is not None:
            kw.update(prefill_chunk_tokens=args.prefill_chunk_tokens)
    if args.draft_m is not None:
        # zero-map NBL drafter: structurally complete (and exactness holds
        # regardless of draft quality), so the server needs no calibration
        # pass — a calibrated registry is a deployment concern
        from repro.launch.speculative import make_nbl_draft
        kw.update(paged=True, page_size=args.page_size,
                  drafts={args.draft_m:
                          make_nbl_draft(cfg, params, args.draft_m)})
    if args.expected_len is not None:
        kw.update(expected_len=args.expected_len)
    if not args.no_obs:
        from repro.obs import Observability
        kw.update(obs=Observability(
            trace_annotations=args.trace_annotations))
    n_slots = args.n_slots
    budget = (int(args.cache_budget_mb * 2**20)
              if args.cache_budget_mb is not None else None)
    if n_slots is None and budget is None:
        n_slots = 4
    return Engine(cfg, params, max_len=args.max_len, n_slots=n_slots,
                  cache_budget_bytes=budget, eos_id=args.eos_id,
                  temperature=args.temperature, seed=args.seed, **kw)


def main(argv: Optional[list] = None) -> int:
    ap = argparse.ArgumentParser(
        description="newline-JSON TCP serving frontend (see module "
                    "docstring for the protocol)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="0 = pick a free port (printed as LISTENING <p>)")
    ap.add_argument("--config", default="tiny-dense")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--n-slots", type=int, default=None)
    ap.add_argument("--cache-budget-mb", type=float, default=None,
                    help="NBL-aware HBM budget instead of --n-slots")
    ap.add_argument("--expected-len", type=int, default=None)
    ap.add_argument("--eos-id", type=int, default=None)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--paged", action="store_true")
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--prefix-sharing", action="store_true")
    ap.add_argument("--shared-prefix-len", type=int, default=0)
    ap.add_argument("--chunked-prefill", action="store_true")
    ap.add_argument("--prefill-chunk-tokens", type=int, default=None)
    ap.add_argument("--draft-m", type=int, default=None,
                    help="register an m-deepest-layers NBL self-drafter "
                         "(zero maps) so clients may submit with "
                         "spec_gamma > 0; implies --paged")
    ap.add_argument("--max-pending", type=int, default=64)
    ap.add_argument("--step-delay-s", type=float, default=0.0,
                    help="sleep after every engine step (testing knob: "
                         "widens the window for mid-stream cancellation "
                         "so smoke tests cannot race completion)")
    ap.add_argument("--no-retain-results", action="store_true",
                    help="drop each finished request from engine memory "
                         "once its stream has delivered it (long-running "
                         "deployments; the stats-window percentile path "
                         "keeps percentiles meaningful regardless)")
    ap.add_argument("--no-obs", action="store_true",
                    help="disable the observability layer (metrics op "
                         "then returns enabled=false); default on — "
                         "host-side only, no extra device dispatches")
    ap.add_argument("--trace-annotations", action="store_true",
                    help="wrap prefill/decode jit calls in jax.profiler."
                         "TraceAnnotation (lines device profiles up with "
                         "the host trace; needs obs enabled)")
    ap.add_argument("--trace-out", default=None, metavar="FILE",
                    help="export the request/step trace as a Chrome-trace/"
                         "Perfetto JSON file at shutdown (needs obs "
                         "enabled; open at https://ui.perfetto.dev)")
    args = ap.parse_args(argv)

    eng = _build_engine(args)
    step_cb = None
    if args.step_delay_s > 0:
        import time as _time
        step_cb = lambda _eng: _time.sleep(args.step_delay_s)  # noqa: E731
    aeng = AsyncEngine(eng, max_pending=args.max_pending,
                       retain_results=not args.no_retain_results,
                       step_cb=step_cb)
    srv = NBLServer(aeng, host=args.host, port=args.port)
    signal.signal(signal.SIGTERM, lambda *_: srv.request_stop())
    print(f"LISTENING {srv.port}", flush=True)
    try:
        srv.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        try:
            srv.shutdown(drain=False)
        except RuntimeError as e:            # step loop died: report it
            print(f"server error: {e}", file=sys.stderr)
            return 1
        if args.trace_out and eng.obs is not None \
                and eng.obs.tracer is not None:
            n = eng.obs.tracer.export_chrome_trace(args.trace_out)
            print(f"trace: {n} events -> {args.trace_out}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
