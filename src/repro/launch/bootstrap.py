"""Multi-host bootstrap for real pods (the non-dry-run path).

On a TPU pod slice each host runs this module; JAX's distributed runtime
wires the hosts into one device fabric and the SAME pjit/shard_map code
from the dry-run executes unchanged (the dry-run's 512 host-platform
devices stand in for exactly this topology).

    # per host (or via the scheduler's env):
    COORDINATOR=10.0.0.1:8476 NPROC=64 PID=$SLURM_PROCID \
        python -m repro.launch.bootstrap --arch gemma2-2b --steps 1000

Fault tolerance at this layer:
  - checkpoint auto-resume (launch.train) makes SIGTERM/preemption safe,
  - a restarted job with a different host count re-partitions the data
    stream deterministically (data.ShardedLoader) and re-shards the
    checkpoint onto the new mesh (checkpoint.manager restore shardings),
  - straggler mitigation: the scheduler can re-assign a dead host's data
    shard via ShardedLoader.reassign before restart.
"""
from __future__ import annotations

import argparse
import os


def initialize_from_env() -> tuple[int, int]:
    """jax.distributed.initialize from COORDINATOR/NPROC/PID env vars.
    No-op for single-process runs. Returns (process_id, n_processes)."""
    import jax
    coord = os.environ.get("COORDINATOR")
    nproc = int(os.environ.get("NPROC", "1"))
    pid = int(os.environ.get("PID", "0"))
    if coord and nproc > 1:
        jax.distributed.initialize(coordinator_address=coord,
                                   num_processes=nproc, process_id=pid)
    return pid, nproc


def main() -> None:
    pid, nproc = initialize_from_env()

    import jax
    from repro.configs import get_config
    from repro.distributed.api import use_mesh
    from repro.data import ShardedLoader
    from repro.launch.mesh import make_production_mesh
    from repro.launch.train import train

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=1000)
    ap.add_argument("--global-batch", type=int, default=256)
    ap.add_argument("--seq", type=int, default=4096)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--schedule", default="cosine")
    ap.add_argument("--ckpt", default="ckpts")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    loader = ShardedLoader(cfg.vocab_size, args.global_batch, args.seq,
                           host_index=pid, n_hosts=nproc)
    if pid == 0:
        print(f"[bootstrap] {args.arch} on {mesh.shape} "
              f"({len(jax.devices())} devices, {nproc} hosts)")
    with use_mesh(mesh):
        train(cfg, steps=args.steps, global_batch=args.global_batch,
              seq=args.seq, peak_lr=args.lr, schedule_name=args.schedule,
              ckpt_dir=args.ckpt, loader=loader,
              log_fn=(print if pid == 0 else lambda s: None))


if __name__ == "__main__":
    main()
