import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell on
512 placeholder host devices, record memory/cost/collective analysis.

    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-2b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
        --out experiments/dryrun

Each cell produces a JSON blob with: compile ok/fail, per-device bytes from
compiled.memory_analysis(), FLOPs/bytes from cost_analysis(), the parsed
collective schedule, and the three roofline terms (§Roofline). ``--nbl m``
dry-runs the NBL-compressed variant (layers chosen deepest-first, the
paper's observed selection pattern) — the KV-cache saving shows up directly
in argument bytes.
"""  # noqa: E402

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import numpy as np   # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.configs import (  # noqa: E402
    ASSIGNED_ARCHS, SHAPES, get_config, shape_applicable,
)
from repro.core.surgery import nbl_variant  # noqa: E402
from repro.distributed.api import (  # noqa: E402
    jit_shardings, shaped_spec, use_mesh,
)
from repro.distributed.sharding import (  # noqa: E402
    batch_specs, cache_specs, param_specs,
)
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.specs import input_specs, param_shapes  # noqa: E402
from repro.models import decode_step, fused_step, loss_fn, prefill  # noqa: E402
from repro.optim import adamw_init, adamw_update, get_schedule  # noqa: E402
from repro.roofline.analysis import summarize  # noqa: E402


def build_target(cfg, shape):
    """Returns (fn, args_shapes, in_shardings, n_tokens, backward)."""
    ins = input_specs(cfg, shape)
    pshapes = param_shapes(cfg)
    pspecs = param_specs(pshapes)
    sched = get_schedule("cosine", 3e-4, 100, 10_000)

    if shape.kind == "train":
        oshapes = jax.eval_shape(lambda: adamw_init(pshapes))
        ospecs = {"mu": pspecs, "nu": pspecs, "count": P()}

        def train_step(params, opt, batch, step):
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: loss_fn(cfg, p, batch, remat=True),
                has_aux=True)(params)
            params, opt, om = adamw_update(grads, opt, params,
                                           lr=sched(step))
            return params, opt, dict(metrics, **om)

        args = (pshapes, oshapes, ins["batch"],
                jax.ShapeDtypeStruct((), np.int32))
        shardings = (pspecs, ospecs, batch_specs(ins["batch"]), P())
        ntok = shape.global_batch * shape.seq_len
        return train_step, args, shardings, ntok, True

    if shape.kind == "prefill":
        def prefill_step(params, tokens, enc=None):
            return prefill(cfg, params, tokens, enc=enc,
                           cache_len=shape.seq_len)
        args = (pshapes, ins["tokens"])
        shardings = (pspecs, shaped_spec(ins["tokens"].shape, "dp", None))
        if "enc" in ins:
            args += (ins["enc"],)
            shardings += (shaped_spec(ins["enc"].shape, "dp", None, None),)
        ntok = shape.global_batch * shape.seq_len
        return prefill_step, args, shardings, ntok, False

    if shape.kind in ("prefill_shared", "prefill_chunked", "spec_verify"):
        # partial prefill: suffix/chunk tokens at absolute positions past
        # pooled prefix pages — a shared prompt prefix (engine _admit), the
        # request's own earlier chunks (engine _chunk_step), or the
        # speculative verifier's candidate block (engine _run_spec_verify,
        # which additionally reads the last γ+1 logits rows); the jit is
        # identical, only the prefix table's provenance differs
        n_logits = 9 if shape.kind == "spec_verify" else 1   # γ=8 verify

        def shared_prefill_step(params, tokens, cache, ptbl, plen):
            return prefill(cfg, params, tokens, cache_len=shape.seq_len,
                           paged=True, prefix_cache=cache, prefix_tbl=ptbl,
                           prefix_len=plen, n_logits=n_logits)
        args = (pshapes, ins["tokens"], ins["cache"], ins["prefix_tbl"],
                ins["prefix_len"])
        shardings = (pspecs, shaped_spec(ins["tokens"].shape, "dp", None),
                     cache_specs(ins["cache"]), P(), P())
        ntok = shape.global_batch * shape.seq_len
        return shared_prefill_step, args, shardings, ntok, False

    if shape.kind == "fused_step":
        # the fused engine dispatch: mixed decode + chunk rows through one
        # jit (engine _execute_fused) — row_len masks each row's valid span
        def fused(params, tokens, cache, row_pos, row_len, tbl):
            return fused_step(cfg, params, tokens, cache, row_pos,
                              row_len, tbl)
        args = (pshapes, ins["tokens"], ins["cache"], ins["row_pos"],
                ins["row_len"], ins["page_tbl"])
        shardings = (pspecs, shaped_spec(ins["tokens"].shape, "dp", None),
                     cache_specs(ins["cache"]),
                     shaped_spec(ins["row_pos"].shape, "dp"),
                     shaped_spec(ins["row_len"].shape, "dp"),
                     shaped_spec(ins["page_tbl"].shape, "dp", None))
        ntok = shape.global_batch * shape.seq_len
        return fused, args, shardings, ntok, False

    # decode/serve: one new token per sequence against a seq_len KV cache.
    # "serve" is the engine's batched slot-decode: pos is a per-slot (B,)
    # vector sharded with the slot dim; "decode" keeps the scalar pos;
    # "serve_paged" decodes against page pools via a per-slot page table.
    cspecs = cache_specs(ins["cache"])
    pos_spec = shaped_spec(ins["pos"].shape, "dp") if ins["pos"].ndim else P()
    if shape.kind == "serve_paged":
        def paged_step(params, token, cache, pos, tbl):
            return decode_step(cfg, params, token, cache, pos, page_tbl=tbl)
        args = (pshapes, ins["token"], ins["cache"], ins["pos"],
                ins["page_tbl"])
        shardings = (pspecs, shaped_spec(ins["token"].shape, "dp", None),
                     cspecs, pos_spec,
                     shaped_spec(ins["page_tbl"].shape, "dp", None))
        return paged_step, args, shardings, shape.global_batch, False

    def serve_step(params, token, cache, pos):
        return decode_step(cfg, params, token, cache, pos)
    args = (pshapes, ins["token"], ins["cache"], ins["pos"])
    shardings = (pspecs, shaped_spec(ins["token"].shape, "dp", None),
                 cspecs, pos_spec)
    return serve_step, args, shardings, shape.global_batch, False


def run_cell(arch: str, shape_name: str, multi_pod: bool, nbl_m: int = 0,
             donate: bool = True) -> dict:
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16", "nbl_m": nbl_m}
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        rec.update(status="skipped", reason=reason)
        return rec
    cfg = nbl_variant(cfg, nbl_m)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(tuple(mesh.shape.values())))
    t0 = time.time()
    try:
        with use_mesh(mesh):
            fn, args, shardings, ntok, backward = build_target(cfg, shape)
            donate_args = ()
            if donate and shape.kind == "train":
                donate_args = (0, 1)
            elif donate and shape.kind in ("decode", "serve", "serve_paged",
                                           "fused_step"):
                donate_args = (2,)
            lowered = jax.jit(fn, in_shardings=jit_shardings(shardings),  # nbl: disable=jit-discipline -- AOT lower/compile cell: the jit exists to be lowered once and measured, never reused
                              donate_argnums=donate_args).lower(*args)
            compiled = lowered.compile()
            try:
                mem = compiled.memory_analysis()
                rec["memory"] = {
                    k: int(getattr(mem, k))
                    for k in ("argument_size_in_bytes",
                              "output_size_in_bytes",
                              "temp_size_in_bytes",
                              "generated_code_size_in_bytes")
                    if hasattr(mem, k)}
            except Exception as e:      # CPU backend may not support it
                rec["memory"] = {"error": str(e)}
            cost = compiled.cost_analysis() or {}
            hlo = compiled.as_text()
            rec["roofline"] = summarize(
                hlo, chips, cfg=cfg, n_tokens=ntok, backward=backward,
                xla_cost=cost)
            rec["status"] = "ok"
    except Exception as e:
        rec["status"] = "fail"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["compile_s"] = round(time.time() - t0, 1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--nbl", type=int, default=0)
    ap.add_argument("--out", default=None)
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()

    archs = list(ASSIGNED_ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    results = []
    for arch in archs:
        for shp in shapes:
            for mp in meshes:
                rec = run_cell(arch, shp, mp, args.nbl)
                results.append(rec)
                tag = f"{arch:22s} {shp:12s} {rec['mesh']:8s}"
                if rec["status"] == "ok":
                    r = rec["roofline"]
                    print(f"{tag} OK  t_c={r['t_compute']:.3e}s "
                          f"t_m={r['t_memory']:.3e}s "
                          f"t_x={r['t_collective']:.3e}s "
                          f"dom={r['dominant']} "
                          f"({rec['compile_s']}s compile)", flush=True)
                elif rec["status"] == "skipped":
                    print(f"{tag} SKIP ({rec['reason'][:60]})", flush=True)
                else:
                    print(f"{tag} FAIL {rec['error'][:120]}", flush=True)
    if args.out:
        os.makedirs(args.out, exist_ok=True)
        suffix = f"_nbl{args.nbl}" if args.nbl else ""
        path = os.path.join(
            args.out, f"dryrun_{args.arch}_{args.shape}_{args.mesh}{suffix}"
            .replace("/", "-") + ".json")
        with open(path, "w") as f:
            json.dump(results, f, indent=1)
        print("wrote", path)
    n_fail = sum(r["status"] == "fail" for r in results)
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
