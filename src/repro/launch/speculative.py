"""Speculative decoding (draft-and-verify) — the paper's §E.2/Table 6
compounding-speed-up experiment, in two tiers:

ENGINE-NATIVE policy (the production path, launch/engine.py spec mode)
    NBL hands the serving engine a free self-drafter: the SAME weights
    under a more aggressive linearization plan (``make_nbl_draft`` — the
    m deepest attention layers replaced by their LMMSE linear maps) are a
    cheap approximation of the full model. Because ``nbl_variant``
    linearizes the DEEPEST layers, every attention layer the draft still
    carries is one of the target's SHALLOW layers — so the draft can
    attend the target's own paged KV through the slot's page table
    (``build_draft_cache_view``) and needs no cache of its own.
    ``draft_burst`` proposes γ greedy tokens per slot in one scanned jit;
    the engine then verifies the whole candidate block with a single
    cache-extend partial prefill (γ+1 logits rows), accepts the longest
    agreeing prefix plus one corrected token, and rolls back by a pure
    length decrement (pages are position-aligned: no kpos to repair —
    see docs/speculative.md for the rollback invariant).

STANDALONE reference (``speculative_generate``)
    The seed-era off-engine loop, kept as the parity oracle the engine
    path and the paper-table experiments are checked against.
    Verification re-runs a full forward over the prefix (O(n²) total —
    fine for CPU-scale tests and for counting verifier calls). Fixed
    relative to the seed: ``eos_id`` stops a row at end-of-sequence
    (parity with ``generate(eos_id=...)``), acceptance is PER-ROW (one
    disagreeing row no longer caps the whole batch at the batch-min
    prefix), and stats count post-truncation — tokens beyond ``max_new``
    or EOS never inflate ``acceptance_rate``.

Greedy speculative decoding is EXACT: the emitted sequence equals the
verifier's own greedy decode (asserted in tests and in-benchmark),
regardless of draft quality — draft quality only moves the acceptance
rate, i.e. the speed.
"""
from __future__ import annotations

from typing import Mapping, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.surgery import compress_params, nbl_variant
from repro.models import apply, decode_step

_DRAFT_KINDS = ("attn", "nbl", "drop", "nbl_block", "drop_block")


# --------------------------------------------------------------------------
# Engine-native drafter plumbing
# --------------------------------------------------------------------------

def attn_sites(cfg: ModelConfig) -> list[tuple[int, int, int]]:
    """(group, unit, repeat) coordinates of every caching attention
    invocation, in flat stack order — the ordinal axis the draft/target
    KV-sharing map is built on (shared blocks count once per invocation,
    exactly like their page pools in models/paging.init_paged_cache)."""
    sites = []
    for gi, g in enumerate(cfg.stack):
        for r in range(g.repeat):
            for u, blk in enumerate(g.unit):
                if blk.kind == "attn":
                    sites.append((gi, u, r))
    return sites


def validate_draft(cfg: ModelConfig, dcfg: ModelConfig) -> None:
    """Structural gate for KV-sharing self-speculation: the draft must be
    a pure linearization of the target — same embedding/head geometry,
    same KV layout, and its surviving attention layers must be a PREFIX of
    the target's attention ordinals (window-for-window), because the
    draft attends the target's pages through the shared table and ordinal
    j of the draft reads ordinal j of the target. ``nbl_variant`` drafts
    satisfy this by construction (it linearizes the deepest layers);
    anything else raises here, at registration, not mid-serve."""
    for attr in ("d_model", "vocab_size", "n_kv_heads", "head_dim",
                 "compute_dtype"):
        if getattr(cfg, attr) != getattr(dcfg, attr):
            raise ValueError(f"draft/target {attr} mismatch: "
                             f"{getattr(dcfg, attr)} vs {getattr(cfg, attr)}")
    bad = [b.kind for b in dcfg.blocks() if b.kind not in _DRAFT_KINDS]
    if bad:
        raise ValueError(f"draft stack carries non-linearizable blocks "
                         f"{sorted(set(bad))} — KV sharing needs a pure "
                         f"attn/nbl/drop plan")
    tw = [b.window for b in cfg.blocks() if b.kind == "attn"]
    dw = [b.window for b in dcfg.blocks() if b.kind == "attn"]
    if len(dw) > len(tw):
        raise ValueError(f"draft has {len(dw)} attention layers, target "
                         f"only {len(tw)} — the draft cannot be deeper")
    if dw != tw[:len(dw)]:
        raise ValueError(f"draft attention windows {dw} are not a prefix "
                         f"of the target's {tw} — ordinal j of the draft "
                         f"must read the KV ordinal j of the target wrote")


def build_draft_cache_view(cfg: ModelConfig, dcfg: ModelConfig, cache):
    """Draft-shaped cache tree over the TARGET's page pools: attention
    ordinal j of the draft maps to the pools of attention ordinal j of the
    target (validate_draft guarantees the prefix property), restacked to
    the draft's scan grouping. Built at trace time inside the burst jit —
    the gather materializes per-ordinal pool copies whose in-burst KV
    writes are carried across the γ scan steps (a draft token must attend
    the burst's earlier draft tokens) and DISCARDED at burst end, so the
    target's committed pools are never mutated by drafting. Non-attention
    draft blocks (nbl/drop) carry no cache: None leaves, matching
    init_paged_cache."""
    tsites = attn_sites(cfg)
    by_leaf: dict = {}
    for j, (gi, u, r) in enumerate(attn_sites(dcfg)):
        by_leaf.setdefault((gi, u), {})[r] = j
    groups = []
    for gi, g in enumerate(dcfg.stack):
        blocks = []
        for u, blk in enumerate(g.unit):
            if blk.kind == "attn":
                ks, vs = [], []
                for r in range(g.repeat):
                    tgi, tu, tr = tsites[by_leaf[(gi, u)][r]]
                    leaf = cache["groups"][tgi]["blocks"][tu]
                    ks.append(leaf["k_pages"][tr])
                    vs.append(leaf["v_pages"][tr])
                blocks.append({"k_pages": jnp.stack(ks),
                               "v_pages": jnp.stack(vs)})
            else:
                blocks.append(None)
        groups.append({"blocks": blocks})
    return {"groups": groups}


def draft_burst(dcfg: ModelConfig, dparams, view, token, pos, page_tbl,
                gamma: int):
    """Propose ``gamma`` greedy draft tokens autoregressively from one
    scanned jit body. ``token`` (B,1) int32 is the slot's last emitted
    (uncached) token; ``pos`` (B,) its position; ``page_tbl`` (B, pps) the
    slot's table row; ``view`` a build_draft_cache_view tree. The view
    rides the scan CARRY so draft token i+1 attends draft token i's KV;
    its writes die with the trace. Returns (B, gamma) int32 proposals."""
    def body(carry, _):
        tok, p, vw = carry
        logits, vw = decode_step(dcfg, dparams, tok, vw, p, page_tbl=page_tbl)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return (nxt[:, None], p + 1, vw), nxt

    (_, _, _), toks = jax.lax.scan(
        body, (token, jnp.asarray(pos, jnp.int32), view), None, length=gamma)
    return jnp.moveaxis(toks, 0, 1)                  # (B, gamma)


def accept_greedy(proposal: np.ndarray, want: np.ndarray) -> np.ndarray:
    """Per-row greedy acceptance. ``proposal`` (B, γ) draft tokens;
    ``want`` (B, γ+1) the verifier's argmax rows — entry [i] is its
    prediction for the position proposal[:, i] sits at, entry [γ] the
    bonus token after a full accept. Both must be HOST numpy arrays
    (callers read tokens back before acceptance — this stays sync-free).
    Returns (B,) accepted prefix lengths; row r then emits
    proposal[r, :n] plus want[r, n]."""
    gamma = proposal.shape[1]
    agree = want[:, :gamma] == proposal
    return np.where(agree.all(1), gamma, np.argmin(agree, axis=1))


def make_nbl_draft(cfg: ModelConfig, params, m: int,
                   linear_maps: Optional[Mapping[int, tuple]] = None
                   ) -> tuple[ModelConfig, dict]:
    """Self-speculative drafter: the SAME model under an m-deepest-layers
    NBL plan. ``linear_maps`` ({layer: (W, b)} from core.calibrate) gives
    a calibrated draft; None installs ZERO maps — the linearized layers
    become identity residual passes, useless as an approximation but
    structurally complete, which is all parity tests and serving smokes
    need (greedy acceptance is exact regardless of draft quality; quality
    only moves the acceptance rate). m=0 returns (cfg, params) unchanged
    — a "draft" that is the target itself, accepting everything."""
    if m == 0:
        return cfg, params
    dcfg = nbl_variant(cfg, m)
    ids = list(cfg.attn_layer_indices())[-m:]
    if linear_maps is None:
        d = cfg.d_model
        zero = (np.zeros((d, d), np.float32), np.zeros((d,), np.float32))
        linear_maps = {i: zero for i in ids}
    dparams = compress_params(cfg, params, dcfg, ids, "nbl",
                              linear_maps=linear_maps)
    return dcfg, dparams


# --------------------------------------------------------------------------
# Standalone reference path (parity oracle)
# --------------------------------------------------------------------------

def speculative_generate(draft_cfg: ModelConfig, draft_params,
                         verify_cfg: ModelConfig, verify_params,
                         prompts: jax.Array, *, max_new: int,
                         gamma: int = 4,
                         eos_id: Optional[int] = None
                         ) -> tuple[np.ndarray, dict]:
    """Greedy speculative decoding, off-engine. prompts: (B, S). Returns
    (tokens (B, max_new) int32, stats). Rows are RAGGED under ``eos_id``
    or per-row acceptance: each row stops at its own first EOS (or
    max_new) and shorter rows are zero-padded on the right —
    ``stats["row_lengths"]`` carries the true per-row counts. Stats count
    POST-truncation: a draft token proposed past a row's remaining budget
    (or emitted past its EOS) never inflates ``draft_tokens``/
    ``accepted``, so ``acceptance_rate`` measures tokens that could
    actually land."""
    prompts = np.asarray(prompts, np.int32)
    b, s0 = prompts.shape
    width = s0 + max_new + gamma                   # fixed: exactly 2 traces

    # Built ONCE per generate call, closing over this call's params
    # (arrays — unhashable, so the shared registry cannot key them); the
    # padded buffer keeps shapes CONSTANT across rounds, so the loop costs
    # two traces total, not one per grown length. draft_next takes the
    # per-row valid lengths and reads each row's logits at its OWN last
    # position — rows of different lengths share one batched call.
    draft_next = jax.jit(  # nbl: disable=jit-discipline -- closes over this call's draft params; built once per call, outside the loop
        lambda t, l: jnp.take_along_axis(
            jnp.argmax(apply(draft_cfg, draft_params, t)[0], axis=-1),
            (jnp.asarray(l, jnp.int32) - 1)[:, None], axis=1
        )[:, 0].astype(jnp.int32))
    verify_block = jax.jit(  # nbl: disable=jit-discipline -- closes over this call's verifier params; built once per call, outside the loop
        lambda t: jnp.argmax(apply(verify_cfg, verify_params, t)[0],
                             axis=-1).astype(jnp.int32))

    buf = np.zeros((b, width), np.int32)
    buf[:, :s0] = prompts
    lens = np.full(b, s0, np.int64)                # committed tokens per row
    out = [[] for _ in range(b)]
    live = np.ones(b, bool)
    stats = {"verifier_calls": 0, "draft_tokens": 0, "accepted": 0}
    while live.any():
        # draft proposes gamma tokens per row (dead rows ride the batched
        # calls; their outputs are ignored below)
        proposal = np.zeros((b, gamma), np.int32)
        for i in range(gamma):
            nxt = np.asarray(draft_next(jnp.asarray(buf),
                                        jnp.asarray(lens + i)))
            proposal[:, i] = nxt
            buf[np.arange(b), lens + i] = nxt      # provisional: may roll back
        # verifier scores every candidate block in ONE call
        pred = np.asarray(verify_block(jnp.asarray(buf)))   # (B, width)
        stats["verifier_calls"] += 1
        # verifier's prediction AT position lens-1+i is the token it wants
        # at lens+i; the gather is gamma+1 wide — entry [n] is the
        # correction token after n accepts (n == gamma: the bonus token).
        idx = lens[:, None] - 1 + np.arange(gamma + 1)[None, :]
        want = np.take_along_axis(pred, idx, axis=1)        # (B, gamma+1)
        n_acc = accept_greedy(proposal, want)               # per-row prefix
        for r in np.nonzero(live)[0]:
            remaining = max_new - len(out[r])
            # post-truncation accounting: only proposals that fit the
            # row's remaining budget count as draft work
            eff = min(gamma, remaining)
            stats["draft_tokens"] += eff
            n = int(n_acc[r])
            block = [int(t) for t in proposal[r, :n]] + [int(want[r, n])]
            before = len(out[r])
            for i, t in enumerate(block[:remaining]):
                out[r].append(t)
                if i < n:
                    stats["accepted"] += 1
                if eos_id is not None and t == eos_id:
                    live[r] = False
                    break
            if len(out[r]) >= max_new:
                live[r] = False
            # commit the row's emitted tokens (overwriting any rejected
            # proposal tokens: the buffer tail is junk until rewritten)
            emitted = out[r][before:]
            buf[r, lens[r]:lens[r] + len(emitted)] = emitted
            lens[r] += len(emitted)
    padded = np.zeros((b, max_new), np.int32)
    for r in range(b):
        padded[r, :len(out[r])] = out[r]
    stats["row_lengths"] = [len(o) for o in out]
    stats["acceptance_rate"] = stats["accepted"] / max(stats["draft_tokens"],
                                                       1)
    stats["tokens_per_verifier_call"] = (sum(stats["row_lengths"])
                                         / max(stats["verifier_calls"], 1))
    return padded, stats
