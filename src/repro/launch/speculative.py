"""Speculative decoding (draft-and-verify) with an NBL-compressed verifier
— the paper's §E.2/Table 6 compounding-speed-up experiment.

Greedy speculative decoding is EXACT: the emitted sequence equals the
verifier's own greedy decode (asserted in tests). The draft proposes γ
tokens autoregressively; the verifier scores the whole candidate block in
one forward pass; the longest agreeing prefix is accepted plus one
corrected token. With an NBL-compressed verifier the per-call verifier
cost also drops (K−m)/K-style, which is why the paper's NBL-12+EAGLE-3
compounds to 4.07×.

Verification here re-runs a full forward over the prefix (O(n²) total —
fine for CPU-scale tests and for counting verifier calls); a production
deployment would verify with a multi-token cache-extend step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import apply


def speculative_generate(draft_cfg: ModelConfig, draft_params,
                         verify_cfg: ModelConfig, verify_params,
                         prompts: jax.Array, *, max_new: int,
                         gamma: int = 4) -> tuple[np.ndarray, dict]:
    """Greedy speculative decoding. prompts: (B, S). Returns
    (tokens (B, max_new), stats{verifier_calls, draft_tokens, accepted})."""
    b = prompts.shape[0]

    # Built ONCE per generate call, outside the decode loop, closing over
    # this call's params (arrays — unhashable, so the shared registry
    # cannot key them); the loop below reuses the same two wrappers, so
    # the per-call trace cost is two traces, not O(tokens). (A dead
    # `greedy_next` jit that took (cfg, params) as a TRACED argument —
    # which would have crashed if ever called, ModelConfig is no pytree —
    # was deleted when the jit-discipline pass first flagged this file.)
    draft_next = jax.jit(  # nbl: disable=jit-discipline -- closes over this call's draft params; built once per call, outside the loop
        lambda t: jnp.argmax(apply(draft_cfg, draft_params, t)[0][:, -1],
                             axis=-1).astype(jnp.int32))
    verify_block = jax.jit(  # nbl: disable=jit-discipline -- closes over this call's verifier params; built once per call, outside the loop
        lambda t: jnp.argmax(apply(verify_cfg, verify_params, t)[0],
                             axis=-1).astype(jnp.int32))

    toks = np.asarray(prompts)
    out = np.zeros((b, 0), np.int32)
    stats = {"verifier_calls": 0, "draft_tokens": 0, "accepted": 0}
    while out.shape[1] < max_new:
        # draft proposes gamma tokens
        cand = toks
        proposal = []
        for _ in range(gamma):
            nxt = np.asarray(draft_next(jnp.asarray(cand)))
            proposal.append(nxt)
            cand = np.concatenate([cand, nxt[:, None]], axis=1)
        proposal = np.stack(proposal, axis=1)            # (B, gamma)
        stats["draft_tokens"] += gamma * b

        # verifier scores the whole candidate block in ONE call
        pred = np.asarray(verify_block(jnp.asarray(cand)))  # (B, S+gamma)
        stats["verifier_calls"] += 1
        base = toks.shape[1]
        # verifier's prediction AT position base-1+i is the token it wants
        # at base+i; accept while it agrees with the draft. The slice is
        # gamma+1 wide: entry [n] is the correction token after n accepts
        # (for n == gamma it is the free bonus token).
        want = pred[:, base - 1:base + gamma]            # (B, gamma+1)
        agree = (want[:, :gamma] == proposal)
        n_acc = np.where(agree.all(1), gamma,
                         np.argmin(agree, axis=1))       # per-row prefix len
        n = int(n_acc.min())                             # lockstep batch
        emitted = (proposal[:, :n] if n else
                   np.zeros((b, 0), np.int32))
        # plus the verifier's correction/bonus token
        correction = want[:, n][:, None]
        block = np.concatenate([emitted, correction], axis=1)
        stats["accepted"] += n * b
        out = np.concatenate([out, block], axis=1)
        toks = np.concatenate([toks, block], axis=1)
    out = out[:, :max_new]
    stats["acceptance_rate"] = stats["accepted"] / max(stats["draft_tokens"],
                                                       1)
    stats["tokens_per_verifier_call"] = (out.shape[1]
                                         / max(stats["verifier_calls"], 1))
    return out, stats
