from repro.launch.mesh import make_production_mesh, make_mesh  # noqa: F401
from repro.launch.engine import AsyncEngine, Engine, Stream  # noqa: F401
from repro.launch.scheduler import Scheduler, nbl_slot_budget  # noqa: F401
