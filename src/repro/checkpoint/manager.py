"""Fault-tolerant checkpointing: atomic save, auto-resume, elastic re-shard.

Layout (one directory per step):

    <root>/step_00001234.tmp/      # in-flight write (ignored by restore)
    <root>/step_00001234/
        manifest.json              # paths, shapes, dtypes, step, mesh shape
        arrays.npz                 # flat path->array
    <root>/LATEST                  # atomic pointer (written after rename)

Crash-safety: arrays land in a ``.tmp`` directory that is os.rename()'d
(atomic on POSIX) only after fsync; a preempted save leaves a ``.tmp``
husk that restore skips and the next save garbage-collects. This is the
single-controller analogue of per-host Orbax-style commits; on a real
multi-host pod each host writes its array shards and host 0 commits the
manifest last (same protocol, noted in DESIGN.md).

Elastic re-shard: arrays are stored unsharded (addressable halo gathered);
``restore(sharding=...)`` device_puts onto whatever mesh the restarted job
has — a 2-pod checkpoint restores onto 1 pod or 4 pods unchanged.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = np.asarray(jax.device_get(leaf))
    return out


class CheckpointManager:
    def __init__(self, root: str, *, keep: int = 3):
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)

    # ---------------------------------------------------------------- save
    def save(self, step: int, tree: Any, extra: Optional[dict] = None) -> str:
        name = f"step_{step:010d}"
        tmp = os.path.join(self.root, name + ".tmp")
        final = os.path.join(self.root, name)
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        flat = _flatten(tree)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        manifest = {
            "step": step,
            "keys": sorted(flat),
            "shapes": {k: list(v.shape) for k, v in flat.items()},
            "dtypes": {k: str(v.dtype) for k, v in flat.items()},
            "extra": extra or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)                       # atomic commit
        with open(os.path.join(self.root, "LATEST.tmp"), "w") as f:
            f.write(name)
            f.flush()
            os.fsync(f.fileno())
        os.rename(os.path.join(self.root, "LATEST.tmp"),
                  os.path.join(self.root, "LATEST"))
        self._gc()
        return final

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.root, f"step_{s:010d}"),
                          ignore_errors=True)
        for d in os.listdir(self.root):             # preempted husks
            if d.endswith(".tmp") and d != "LATEST.tmp":
                shutil.rmtree(os.path.join(self.root, d), ignore_errors=True)

    # ------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.root):
            if d.startswith("step_") and not d.endswith(".tmp") \
                    and os.path.exists(os.path.join(self.root, d,
                                                    "manifest.json")):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Any,
                sharding_fn=None) -> Any:
        """Rebuild ``like``-structured pytree. ``sharding_fn(path, leaf)``
        optionally returns a Sharding for elastic placement."""
        d = os.path.join(self.root, f"step_{step:010d}")
        with np.load(os.path.join(d, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
        paths, treedef = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for path, leaf in paths:
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in path)
            arr = flat[key]
            assert tuple(arr.shape) == tuple(leaf.shape), (key, arr.shape,
                                                           leaf.shape)
            if sharding_fn is not None:
                arr = jax.device_put(arr, sharding_fn(key, leaf))
            leaves.append(arr)
        return jax.tree_util.tree_unflatten(treedef, leaves)

    def restore_latest(self, like: Any, sharding_fn=None
                       ) -> tuple[Optional[int], Any]:
        step = self.latest_step()
        if step is None:
            return None, like
        return step, self.restore(step, like, sharding_fn)
