"""Model surgery: rewrite config stack-plans and re-stack params after
linearizing (NBL) or removing (DROP/SLEB) blocks.

The surgeon keeps the model *scannable*: after transforming per-layer block
descriptors it re-groups the flat block list into maximal repeated runs
(periods up to 8), so a dense model with m linearized layers lowers to
O(2m+1) scan groups instead of O(K) unrolled blocks.
"""
from __future__ import annotations

from typing import Iterable, Mapping, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import Block, ModelConfig, StackGroup
from repro.models.transformer import layer_params

MODES = ("nbl", "drop", "nbl_block", "drop_block")


def transform_block(blk: Block, mode: str) -> Block:
    if mode == "nbl":
        return blk.replace(kind="nbl", window=None)
    if mode == "drop":
        return blk.replace(kind="drop", window=None)
    if mode == "nbl_block":
        return blk.replace(kind="nbl_block", ffn="none", window=None,
                           shared=False)
    if mode == "drop_block":
        return blk.replace(kind="drop_block", ffn="none", window=None,
                           shared=False)
    raise ValueError(mode)


def _regroup(blocks: list[Block], max_period: int = 8) -> tuple[StackGroup, ...]:
    """Greedy periodic run-length grouping of a flat block list."""
    groups: list[StackGroup] = []
    i, n = 0, len(blocks)
    while i < n:
        best_unit, best_rep, best_cover = (blocks[i],), 1, 1
        for period in range(1, max_period + 1):
            if i + period > n:
                break
            unit = tuple(blocks[i:i + period])
            rep = 1
            while (i + (rep + 1) * period <= n
                   and tuple(blocks[i + rep * period:
                             i + (rep + 1) * period]) == unit):
                rep += 1
            cover = rep * period
            # HLO size ∝ unit length, so only repeated units beat the
            # single-block fallback; among those prefer more coverage,
            # then shorter units.
            if rep >= 2 and (cover > best_cover
                             or (cover == best_cover
                                 and period < len(best_unit))):
                best_unit, best_rep, best_cover = unit, rep, cover
        groups.append(StackGroup(unit=best_unit, repeat=best_rep))
        i += best_cover
    return tuple(groups)


def nbl_variant(cfg: ModelConfig, m: int) -> ModelConfig:
    """Compressed config: linearize the m deepest self-attention layers
    (paper App. G: selected layers concentrate at the end of the stack).
    m=0 returns the config unchanged."""
    cand = cfg.attn_layer_indices()
    return compress_config(cfg, cand[-m:], "nbl") if m else cfg


def compress_config(cfg: ModelConfig, layer_ids: Iterable[int],
                    mode: str = "nbl") -> ModelConfig:
    """New config with ``layer_ids`` transformed per ``mode``."""
    assert mode in MODES, mode
    ids = set(layer_ids)
    blocks = cfg.blocks()
    for i in ids:
        blocks[i] = transform_block(blocks[i], mode)
    nbl_prev = set(cfg.nbl_layers)
    if mode in ("nbl", "nbl_block"):
        nbl_prev |= ids
    return cfg.replace(stack=_regroup(blocks),
                       nbl_layers=tuple(sorted(nbl_prev)))


def _transform_params(blk_old: Block, p_old: dict, mode: str,
                      linear: Optional[tuple[np.ndarray, np.ndarray]],
                      dtype) -> dict:
    """Per-layer param rewrite. ``linear`` = (W (d_out,d_in), b) from LMMSE.
    The model computes h = x @ w + b, so w stores W᳕."""
    if mode in ("nbl", "nbl_block"):
        assert linear is not None, "NBL needs LMMSE (W, b)"
        w, b = linear
        mixer = {"w": jnp.asarray(np.asarray(w).T, dtype),
                 "b": jnp.asarray(np.asarray(b), dtype)}
        if mode == "nbl_block":
            return {"mixer": mixer}
        p = {"mixer": mixer}
    elif mode == "drop":
        p = {}
    else:  # drop_block
        return {}
    # retain the FFN path (and its norm) untouched
    for k in ("norm2", "ffn"):
        if k in p_old:
            p[k] = p_old[k]
    return p


def compress_params(cfg: ModelConfig, params: dict, new_cfg: ModelConfig,
                    layer_ids: Iterable[int], mode: str = "nbl",
                    linear_maps: Optional[Mapping[int, tuple]] = None) -> dict:
    """Re-stack params for ``new_cfg`` (produced by compress_config).

    Shared blocks keep a single copy per group; if regrouping splits a shared
    block across groups each group keeps its own copy (small, documented).
    """
    ids = set(layer_ids)
    dtype = jnp.dtype(cfg.param_dtype)
    old_blocks = cfg.blocks()
    per_layer = []
    for i, blk in enumerate(old_blocks):
        p_i, _ = layer_params(cfg, params, i)
        if i in ids:
            lin = None if linear_maps is None else linear_maps.get(i)
            p_i = _transform_params(blk, p_i, mode, lin, dtype)
        per_layer.append(p_i)

    new_params = {k: v for k, v in params.items() if k != "groups"}
    groups = []
    i = 0
    for g in new_cfg.stack:
        scanned, shared = [], []
        for u, blk in enumerate(g.unit):
            layer_ps = [per_layer[i + r * len(g.unit) + u]
                        for r in range(g.repeat)]
            if blk.shared:
                shared.append(layer_ps[0])
                scanned.append(None)
            else:
                scanned.append(jax.tree.map(
                    lambda *a: jnp.stack(a), *layer_ps))
                shared.append(None)
        groups.append({"scanned": scanned, "shared": shared})
        i += g.n_blocks
    new_params["groups"] = groups
    return new_params


def compress(cfg: ModelConfig, params: dict, layer_ids: Iterable[int],
             mode: str = "nbl",
             linear_maps: Optional[Mapping[int, tuple]] = None
             ) -> tuple[ModelConfig, dict]:
    layer_ids = list(layer_ids)
    new_cfg = compress_config(cfg, layer_ids, mode)
    new_params = compress_params(cfg, params, new_cfg, layer_ids, mode,
                                 linear_maps)
    return new_cfg, new_params
