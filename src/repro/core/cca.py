"""Canonical Correlation Analysis and the Theorem-3.2 NMSE bound.

All dense linear algebra here is host-side float64 numpy (the paper runs this
on CPU/GPU once per layer at calibration time; cost O(d³), App. D).
"""
from __future__ import annotations

import numpy as np


def inv_sqrt_psd(c: np.ndarray, eps: float = 1e-6) -> np.ndarray:
    """C^{-1/2} of a symmetric PSD matrix via eigh, eigenvalue-floored."""
    c = np.asarray(c, np.float64)
    c = 0.5 * (c + c.T)
    w, v = np.linalg.eigh(c)
    floor = max(eps, eps * float(w.max(initial=1.0)))
    w = np.maximum(w, floor)
    return (v * (w ** -0.5)) @ v.T


def canonical_correlations(cxx: np.ndarray, cyx: np.ndarray,
                           cyy: np.ndarray, eps: float = 1e-6) -> np.ndarray:
    """Singular values ρ_i of C_W = C_YY^{-1/2} C_YX C_XX^{-1/2}, clipped to
    [0, 1] (floating-point can nudge slightly above 1)."""
    cw = inv_sqrt_psd(cyy, eps) @ np.asarray(cyx, np.float64) @ inv_sqrt_psd(cxx, eps)
    rho = np.linalg.svd(cw, compute_uv=False)
    return np.clip(rho, 0.0, 1.0)


def nmse_bound(rho: np.ndarray, h_out: int, h_in: int) -> float:
    """Theorem 3.2: NMSE ≤ (h_out − r) + Σ_{i≤r} (1 − ρ_i²), r = min(h_out, h_in)."""
    r = min(h_out, h_in)
    rho = np.asarray(rho, np.float64)[:r]
    return float((h_out - r) + np.sum(1.0 - rho ** 2))


def cca_bound_from_moments(fin: dict) -> tuple[float, np.ndarray]:
    """Algorithm 2: the bound is computed on (X, Y₊) — the *post-residual*
    attention output — while the LMMSE weights use (X, Y)."""
    rho = canonical_correlations(fin["cxx"], fin["cypx"], fin["cypyp"])
    h_out, h_in = fin["cypx"].shape
    return nmse_bound(rho, h_out, h_in), rho
