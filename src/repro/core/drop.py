"""Removal baselines the paper compares against.

  Attn DROP / Block DROP  [He et al. 2024]: rank blocks by cosine distance
      between block input and output (most-similar first), remove the
      attention sub-block / whole block.
  SLEB [Song et al. 2024]: greedy transformer-block removal by perplexity
      impact on a calibration stream.
"""
from __future__ import annotations

from typing import Callable

from repro.configs.base import ModelConfig
from repro.core.calibrate import calibrate
from repro.core.selection import select_layers
from repro.core.surgery import compress
from repro.eval import perplexity


def drop_compress(cfg: ModelConfig, params: dict, data_factory: Callable,
                  m: int, *, block: bool = False) -> tuple[ModelConfig, dict, list[int]]:
    """Attn DROP (block=False) / Block DROP (block=True)."""
    calib = calibrate(cfg, params, data_factory, tap_block=block)
    ids = select_layers(calib, m, criterion="cosine")
    mode = "drop_block" if block else "drop"
    new_cfg, new_params = compress(cfg, params, ids, mode)
    return new_cfg, new_params, ids


def sleb_compress(cfg: ModelConfig, params: dict, data_factory: Callable,
                  m: int) -> tuple[ModelConfig, dict, list[int]]:
    """Greedy block removal: at each of m rounds remove the block whose
    removal hurts calibration perplexity least."""
    removed: list[int] = []
    cur_cfg, cur_params = cfg, params
    for _ in range(m):
        candidates = [i for i, b in enumerate(cur_cfg.blocks())
                      if b.kind not in ("drop_block",) and not b.shared]
        best, best_ppl = None, float("inf")
        for i in candidates:
            t_cfg, t_params = compress(cur_cfg, cur_params, [i], "drop_block")
            ppl = perplexity(t_cfg, t_params, data_factory)
            if ppl < best_ppl:
                best, best_ppl = i, ppl
        removed.append(best)
        cur_cfg, cur_params = compress(cur_cfg, cur_params, [best],
                                       "drop_block")
    return cur_cfg, cur_params, removed
