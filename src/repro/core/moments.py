"""Streaming first/second-moment accumulation for NBL calibration.

The paper (App. D) forms X, Y ∈ R^{(s·t)×d} by stacking all calibration
tokens and computes covariances in one shot. At 405B scale (and on a
multi-pod mesh) the token matrix cannot be centralized, so we accumulate raw
moments *streamingly* per data shard:

    n, Σx, Σy, Σy₊, ΣxᵀX, Σy x᳕, Σy₊x᳕, Σy₊y₊᳕, Σcos(x, y₊)

and merge shards by summation (a `psum` over the data axes under pjit, or a
tree-add on host). Covariances are finalized once, in float64, on host —
the O(d³) eigh/SVD is calibration-time, not inference-time (paper App. D).

`Σcos` additionally streams the DROP baseline's cosine-distance criterion
(1 − E[cos(x, y₊)]) so both selection criteria come from one pass.

Accumulation order is fixed by the data pipeline, so results are bitwise
deterministic for a given shard count — required for elastic restart of an
interrupted calibration (see checkpoint/).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def init_moments(d_in: int, d_out: int, dtype=jnp.float32) -> dict:
    z = jnp.zeros
    return {
        "n": z((), dtype),
        "sx": z((d_in,), dtype),
        "sy": z((d_out,), dtype),
        "syp": z((d_out,), dtype),
        "sxx": z((d_in, d_in), dtype),
        "syx": z((d_out, d_in), dtype),
        "sypx": z((d_out, d_in), dtype),
        "sypyp": z((d_out, d_out), dtype),
        "scos": z((), dtype),
    }


def update_moments(state: dict, x: jax.Array, y: jax.Array) -> dict:
    """Accumulate one batch. x: (..., d_in), y: (..., d_out) — the attention
    (or block) input and its pre-residual output. y₊ = y + x (Algorithm 2)."""
    d_in = x.shape[-1]
    d_out = y.shape[-1]
    xt = x.reshape(-1, d_in).astype(jnp.float32)
    yt = y.reshape(-1, d_out).astype(jnp.float32)
    yp = yt + xt if d_in == d_out else yt

    nrm = (jnp.linalg.norm(xt, axis=-1) * jnp.linalg.norm(yp, axis=-1))
    cos = (xt * yp).sum(-1) / jnp.maximum(nrm, 1e-20)

    return {
        "n": state["n"] + xt.shape[0],
        "sx": state["sx"] + xt.sum(0),
        "sy": state["sy"] + yt.sum(0),
        "syp": state["syp"] + yp.sum(0),
        "sxx": state["sxx"] + xt.T @ xt,
        "syx": state["syx"] + yt.T @ xt,
        "sypx": state["sypx"] + yp.T @ xt,
        "sypyp": state["sypyp"] + yp.T @ yp,
        "scos": state["scos"] + cos.sum(),
    }


def merge_moments(a: dict, b: dict) -> dict:
    return jax.tree.map(jnp.add, a, b)


def psum_moments(state: dict, axes) -> dict:
    """Cross-shard reduction inside shard_map'd calibration."""
    return jax.tree.map(lambda v: jax.lax.psum(v, axes), state)


def finalize(state: dict) -> dict:
    """Host-side float64 conversion to means/covariances (unbiased)."""
    s = {k: np.asarray(v, np.float64) for k, v in state.items()}
    n = float(s["n"])
    assert n > 1, "need >1 calibration tokens"
    ex, ey, eyp = s["sx"] / n, s["sy"] / n, s["syp"] / n
    c = 1.0 / (n - 1.0)
    return {
        "n": n,
        "ex": ex, "ey": ey, "eyp": eyp,
        "cxx": c * (s["sxx"] - n * np.outer(ex, ex)),
        "cyx": c * (s["syx"] - n * np.outer(ey, ex)),
        "cypx": c * (s["sypx"] - n * np.outer(eyp, ex)),
        "cypyp": c * (s["sypyp"] - n * np.outer(eyp, eyp)),
        "cos_mean": float(s["scos"]) / n,
    }
