"""One-call public API for the paper's technique and its baselines."""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.calibrate import LayerCalib, calibrate, candidate_layers
from repro.core.selection import rank_layers, select_layers
from repro.core.surgery import compress


@dataclasses.dataclass
class CompressionReport:
    method: str
    layers: list[int]
    ranking: list[int]
    bounds: dict[int, float]
    cos_dists: dict[int, float]
    nmse: dict[int, float]

    def summary(self) -> str:
        rows = [f"{self.method}: linearized/removed layers {self.layers}"]
        for i in self.layers:
            rows.append(f"  layer {i:3d} bound={self.bounds[i]:.4f} "
                        f"nmse={self.nmse.get(i, float('nan')):.4f} "
                        f"cos_dist={self.cos_dists[i]:.4f}")
        return "\n".join(rows)


def nbl_compress(cfg: ModelConfig, params: dict, data_factory: Callable,
                 m: int, *, block: bool = False, criterion: str = "cca",
                 layers: Optional[Sequence[int]] = None,
                 block_kinds: Sequence[str] = ("attn",),
                 calib: Optional[dict[int, LayerCalib]] = None,
                 ) -> tuple[ModelConfig, dict, CompressionReport]:
    """Neural Block Linearization (Algorithm 1).

    block=False  -> Attn NBL-m (the paper's main configuration)
    block=True   -> Block NBL-m (whole transformer blocks)
    criterion    -> "cca" (Theorem 3.2 bound) or "cosine" (ablation F.3)
    block_kinds  -> ("attn",) default; ("mamba",) linearizes SSD mixers
                    (the 'any block' generality claim; used as an ablation)
    """
    if calib is None:
        cand = layers if layers is not None else candidate_layers(cfg, tuple(block_kinds))
        calib = calibrate(cfg, params, data_factory, layers=cand,
                          tap_block=block)
    ids = select_layers(calib, m, criterion)
    mode = "nbl_block" if block else "nbl"
    new_cfg, new_params = compress(
        cfg, params, ids, mode,
        linear_maps={i: calib[i].linear for i in ids})
    report = CompressionReport(
        method=("Block" if block else "Attn") + f" NBL-{m} ({criterion})",
        layers=ids, ranking=rank_layers(calib, criterion),
        bounds={i: c.bound for i, c in calib.items()},
        cos_dists={i: c.cos_dist for i, c in calib.items()},
        nmse={i: c.nmse for i, c in calib.items()})
    return new_cfg, new_params, report
