"""The paper's contribution: Neural Block Linearization (NBL).

  moments    streaming distributed (X, Y) moment accumulation
  cca        canonical correlations + Theorem-3.2 NMSE bound
  lmmse      Proposition-3.1 closed-form linear estimator
  calibrate  Algorithm 1/2 driver over a calibration stream
  selection  CCA-bound / cosine / greedy layer selection
  surgery    config + param rewriting (keeps models scannable)
  drop       DROP / SLEB removal baselines
  api        nbl_compress / reports
"""
from repro.core.api import CompressionReport, nbl_compress  # noqa: F401
from repro.core.calibrate import LayerCalib, calibrate, candidate_layers  # noqa: F401
from repro.core.cca import (  # noqa: F401
    canonical_correlations, cca_bound_from_moments, inv_sqrt_psd, nmse_bound,
)
from repro.core.drop import drop_compress, sleb_compress  # noqa: F401
from repro.core.lora import lora_apply, lora_finetune, lora_init  # noqa: F401
from repro.core.lmmse import lmmse_from_moments, lmmse_mse  # noqa: F401
from repro.core.moments import (  # noqa: F401
    finalize, init_moments, merge_moments, psum_moments, update_moments,
)
from repro.core.selection import greedy_select, rank_layers, select_layers  # noqa: F401
from repro.core.surgery import (  # noqa: F401
    compress, compress_config, compress_params, nbl_variant,
)
