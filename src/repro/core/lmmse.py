"""Closed-form LMMSE estimator (Proposition 3.1).

    W = C_YX C_XX^{-1},   b = E[Y] − W E[X].

Solved host-side in float64 via a symmetric solve with a small ridge on
C_XX (calibration sample noise makes the smallest eigenvalues unreliable;
the ridge is relative to mean diagonal magnitude).
"""
from __future__ import annotations

import numpy as np


def lmmse_from_moments(fin: dict, ridge: float = 1e-6
                       ) -> tuple[np.ndarray, np.ndarray]:
    """Returns (W (d_out, d_in), b (d_out,)) in float64."""
    cxx = np.asarray(fin["cxx"], np.float64)
    cyx = np.asarray(fin["cyx"], np.float64)
    d = cxx.shape[0]
    lam = ridge * float(np.trace(cxx)) / d
    a = cxx + lam * np.eye(d)
    # W = C_yx A^{-1}  <=>  A W^T = C_yx^T  (A symmetric PD)
    w = np.linalg.solve(a, cyx.T).T
    b = fin["ey"] - w @ fin["ex"]
    return w, b


def lmmse_mse(fin: dict, w: np.ndarray) -> float:
    """Achieved MSE of the estimator: Tr(C_YY − W C_XY) (eq. 12 with the
    optimal W; also valid as Tr(C_YY) − Tr(W C_XY) for the ridge solution
    up to O(ridge))."""
    cyy_tr = float(np.trace(fin["cypyp"]))  # not used; kept for clarity
    del cyy_tr
    cyx = np.asarray(fin["cyx"], np.float64)
    # E‖Y−Ŷ‖² = Tr(C_YY) − Tr(W C_XY); we only have C_Y₊Y₊, so compute
    # Tr(C_YY) from it: C_Y₊Y₊ = C_YY + C_YX + C_XY + C_XX.
    cyy = (np.asarray(fin["cypyp"]) - cyx - cyx.T - np.asarray(fin["cxx"]))
    return float(np.trace(cyy) - np.trace(w @ cyx.T))
