"""NBL calibration driver: stream calibration batches through the model,
accumulate per-layer (X, Y) moments, compute CCA bounds + LMMSE maps.

Memory strategy (paper App. D adapted to accelerators): layers are processed
in chunks of ``chunk_layers``; for each chunk the calibration stream is
re-played (data factories are deterministic) and only that chunk's taps are
alive at once. The moment update itself is jit'd; under a mesh the token
batch is data-parallel and the d×d accumulators replicate (XLA inserts the
cross-shard reduction for the sharded-token contraction — the psum of
DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Iterable, Optional, Sequence

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import cca, lmmse
from repro.core.moments import finalize, init_moments, update_moments
from repro.jitcache import shared_jit
from repro.models.transformer import forward_with_taps


def _moment_step(cfg: ModelConfig, tap_block: bool, p, tokens, enc, moms):
    _, taps = forward_with_taps(cfg, p, tokens, enc=enc,
                                tap_layers=tuple(moms.keys()),
                                tap_block=tap_block)
    return {i: update_moments(moms[i], *taps[i]) for i in moms}


@dataclasses.dataclass
class LayerCalib:
    layer: int
    bound: float                 # Theorem 3.2 NMSE upper bound
    cos_dist: float              # DROP's criterion: 1 − E[cos(x, y₊)]
    rho: np.ndarray              # canonical correlations
    w: np.ndarray                # LMMSE weight (d_out, d_in)
    b: np.ndarray                # LMMSE bias (d_out,)
    mse: float                   # achieved MSE Tr(C_YY) − Tr(W C_XY)
    nmse: float                  # mse / Tr(C_Y₊Y₊)

    @property
    def linear(self) -> tuple[np.ndarray, np.ndarray]:
        return self.w, self.b


def candidate_layers(cfg: ModelConfig, block_kinds: Sequence[str] = ("attn",)
                     ) -> list[int]:
    """Default NBL candidates: non-shared self-attention blocks. The generic
    path (paper: "NBL can linearize any block") accepts ("mamba",) etc."""
    if block_kinds == ("attn",):
        return cfg.attn_layer_indices()
    return [i for i, b in enumerate(cfg.blocks())
            if b.kind in block_kinds and not b.shared]


def calibrate(cfg: ModelConfig, params: dict,
              data_factory: Callable[[], Iterable[dict]], *,
              layers: Optional[Sequence[int]] = None,
              tap_block: bool = False,
              chunk_layers: int = 8,
              ridge: float = 1e-6) -> dict[int, LayerCalib]:
    """Run Algorithm 1 steps 3-6 + the (W, b) computation of step 9 for all
    candidate layers. ``data_factory()`` returns a fresh iterator of batches
    ({"tokens": (B,S), optional "enc"}) — replayed once per layer chunk."""
    layers = list(layers if layers is not None else candidate_layers(cfg))
    d = cfg.d_model

    # shared across calls (the moms dict's KEYS are pytree structure, so
    # each layer chunk gets its own entry in the wrapper's trace cache —
    # exactly what re-running calibrate over sweeps wants to reuse)
    step = shared_jit(("calibrate.step", cfg, bool(tap_block)),
                      lambda: jax.jit(partial(_moment_step, cfg, tap_block)))

    results: dict[int, LayerCalib] = {}
    for c0 in range(0, len(layers), chunk_layers):
        chunk = layers[c0:c0 + chunk_layers]
        moms = {i: init_moments(d, d) for i in chunk}
        for batch in data_factory():
            moms = step(params, batch["tokens"], batch.get("enc"), moms)
        for i in chunk:
            fin = finalize(jax.device_get(moms[i]))
            bound, rho = cca.cca_bound_from_moments(fin)
            w, b = lmmse.lmmse_from_moments(fin, ridge)
            mse = lmmse.lmmse_mse(fin, w)
            tr = float(np.trace(fin["cypyp"]))
            results[i] = LayerCalib(
                layer=i, bound=bound, cos_dist=1.0 - fin["cos_mean"],
                rho=rho, w=w, b=b, mse=mse, nmse=mse / max(tr, 1e-30))
    return results
