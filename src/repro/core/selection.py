"""Layer-selection criteria (Algorithm 1 step 7 + paper ablations F.3/F.4).

  - "cca":    rank by the Theorem-3.2 NMSE bound (the paper's criterion)
  - "cosine": rank by DROP's cosine distance 1 − E[cos(x, y₊)]
               (layers whose output is most similar to their input first)
"""
from __future__ import annotations

from typing import Callable, Mapping

from repro.configs.base import ModelConfig
from repro.core.calibrate import LayerCalib, calibrate
from repro.core.surgery import compress


def rank_layers(calib: Mapping[int, LayerCalib],
                criterion: str = "cca") -> list[int]:
    if criterion == "cca":
        return sorted(calib, key=lambda i: calib[i].bound)
    if criterion == "cosine":
        return sorted(calib, key=lambda i: calib[i].cos_dist)
    raise ValueError(criterion)


def select_layers(calib: Mapping[int, LayerCalib], m: int,
                  criterion: str = "cca") -> list[int]:
    """The m most-linearizable layers (lowest bound / distance)."""
    return rank_layers(calib, criterion)[:m]


def greedy_select(cfg: ModelConfig, params: dict,
                  data_factory: Callable, m: int, *,
                  mode: str = "nbl") -> tuple[list[int], dict[int, LayerCalib]]:
    """Paper Appendix F.4 ablation: iteratively pick the single best layer,
    apply its linearization, re-calibrate on the compressed model, repeat.
    (The paper finds one-shot CCA ranking outperforms this.)"""
    chosen: list[int] = []
    cur_cfg, cur_params = cfg, params
    all_calib: dict[int, LayerCalib] = {}
    for _ in range(m):
        remaining = [i for i in cur_cfg.attn_layer_indices()
                     if i not in chosen]
        calib = calibrate(cur_cfg, cur_params, data_factory, layers=remaining)
        best = min(calib, key=lambda i: calib[i].bound)
        all_calib[best] = calib[best]
        chosen.append(best)
        cur_cfg, cur_params = compress(
            cur_cfg, cur_params, [best], mode,
            linear_maps={best: calib[best].linear})
    return chosen, all_calib
