"""LoRA refinement of NBL-linearized layers (paper Appendix F.2).

The paper finds LoRA on the inserted linear layers gives only marginal
gains over NBL alone — we reproduce that ablation. Adapters attach ONLY to
``nbl``/``nbl_block`` mixer weights (w' = w + a @ b, a zero-init so step 0
is exactly the NBL model); everything else stays frozen, so the fine-tune
optimizes a ~2·d·r-per-layer parameter set.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import loss_fn
from repro.optim import adamw_init, adamw_update


def _nbl_sites(cfg: ModelConfig):
    """Yields (group_idx, unit_idx, repeat) for scanned nbl blocks."""
    for gi, g in enumerate(cfg.stack):
        for u, blk in enumerate(g.unit):
            if blk.kind in ("nbl", "nbl_block") and not blk.shared:
                yield gi, u, g.repeat


def lora_init(cfg: ModelConfig, rank: int, key: jax.Array) -> dict:
    """{(gi,ui) -> {"a": (R, d, r) zeros, "b": (R, r, d) normal}}."""
    d = cfg.d_model
    out = {}
    for gi, u, rep in _nbl_sites(cfg):
        key, sub = jax.random.split(key)
        out[f"{gi}/{u}"] = {
            "a": jnp.zeros((rep, d, rank), jnp.float32),
            "b": (jax.random.normal(sub, (rep, rank, d)) * rank ** -0.5
                  ).astype(jnp.float32),
        }
    return out


def lora_apply(cfg: ModelConfig, params: dict, lora: dict) -> dict:
    """Params with w' = w + a @ b on every adapted layer (non-mutating)."""
    groups = [dict(g, scanned=list(g["scanned"])) for g in params["groups"]]
    for keyname, ab in lora.items():
        gi, u = map(int, keyname.split("/"))
        blkp = dict(groups[gi]["scanned"][u])
        mixer = dict(blkp["mixer"])
        delta = jnp.einsum("ldr,lre->lde", ab["a"], ab["b"])
        mixer["w"] = (mixer["w"].astype(jnp.float32) + delta
                      ).astype(mixer["w"].dtype)
        blkp["mixer"] = mixer
        groups[gi]["scanned"][u] = blkp
    return dict(params, groups=groups)


def lora_finetune(cfg: ModelConfig, params: dict,
                  data_factory: Callable, *, steps: int = 30,
                  rank: int = 8, lr: float = 1e-3, seed: int = 0,
                  log_fn=lambda s: None) -> dict:
    """Fine-tune only the LoRA adapters; returns merged params."""
    lora = lora_init(cfg, rank, jax.random.PRNGKey(seed))
    if not lora:
        return params
    opt = adamw_init(lora)

    @jax.jit  # nbl: disable=jit-discipline -- closes over THIS run's params (arrays); a shared wrapper would pin stale weights across runs
    def step(lo, op, batch, i):
        def f(lo):
            return loss_fn(cfg, lora_apply(cfg, params, lo), batch,
                           remat=False)[0]
        loss, g = jax.value_and_grad(f)(lo)
        lo, op, _ = adamw_update(g, op, lo, lr=lr, weight_decay=0.0)
        return lo, op, loss

    it = 0
    while it < steps:
        for batch in data_factory():
            lora, opt, loss = step(lora, opt, batch, it)
            if it % 10 == 0:
                log_fn(f"[lora] step {it} loss {float(loss):.4f}")
            it += 1
            if it >= steps:
                break
    return lora_apply(cfg, params, lora)
