"""Int8 error-feedback gradient compression for the DP all-reduce.

At 512+ chips the gradient all-reduce over the ("pod","data") axes is the
dominant inter-pod collective. Quantizing to int8 with a per-tensor scale
cuts those bytes 4× (vs f32); the quantization error is fed back into the
next step's gradient (EF-SGD), which keeps convergence (validated on a tiny
model in tests/test_optim.py).

Usage inside a shard_map'd train step:

    g_sum, err = compressed_psum(grads, err, axes=("pod", "data"))

The psum itself runs on int32 (XLA has no int8 all-reduce; int32 carries the
sum of ≤ 2¹⁵ int8 shards losslessly), scales are psum-maxed.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ef_init(params):
    return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8. Returns (q, scale)."""
    scale = jnp.max(jnp.abs(x)) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(grads, err, axes):
    """Error-feedback int8 all-reduce of a gradient pytree over mesh
    ``axes``. Returns (mean_grads_f32, new_err). Call inside shard_map."""
    axis_size = getattr(jax.lax, "axis_size",
                        lambda a: jax.lax.psum(1, a))  # pre-0.5 fallback
    nshards = 1
    for a in (axes if isinstance(axes, (tuple, list)) else (axes,)):
        nshards *= axis_size(a)

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = quantize_int8(g32)
        # shared scale across shards so the int32 sum is exact
        scale = jax.lax.pmax(scale, axes)
        q = jnp.clip(jnp.round(g32 / scale), -127, 127)
        sent = q * scale
        new_e = g32 - sent
        total = jax.lax.psum(q.astype(jnp.int32), axes)
        return (total.astype(jnp.float32) * scale) / nshards, new_e

    flat, treedef = jax.tree.flatten(grads)
    eflat = treedef.flatten_up_to(err)
    out = [one(g, e) for g, e in zip(flat, eflat)]
    return (jax.tree.unflatten(treedef, [o[0] for o in out]),
            jax.tree.unflatten(treedef, [o[1] for o in out]))
