"""LR schedules: cosine (default) and WSD (warmup-stable-decay, MiniCPM —
the schedule its config card calls for)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    floor: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / jnp.maximum(warmup, 1)
        frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1),
                        0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5
                         * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def wsd_schedule(peak_lr: float, warmup: int, total: int,
                 decay_frac: float = 0.1, floor: float = 0.1):
    """Warmup → stable plateau → linear decay over the last decay_frac."""
    decay_start = int(total * (1 - decay_frac))

    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / jnp.maximum(warmup, 1)
        frac = jnp.clip((step - decay_start)
                        / jnp.maximum(total - decay_start, 1), 0.0, 1.0)
        dec = peak_lr * (1 - (1 - floor) * frac)
        out = jnp.where(step < warmup, warm, peak_lr)
        return jnp.where(step >= decay_start, dec, out)
    return lr


def get_schedule(name: str, peak_lr: float, warmup: int, total: int):
    if name == "cosine":
        return cosine_schedule(peak_lr, warmup, total)
    if name == "wsd":
        return wsd_schedule(peak_lr, warmup, total)
    raise ValueError(name)
