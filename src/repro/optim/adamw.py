"""AdamW with global-norm clipping.

Implemented directly in JAX (no optax in this environment). ZeRO-1 moment
sharding is applied at the jit boundary (distributed.sharding.zero1_specs):
the Adam moments' in/out shardings add the DP axes on top of the weight's
own spec, so each DP replica holds 1/|dp| of the optimizer state and the
update math runs sharded. For a 1T-param model (kimi-k2) this is the
difference between ~8 GB and ~125 GB of optimizer state per chip.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def adamw_init(params, *, zero1: bool = True) -> dict:
    del zero1                       # sharding handled via zero1_specs
    def mom(p):
        return jnp.zeros_like(p, dtype=jnp.float32)
    return {
        "mu": jax.tree.map(mom, params),
        "nu": jax.tree.map(mom, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm_clip(grads, max_norm: float):
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), gn


def adamw_update(grads, state: dict, params, *, lr, b1: float = 0.9,
                 b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1,
                 max_grad_norm: Optional[float] = 1.0,
                 zero1: bool = True):
    """Returns (new_params, new_state, metrics)."""
    if max_grad_norm is not None:
        grads, gn = global_norm_clip(grads, max_grad_norm)
    else:
        gn = jnp.zeros(())
    count = state["count"] + 1
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g32 = g.astype(jnp.float32)
        mu = b1 * mu + (1 - b1) * g32
        nu = b2 * nu + (1 - b2) * g32 * g32
        step = (mu / c1) / (jnp.sqrt(nu / c2) + eps)
        step = step + weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
        return new_p, mu, nu

    flat, treedef = jax.tree.flatten(params)
    gflat = treedef.flatten_up_to(grads)
    muflat = treedef.flatten_up_to(state["mu"])
    nuflat = treedef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat, gflat, muflat, nuflat)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_state = {
        "mu": jax.tree.unflatten(treedef, [o[1] for o in out]),
        "nu": jax.tree.unflatten(treedef, [o[2] for o in out]),
        "count": count,
    }
    return new_params, new_state, {"grad_norm": gn}
