from repro.optim.adamw import adamw_init, adamw_update, global_norm_clip  # noqa: F401
from repro.optim.schedules import cosine_schedule, wsd_schedule, get_schedule  # noqa: F401
from repro.optim.compression import (  # noqa: F401
    ef_init, quantize_int8, dequantize_int8, compressed_psum,
)
