"""Quickstart: train a small LM, apply Neural Block Linearization, compare.

    PYTHONPATH=src python examples/quickstart.py

Demonstrates the full paper pipeline in ~2 minutes on CPU:
  1. train a tiny transformer on the synthetic corpus,
  2. calibrate (Algorithm 2): moments → CCA bounds → LMMSE maps,
  3. select + linearize the m most-redundant attention layers (Algorithm 1),
  4. compare perplexity and KV-cache bytes against Attn DROP.
"""
import jax

from repro.configs import get_config
from repro.core import drop_compress, nbl_compress
from repro.data import calib_factory
from repro.eval import perplexity
from repro.launch.train import train
from repro.models.kv_cache import cache_bytes


def main() -> None:
    cfg = get_config("tiny-dense")
    print(f"== training {cfg.name} ({cfg.n_params():,} params) ==")
    out = train(cfg, steps=150, global_batch=16, seq=64, peak_lr=3e-3,
                log_every=50)
    params = out["params"]

    fac = calib_factory(cfg, batch=4, seq=64, n_batches=6)
    evalfac = calib_factory(cfg, batch=4, seq=64, n_batches=4, seed=777)
    base_ppl = perplexity(cfg, params, evalfac)
    print(f"baseline ppl {base_ppl:.2f}  "
          f"kv-cache {cache_bytes(cfg, 8, 512):,} B")

    m = 2
    ncfg, nparams, report = nbl_compress(cfg, params, fac, m)
    print("\n== NBL calibration report ==")
    print(report.summary())
    nbl_ppl = perplexity(ncfg, nparams, evalfac)
    print(f"\nAttn NBL-{m}:  ppl {nbl_ppl:.2f}  "
          f"kv-cache {cache_bytes(ncfg, 8, 512):,} B "
          f"({cfg.n_blocks - m}/{cfg.n_blocks} of baseline)")

    dcfg, dparams, _ = drop_compress(cfg, params, fac, m)
    drop_ppl = perplexity(dcfg, dparams, evalfac)
    print(f"Attn DROP-{m}: ppl {drop_ppl:.2f}")
    print(f"\nNBL degradation {nbl_ppl / base_ppl - 1:+.1%} vs "
          f"DROP {drop_ppl / base_ppl - 1:+.1%} (paper: NBL ≤ DROP)")


if __name__ == "__main__":
    main()
