"""The paper's scenario end-to-end: compress a pre-trained model with NBL,
then SERVE it — batched prefill + autoregressive decode with per-layer KV
caches (none on linearized layers).

    PYTHONPATH=src python examples/compress_and_serve.py [--m 2] [--new 24]

Shows: identical generations where the model is confident, the KV-cache
shrink, and the serve-step FLOP reduction (the structural speed-up that
turns into the paper's 1.1-1.5× on real hardware).
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import nbl_compress
from repro.data import ZipfMarkov, calib_factory
from repro.launch.serve import generate
from repro.launch.train import train
from repro.models.kv_cache import cache_bytes
from repro.obs import clock


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--m", type=int, default=2, help="layers to linearize")
    ap.add_argument("--new", type=int, default=24, help="tokens to decode")
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()

    cfg = get_config("tiny-dense")
    print(f"== pre-training {cfg.name} ==")
    params = train(cfg, steps=150, global_batch=16, seq=64, peak_lr=3e-3,
                   log_every=75)["params"]

    print(f"== NBL-compressing {args.m} attention layers ==")
    fac = calib_factory(cfg, batch=4, seq=64, n_batches=6)
    ncfg, nparams, report = nbl_compress(cfg, params, fac, args.m)
    print(report.summary())

    proc = ZipfMarkov(cfg.vocab_size, seed=0)
    prompts = jnp.asarray(proc.sample(args.batch, 16, seed=42))

    print(f"\n== serving: {args.batch} requests, prompt 16, "
          f"+{args.new} tokens ==")
    outs = {}
    for tag, (c, p) in {"baseline": (cfg, params),
                        f"nbl-{args.m}": (ncfg, nparams)}.items():
        t0 = clock()
        toks = generate(c, p, prompts, max_new=args.new)
        dt = clock() - t0
        outs[tag] = np.asarray(toks)
        kv = cache_bytes(c, args.batch, 16 + args.new)
        print(f"{tag:10s} {dt:6.2f}s wall (CPU)  kv-cache {kv:,} B  "
              f"first-request tokens: {outs[tag][0][:10].tolist()}")

    agree = (outs["baseline"] == outs[f"nbl-{args.m}"]).mean()
    print(f"\ntoken agreement baseline vs NBL-{args.m}: {agree:.1%}")
    kv0 = cache_bytes(cfg, args.batch, 16 + args.new)
    kv1 = cache_bytes(ncfg, args.batch, 16 + args.new)
    print(f"KV-cache reduction: {1 - kv1 / kv0:.1%} "
          f"(= m/K = {args.m}/{cfg.n_blocks} of attention caches)")

    # the freed cache becomes admission headroom: at a fixed byte budget the
    # continuous-batching engine runs more concurrent requests (ragged
    # prompt lengths, slots recycled as requests retire).
    from repro.launch.scheduler import nbl_slot_budget
    from repro.launch.serve import serve_requests

    max_len = 16 + args.new
    budget = 2 * cache_bytes(cfg, 1, max_len)
    rng = np.random.default_rng(7)
    ragged = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
              for n in (8, 16, 11, 14, 9, 12)]
    print(f"\n== continuous-batching engine, fixed budget {budget:,} B ==")
    for tag, (c, p) in {"baseline": (cfg, params),
                        f"nbl-{args.m}": (ncfg, nparams)}.items():
        slots = nbl_slot_budget(c, budget, max_len)
        _, stats = serve_requests(c, p, ragged, max_new=args.new,
                                  max_len=max_len, n_slots=slots)
        print(f"{tag:10s} {slots} slots  "
              f"{stats['n_decode_steps']:3d} decode sweeps  "
              f"{stats['requests_per_s']:.1f} req/s")


if __name__ == "__main__":
    main()
