"""End-to-end training driver: checkpointed, fault-tolerant, mesh-ready.

    PYTHONPATH=src python examples/train_e2e.py                  # CPU smoke
    PYTHONPATH=src python examples/train_e2e.py --preset 100m --steps 300
    PYTHONPATH=src python examples/train_e2e.py --arch gemma2-2b ...

The smoke preset (~2M params) runs a few hundred steps in minutes on one
CPU core; --preset 100m is the deliverable-scale config (~110M params,
llama-family) for a real accelerator; --arch selects any registered
architecture at full published size (production mesh assumed). Training
auto-resumes from the newest checkpoint — kill and rerun to see it.
"""
import argparse

from repro.configs import get_config
from repro.configs.base import ModelConfig, dense_stack
from repro.launch.train import train


def preset(name: str) -> ModelConfig:
    if name == "smoke":
        return ModelConfig(
            name="smoke-20m", family="dense", d_model=128, vocab_size=2048,
            stack=dense_stack(4), n_heads=4, n_kv_heads=2, head_dim=32,
            d_ff=512, param_dtype="float32", compute_dtype="float32",
            max_seq_len=256)
    if name == "100m":
        return ModelConfig(
            name="llama-110m", family="dense", d_model=768, vocab_size=32_000,
            stack=dense_stack(12), n_heads=12, n_kv_heads=4, head_dim=64,
            d_ff=2048, max_seq_len=2048)
    raise ValueError(name)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="full-size registered arch")
    ap.add_argument("--preset", default="smoke", choices=["smoke", "100m"])
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--schedule", default="cosine", choices=["cosine", "wsd"])
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.arch else preset(args.preset)
    print(f"== {cfg.name}: {cfg.n_params():,} params, {args.steps} steps, "
          f"schedule={args.schedule} ==")
    out = train(cfg, steps=args.steps, global_batch=args.batch, seq=args.seq,
                peak_lr=args.lr, schedule_name=args.schedule,
                ckpt_dir=args.ckpt, ckpt_every=50, log_every=20)
    hist = out["history"]
    print(f"loss {hist[0][1]:.3f} -> {hist[-1][1]:.3f} "
          f"in {out['wall_s']:.0f}s; checkpoints in {args.ckpt}")


if __name__ == "__main__":
    main()
