"""Minimal client for the newline-JSON TCP serving frontend.

Speaks the protocol documented in ``src/repro/launch/server.py``: submit
streaming generation requests, watch tokens arrive live, cancel one
mid-stream. Usable as a CLI demo against a running server::

    PYTHONPATH=src python -m repro.launch.server --port 0 &   # prints port
    python examples/stream_client.py --port <port> --n 3 --cancel-first 2

``--watch`` instead polls the server's ``metrics`` op and renders a
one-line live ticker (tok/s, queue depth, free pages, prefix hit-rate,
step-budget pressure + fused/legacy path tag) from the observability
registry — run it in a second terminal while traffic flows.

Also usable as a library (the CI async smoke imports ``Client`` from this
file). No repro imports — the client needs only the stdlib, like a real
remote caller would.
"""
from __future__ import annotations

import argparse
import json
import random
import socket
import sys
import time
from collections import deque
from typing import Optional


class Client:
    """One connection to the serving frontend.

    Events arrive interleaved across in-flight requests; ``events()``
    yields them in arrival order. Ops that wait for a specific reply
    (``submit``, ``stats``) buffer any events they skip past, and
    ``events()`` drains that buffer first — nothing is lost."""

    def __init__(self, host: str = "127.0.0.1", port: int = 0,
                 timeout: Optional[float] = None):
        self._sock = socket.create_connection((host, port), timeout=timeout)
        self._reader = self._sock.makefile("r", encoding="utf-8")
        self._buf: deque = deque()

    def send(self, obj: dict) -> None:
        self._sock.sendall((json.dumps(obj) + "\n").encode())

    def _recv(self) -> dict:
        line = self._reader.readline()
        if not line:
            raise ConnectionError("server closed the connection")
        return json.loads(line)

    def _wait_for(self, event: str) -> dict:
        """Next event of the given type; everything skipped is buffered —
        except "error" events, which RAISE: while waiting for a reply, an
        error is the server telling us that reply is never coming (e.g. a
        submit against a shut-down engine), and buffering past it would
        block forever."""
        while True:
            ev = self._recv()
            if ev.get("event") == event:
                return ev
            if ev.get("event") == "error":
                raise RuntimeError(f"server error: {ev.get('error')}")
            self._buf.append(ev)

    def events(self):
        """Yield events in arrival order (buffered ones first)."""
        while True:
            yield self._buf.popleft() if self._buf else self._recv()

    def submit(self, prompt, max_new: int, *, stream: bool = True,
               tag=None, spec_gamma: int = 0,
               draft_m: Optional[int] = None) -> int:
        """Submit a request; returns its rid (a rejected submission still
        gets a rid — its "done" event carries status/error).
        ``spec_gamma > 0`` opts into speculative decoding on a server
        started with ``--draft-m``; ``draft_m`` picks the registered
        drafter."""
        msg = {"op": "submit", "prompt": [int(t) for t in prompt],
               "max_new": int(max_new), "stream": stream, "tag": tag}
        if spec_gamma:
            msg["spec_gamma"] = int(spec_gamma)
            if draft_m is not None:
                msg["draft_m"] = int(draft_m)
        self.send(msg)
        return int(self._wait_for("submitted")["rid"])

    def cancel(self, rid: int) -> None:
        self.send({"op": "cancel", "rid": int(rid)})

    def stats(self) -> dict:
        self.send({"op": "stats"})
        return self._wait_for("stats")["stats"]

    def metrics(self) -> dict:
        """One observability scrape: {"enabled", "metrics" (registry
        snapshot), "prometheus" (text exposition)}."""
        self.send({"op": "metrics"})
        ev = self._wait_for("metrics")
        return {k: v for k, v in ev.items() if k != "event"}

    def shutdown(self) -> None:
        """Ask the server to drain and exit."""
        self.send({"op": "shutdown"})

    def close(self) -> None:
        try:
            # the makefile wrapper holds its own reference to the socket;
            # FIN (which tells the server to cancel anything we left in
            # flight) is only sent once both are closed
            self._reader.close()
            self._sock.close()
        except OSError:
            pass


def watch(cli: "Client", interval: float, n_polls: Optional[int],
          out=sys.stdout) -> int:
    """Live metrics ticker: polls the ``metrics`` op every ``interval``
    seconds and renders one line per poll — streamed tok/s (token-counter
    delta over the poll gap), queue depth, active slots, free pages, the
    prefix hit-rate (hits / admissions), and the fused step pipeline's
    budget pressure (the ``nbl_step_budget_utilization`` gauge, with a
    fused/legacy tag from the dispatch counters — docs/architecture.md).
    Runs ``n_polls`` times (None = until interrupted); returns the number
    of polls rendered."""
    prev_tok, prev_t, polls = None, None, 0
    while n_polls is None or polls < n_polls:
        m = cli.metrics()
        now = time.monotonic()
        if not m.get("enabled"):
            print("metrics disabled on this server (--no-obs)", file=out)
            return polls
        snap = m["metrics"]
        c, g = snap["counters"], snap["gauges"]
        tok = c.get("nbl_tokens_emitted_total", 0)
        rate = ((tok - prev_tok) / (now - prev_t)
                if prev_t is not None and now > prev_t else 0.0)
        hits = c.get("nbl_prefix_hits_total", 0)
        admitted = c.get("nbl_requests_admitted_total", 0)
        hit_rate = f"{hits / admitted:.0%}" if admitted else "-"
        # budget pressure: last step's planned tokens / step_tokens (0.0
        # when unbudgeted or on the legacy two-dispatch path)
        util = g.get("nbl_step_budget_utilization", 0.0)
        path = ("fused" if c.get("nbl_fused_dispatches_total", 0)
                else "legacy" if c.get("nbl_legacy_dispatches_total", 0)
                else "-")
        print(f"[{snap['labels'].get('engine_mode', '?')}] "
              f"{rate:8.1f} tok/s | queue {g.get('nbl_queue_depth', 0):3d}"
              f" | active {g.get('nbl_slots_active', 0):3d}"
              f" | free pages {g.get('nbl_pages_free', 0):4d}"
              f" | prefix hit {hit_rate}"
              f" | budget {util:4.0%} ({path})", file=out, flush=True)
        prev_tok, prev_t = tok, now
        polls += 1
        if n_polls is None or polls < n_polls:
            time.sleep(interval)
    return polls


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, required=True)
    ap.add_argument("--n", type=int, default=3, help="requests to submit")
    ap.add_argument("--prompt-len", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--vocab", type=int, default=256,
                    help="prompt tokens drawn from [0, vocab)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--cancel-first", type=int, default=None, metavar="K",
                    help="cancel the first request after K streamed tokens")
    ap.add_argument("--spec-gamma", type=int, default=0,
                    help="submit with speculative decoding (needs a server "
                         "started with --draft-m)")
    ap.add_argument("--draft-m", type=int, default=None,
                    help="drafter depth for --spec-gamma submissions")
    ap.add_argument("--watch", action="store_true",
                    help="poll the metrics op and render a one-line live "
                         "ticker instead of submitting requests")
    ap.add_argument("--watch-interval", type=float, default=1.0,
                    help="seconds between --watch polls")
    ap.add_argument("--watch-n", type=int, default=None, metavar="N",
                    help="stop --watch after N polls (default: forever)")
    args = ap.parse_args()

    rng = random.Random(args.seed)
    cli = Client(args.host, args.port)
    if args.watch:
        try:
            watch(cli, args.watch_interval, args.watch_n)
        except KeyboardInterrupt:
            pass
        finally:
            cli.close()
        return
    rids = [cli.submit([rng.randrange(args.vocab)
                        for _ in range(args.prompt_len)],
                       args.max_new, tag=i, spec_gamma=args.spec_gamma,
                       draft_m=args.draft_m) for i in range(args.n)]
    victim = rids[0] if args.cancel_first is not None else None
    tokens: dict = {r: [] for r in rids}
    done: dict = {}
    for ev in cli.events():
        kind = ev.get("event")
        if kind == "token":
            tokens[ev["rid"]].append(ev["token"])
            print(f"rid={ev['rid']} token[{ev['index']}]={ev['token']}")
            if ev["rid"] == victim \
                    and len(tokens[victim]) == args.cancel_first:
                print(f"cancelling rid={victim} mid-stream")
                cli.cancel(victim)
        elif kind == "done":
            done[ev["rid"]] = ev
            print(f"rid={ev['rid']} DONE status={ev['status']} "
                  f"n_tokens={len(ev['tokens'])} error={ev['error']}")
            if len(done) == len(rids):
                break
    st = cli.stats()
    print(f"server stats: n={st.get('n')} cancelled={st.get('n_cancelled')} "
          f"rejected={st.get('n_rejected')} "
          f"pages_in_use={st.get('pages_in_use', 'n/a')}")
    cli.close()


if __name__ == "__main__":
    main()
