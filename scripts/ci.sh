#!/usr/bin/env bash
# One-step CI for a bare CPU image:
#   0. static analysis: python -m repro.analysis over src/repro +
#      benchmarks + examples (guarded-by lock discipline, jit-discipline /
#      retrace hazards, hot-path host syncs incl. the perf_counter
#      ownership rule, obs-hook hygiene). Runs FIRST — it needs no jax
#      tracing and fails in ~a second. The --json report lands next to the
#      benchmark artifacts. Any finding not in scripts/analysis_baseline
#      .json (burned to empty) fails the build.
#   1. tier-1 suite (the ROADMAP verify command)
#   2. fast continuous-batching engine smoke on the tiny config
#   3. paged-engine smoke: interpret-mode paged-attention kernel vs its XLA
#      reference + paged-engine/generate() token parity on the tiny config
#   4. prefix-sharing smoke: two requests sharing a 2-page prefix — the
#      second admission prefills the suffix only (refcounted CoW pages)
#      and still exact-matches generate(); then the prefix_throughput
#      benchmark scenario under --fast
#   5. chunked-prefill smoke: a long prompt admitted one page-aligned
#      chunk per step next to two active decodes — decode tokens emitted
#      BETWEEN chunks, exact parity — then the serving-oracle fuzz suite
#      at a bounded example count (50 seeds x 6 engine modes x {sync,
#      async} x {fused, legacy} = 1200 randomized workloads vs
#      generate(), the sixth mode being engine-native speculative
#      decoding and every mode replayed through BOTH the fused
#      one-dispatch step pipeline and the legacy two-dispatch oracle —
#      docs/architecture.md), then the chunked_throughput and
#      fused_throughput benchmark scenarios under --fast (the latter
#      asserting p99 inter-token latency during long-prompt admission
#      strictly below the legacy path at equal HBM budget)
#   6. async serving smoke: the newline-JSON TCP server is started on a
#      free port, 3 overlapping requests are streamed through the
#      examples/stream_client.py Client, one is cancelled mid-stream —
#      survivors exact-match generate(), the victim's partial tokens are a
#      greedy-exact prefix, and the page pool ends with ZERO leaked pages.
#      The server runs with observability on (the default): the metrics
#      op is scraped MID-STREAM, the Prometheus exposition is parsed
#      line-by-line and key series are asserted non-zero. Then the
#      async_throughput benchmark scenario under --fast — which itself
#      asserts the obs overhead guard (registry-enabled streamed tok/s
#      within 3% of disabled + zero extra device dispatches at m=0).
#   7. speculative smoke: the server is restarted with --draft-m (the
#      NBL self-drafter registered engine-side), a spec stream and a
#      plain stream run concurrently through the client — both
#      exact-match generate(), the stats op shows bursts ran and ZERO
#      leaked pages after rollback. Then the speculative_throughput
#      benchmark scenario under --fast (calibrated drafter beating the
#      non-spec engine at equal HBM budget, in-benchmark parity).
#
#   bash scripts/ci.sh
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

echo "== static analysis: repro.analysis (4 passes, empty baseline) =="
# replaces the old grep-based perf_counter lint: the host-sync pass owns
# the "raw time.perf_counter() only under src/repro/obs/" rule now, with
# per-line suppressions instead of a magic site count
mkdir -p benchmarks/out
python -m repro.analysis src/repro benchmarks examples \
    --json benchmarks/out/analysis.json

echo "== tier-1: pytest =="
python -m pytest -x -q

echo "== engine smoke (tiny config) =="
python - <<'EOF'
import warnings; warnings.filterwarnings("ignore")
import jax, numpy as np, jax.numpy as jnp
from repro.configs import get_config
from repro.launch.engine import Engine
from repro.launch.serve import generate
from repro.models import init_params

cfg = get_config("tiny-dense")
params = init_params(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(0)
prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
           for n in (5, 9, 7)]
refs = [np.asarray(generate(cfg, params, jnp.asarray(p)[None],
                            max_new=4))[0] for p in prompts]
eng = Engine(cfg, params, max_len=16, n_slots=2)
rids = [eng.submit(p, 4) for p in prompts]
out = eng.run()
for i, rid in enumerate(rids):
    np.testing.assert_array_equal(out[rid], refs[i])
s = eng.stats()
print(f"engine smoke OK: {s['n']} requests, {s['n_decode_steps']} decode "
      f"sweeps, {s['n_slots']} slots")
EOF

echo "== paged engine smoke (tiny config, interpret-mode kernel) =="
python - <<'EOF'
import warnings; warnings.filterwarnings("ignore")
import jax, numpy as np, jax.numpy as jnp
from repro.configs import get_config
from repro.kernels.paged_attention import paged_attention, paged_decode_xla
from repro.launch.engine import Engine
from repro.launch.serve import generate
from repro.models import init_params

# interpret-mode Pallas kernel vs XLA reference (GQA + window + softcap)
rng = np.random.default_rng(0)
q = jnp.asarray(rng.standard_normal((2, 2, 2, 16)), jnp.float32)
kp = jnp.asarray(rng.standard_normal((6, 2, 8, 16)), jnp.float32)
vp = jnp.asarray(rng.standard_normal((6, 2, 8, 16)), jnp.float32)
tbl = jnp.asarray([[3, 1, -1], [5, -1, -1]], jnp.int32)
lens = jnp.asarray([11, 4], jnp.int32)
out = paged_attention(q, kp, vp, tbl, lens, window=6, softcap=30.0,
                      interpret=True)
ref = paged_decode_xla(q, kp, vp, tbl, lens, window=6, softcap=30.0)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                           atol=2e-5, rtol=2e-5)

# paged engine / generate() token parity under page pressure
cfg = get_config("tiny-dense")
params = init_params(jax.random.PRNGKey(0), cfg)
prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
           for n in (5, 9, 7)]
refs = [np.asarray(generate(cfg, params, jnp.asarray(p)[None],
                            max_new=4))[0] for p in prompts]
eng = Engine(cfg, params, max_len=16, n_slots=2, paged=True, page_size=4)
rids = [eng.submit(p, 4) for p in prompts]
outp = eng.run()
for i, rid in enumerate(rids):
    np.testing.assert_array_equal(outp[rid], refs[i])
eng.allocator.check_invariants()
s = eng.stats()
print(f"paged smoke OK: kernel==xla; {s['n']} requests, "
      f"{s['n_decode_steps']} decode sweeps, {s['n_pages']} pages, "
      f"peak {s['peak_pages_in_use']} in use")
EOF

echo "== prefix-sharing smoke (tiny config) =="
python - <<'EOF'
import warnings; warnings.filterwarnings("ignore")
import jax, numpy as np, jax.numpy as jnp
from repro.configs import get_config
from repro.launch.engine import Engine
from repro.launch.serve import generate
from repro.models import init_params

cfg = get_config("tiny-dense")
params = init_params(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(0)
sys_p = rng.integers(0, cfg.vocab_size, 17).astype(np.int32)  # >= 2 full pages
prompts = [np.concatenate([sys_p, rng.integers(0, cfg.vocab_size, n)
                           .astype(np.int32)]) for n in (4, 6)]
refs = [np.asarray(generate(cfg, params, jnp.asarray(p)[None],
                            max_new=5))[0] for p in prompts]
eng = Engine(cfg, params, max_len=48, n_slots=2, paged=True, page_size=8,
             prefix_sharing=True)
rids = [eng.submit(p, 5) for p in prompts]
out = eng.run()
for i, rid in enumerate(rids):
    np.testing.assert_array_equal(out[rid], refs[i])
s = eng.stats()
assert s["n_prefix_hits"] == 1, s          # 2nd admission hit the index
# 2nd prefill covered ONLY the suffix past the 2 shared pages (16 tokens)
assert s["n_prefill_tokens"] == len(prompts[0]) + len(prompts[1]) - 16, s
eng.allocator.check_invariants()
print(f"prefix smoke OK: {s['n']} requests, {s['n_prefix_hits']} hit, "
      f"{s['n_prefill_tokens']} tokens prefilled, "
      f"{s['n_shared_prompt_tokens']} shared")
EOF

echo "== prefix_throughput scenario (--fast) =="
python -m benchmarks.run --fast --only prefix_throughput > /dev/null
test -s benchmarks/out/prefix_throughput.json

echo "== chunked-prefill smoke (tiny config) =="
python - <<'EOF'
import warnings; warnings.filterwarnings("ignore")
import jax, numpy as np, jax.numpy as jnp
from repro.configs import get_config
from repro.launch.engine import Engine
from repro.launch.serve import generate
from repro.models import init_params

cfg = get_config("tiny-dense")
params = init_params(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(0)
shorts = [rng.integers(0, cfg.vocab_size, 4).astype(np.int32)
          for _ in range(2)]
longp = rng.integers(0, cfg.vocab_size, 24).astype(np.int32)
refs = [np.asarray(generate(cfg, params, jnp.asarray(p)[None],
                            max_new=n))[0]
        for p, n in [(shorts[0], 12), (shorts[1], 12), (longp, 4)]]

eng = Engine(cfg, params, max_len=40, n_slots=3, paged=True, page_size=4,
             chunked_prefill=True, prefill_chunk_tokens=4)
sids = [eng.submit(p, 12) for p in shorts]
eng.step(); eng.step()                     # shorts mid-decode
lid = eng.submit(longp, 4)                 # 6 chunks of 1 page each
eng.run()
s = eng.stats()
# decode tokens were emitted BETWEEN chunks (the engine-native statistic,
# validated against a hand count in tests/test_paging.py)
assert s["n_interleaved_decode_steps"] >= 3, s
for rid, want in zip(sids + [lid], refs):
    np.testing.assert_array_equal(eng.finished[rid].tokens, want)
eng.allocator.check_invariants()
print(f"chunked smoke OK: {s['n_chunks']} chunks, "
      f"{s['n_interleaved_decode_steps']} interleaved decode steps, "
      f"exact parity")
EOF

echo "== serving-oracle fuzz suite (1200 examples: 50 seeds x 6 modes x {sync,async} x {fused,legacy}) =="
NBL_FUZZ_EXAMPLES=50 python -m pytest -q tests/test_serving_fuzz.py

echo "== chunked_throughput scenario (--fast) =="
python -m benchmarks.run --fast --only chunked_throughput > /dev/null
test -s benchmarks/out/chunked_throughput.json

echo "== fused_throughput scenario (--fast, one-dispatch step vs legacy) =="
python -m benchmarks.run --fast --only fused_throughput > /dev/null
test -s benchmarks/out/fused_throughput.json

echo "== async serving smoke (TCP server: stream 3, cancel 1 mid-stream) =="
python - <<'EOF'
import warnings; warnings.filterwarnings("ignore")
import importlib.util, subprocess, sys
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_config
from repro.launch.serve import generate
from repro.models import init_params

# the server inits params from (config, seed), so this process can
# recompute generate() references for token-exact parity over the wire
# --step-delay-s widens each decode step so the mid-stream cancel below
# cannot race the victim's completion on a descheduled CI box
proc = subprocess.Popen(
    [sys.executable, "-m", "repro.launch.server", "--port", "0",
     "--config", "tiny-dense", "--seed", "0", "--max-len", "48",
     "--n-slots", "2", "--paged", "--page-size", "4",
     "--step-delay-s", "0.02"],
    stdout=subprocess.PIPE, text=True)
try:
    line = proc.stdout.readline().strip()
    assert line.startswith("LISTENING"), line
    port = int(line.split()[1])

    spec = importlib.util.spec_from_file_location(
        "stream_client", "examples/stream_client.py")
    sc = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(sc)
    cli = sc.Client("127.0.0.1", port, timeout=300)

    cfg = get_config("tiny-dense")
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (5, 9, 7)]
    new = (6, 6, 32)
    refs = [np.asarray(generate(cfg, params, jnp.asarray(p)[None],
                                max_new=mn))[0]
            for p, mn in zip(prompts, new)]

    rids = [cli.submit(p, mn, tag=i)
            for i, (p, mn) in enumerate(zip(prompts, new))]
    victim = rids[2]
    tokens = {r: [] for r in rids}; done = {}
    scrape = None
    for ev in cli.events():
        if ev["event"] == "token":
            tokens[ev["rid"]].append(ev["token"])
            if scrape is None and sum(map(len, tokens.values())) == 3:
                scrape = cli.metrics()       # obs scrape MID-STREAM
            if ev["rid"] == victim and len(tokens[victim]) == 2:
                cli.cancel(victim)           # mid-stream, from the client
        elif ev["event"] == "done":
            done[ev["rid"]] = ev
            if len(done) == 3:
                break

    # --- observability surface: mid-stream scrape is live + consistent
    import re
    assert scrape is not None and scrape["enabled"], scrape
    snap = scrape["metrics"]
    assert snap["labels"]["engine_mode"] == "paged", snap["labels"]
    assert snap["counters"]["nbl_requests_submitted_total"] == 3
    assert snap["counters"]["nbl_tokens_emitted_total"] >= 3
    assert snap["counters"]["nbl_decode_steps_total"] >= 1
    assert snap["last_step"]["n_decoding"] >= 1   # caught it mid-flight
    text = scrape["prometheus"]
    sample = re.compile(r'^[A-Za-z_:][A-Za-z0-9_:]*(\{[^}]*\})? '
                        r'[-+0-9.einfEINF]+$')
    lines = [l for l in text.splitlines() if l and not l.startswith("#")]
    assert lines and all(sample.match(l) for l in lines), lines[:5]
    nz = {l.split("{")[0] for l in lines
          if float(l.rsplit(" ", 1)[1]) > 0}
    for key in ("nbl_requests_submitted_total", "nbl_tokens_emitted_total",
                "nbl_decode_steps_total", "nbl_prefills_total",
                "nbl_ttft_seconds_count", "nbl_pages_in_use"):
        assert any(s.startswith(key) for s in nz), (key, sorted(nz))
    for i in range(2):                       # survivors: exact parity
        assert done[rids[i]]["status"] == "finished", done[rids[i]]
        np.testing.assert_array_equal(np.asarray(done[rids[i]]["tokens"]),
                                      refs[i])
    assert done[victim]["status"] == "cancelled", done[victim]
    nv = len(done[victim]["tokens"])
    assert 2 <= nv < 32                      # stopped mid-generation
    np.testing.assert_array_equal(np.asarray(done[victim]["tokens"]),
                                  refs[2][:nv])   # greedy-exact prefix
    st = cli.stats()
    assert st["pages_in_use"] == 0, st       # ZERO leaked pages
    assert st["n_cancelled"] == 1 and st["n"] == 2, st
    cli.shutdown(); cli.close()
    proc.wait(timeout=120)
    assert proc.returncode == 0, proc.returncode
    print(f"async smoke OK: 2 survivors exact, victim cancelled at {nv} "
          f"tokens, 0 leaked pages, clean server exit")
finally:
    if proc.poll() is None:
        proc.kill()
EOF

echo "== async_throughput scenario (--fast, incl. obs overhead guard) =="
python -m benchmarks.run --fast --only async_throughput > /dev/null
test -s benchmarks/out/async_throughput.json

echo "== speculative smoke (TCP server with --draft-m: spec + plain streams) =="
python - <<'EOF'
import warnings; warnings.filterwarnings("ignore")
import importlib.util, subprocess, sys
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_config
from repro.launch.serve import generate
from repro.models import init_params

# --draft-m registers the zero-map NBL self-drafter engine-side; greedy
# acceptance keeps the stream token-exact regardless of draft quality,
# so the smoke asserts PARITY through draft/verify/rollback, not speed
proc = subprocess.Popen(
    [sys.executable, "-m", "repro.launch.server", "--port", "0",
     "--config", "tiny-dense", "--seed", "0", "--max-len", "48",
     "--n-slots", "2", "--paged", "--page-size", "4", "--draft-m", "2"],
    stdout=subprocess.PIPE, text=True)
try:
    line = proc.stdout.readline().strip()
    assert line.startswith("LISTENING"), line
    port = int(line.split()[1])

    spec = importlib.util.spec_from_file_location(
        "stream_client", "examples/stream_client.py")
    sc = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(sc)
    cli = sc.Client("127.0.0.1", port, timeout=300)

    cfg = get_config("tiny-dense")
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (6, 9)]
    refs = [np.asarray(generate(cfg, params, jnp.asarray(p)[None],
                                max_new=12))[0] for p in prompts]

    # one speculative stream, one plain — mixed traffic over the wire
    rids = [cli.submit(prompts[0], 12, tag=0, spec_gamma=3, draft_m=2),
            cli.submit(prompts[1], 12, tag=1)]
    done = {}
    for ev in cli.events():
        if ev["event"] == "done":
            done[ev["rid"]] = ev
            if len(done) == 2:
                break
    for rid, want in zip(rids, refs):
        assert done[rid]["status"] == "finished", done[rid]
        np.testing.assert_array_equal(np.asarray(done[rid]["tokens"]), want)

    st = cli.stats()
    assert st["pages_in_use"] == 0, st         # rollback freed every page
    assert st["n_spec_bursts"] >= 1, st        # the spec path really ran
    assert st["n_spec_tokens"] >= 1, st
    # a spec submission that cannot fit its candidate span is rejected
    # with an error, over the wire, without killing the stream loop
    bad = cli.submit(prompts[0], 40, spec_gamma=3, draft_m=2)
    for ev in cli.events():
        if ev["event"] == "done" and ev["rid"] == bad:
            assert ev["status"] == "rejected" and "max_len" in ev["error"]
            break
    cli.shutdown(); cli.close()
    proc.wait(timeout=120)
    assert proc.returncode == 0, proc.returncode
    print(f"spec smoke OK: spec+plain exact parity, "
          f"{st['n_spec_bursts']} bursts, "
          f"{st['n_spec_accepted_tokens']} accepted, 0 leaked pages")
finally:
    if proc.poll() is None:
        proc.kill()
EOF

echo "== speculative_throughput scenario (--fast, calibrated drafter) =="
python -m benchmarks.run --fast --only speculative_throughput > /dev/null
test -s benchmarks/out/speculative_throughput.json

echo "CI OK"
