#!/usr/bin/env bash
# One-step CI for a bare CPU image:
#   1. tier-1 suite (the ROADMAP verify command)
#   2. fast continuous-batching engine smoke on the tiny config
#   3. paged-engine smoke: interpret-mode paged-attention kernel vs its XLA
#      reference + paged-engine/generate() token parity on the tiny config
#
#   bash scripts/ci.sh
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

echo "== tier-1: pytest =="
python -m pytest -x -q

echo "== engine smoke (tiny config) =="
python - <<'EOF'
import warnings; warnings.filterwarnings("ignore")
import jax, numpy as np, jax.numpy as jnp
from repro.configs import get_config
from repro.launch.engine import Engine
from repro.launch.serve import generate
from repro.models import init_params

cfg = get_config("tiny-dense")
params = init_params(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(0)
prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
           for n in (5, 9, 7)]
refs = [np.asarray(generate(cfg, params, jnp.asarray(p)[None],
                            max_new=4))[0] for p in prompts]
eng = Engine(cfg, params, max_len=16, n_slots=2)
rids = [eng.submit(p, 4) for p in prompts]
out = eng.run()
for i, rid in enumerate(rids):
    np.testing.assert_array_equal(out[rid], refs[i])
s = eng.stats()
print(f"engine smoke OK: {s['n']} requests, {s['n_decode_steps']} decode "
      f"sweeps, {s['n_slots']} slots")
EOF

echo "== paged engine smoke (tiny config, interpret-mode kernel) =="
python - <<'EOF'
import warnings; warnings.filterwarnings("ignore")
import jax, numpy as np, jax.numpy as jnp
from repro.configs import get_config
from repro.kernels.paged_attention import paged_attention, paged_decode_xla
from repro.launch.engine import Engine
from repro.launch.serve import generate
from repro.models import init_params

# interpret-mode Pallas kernel vs XLA reference (GQA + window + softcap)
rng = np.random.default_rng(0)
q = jnp.asarray(rng.standard_normal((2, 2, 2, 16)), jnp.float32)
kp = jnp.asarray(rng.standard_normal((6, 2, 8, 16)), jnp.float32)
vp = jnp.asarray(rng.standard_normal((6, 2, 8, 16)), jnp.float32)
tbl = jnp.asarray([[3, 1, -1], [5, -1, -1]], jnp.int32)
lens = jnp.asarray([11, 4], jnp.int32)
out = paged_attention(q, kp, vp, tbl, lens, window=6, softcap=30.0,
                      interpret=True)
ref = paged_decode_xla(q, kp, vp, tbl, lens, window=6, softcap=30.0)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                           atol=2e-5, rtol=2e-5)

# paged engine / generate() token parity under page pressure
cfg = get_config("tiny-dense")
params = init_params(jax.random.PRNGKey(0), cfg)
prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
           for n in (5, 9, 7)]
refs = [np.asarray(generate(cfg, params, jnp.asarray(p)[None],
                            max_new=4))[0] for p in prompts]
eng = Engine(cfg, params, max_len=16, n_slots=2, paged=True, page_size=4)
rids = [eng.submit(p, 4) for p in prompts]
outp = eng.run()
for i, rid in enumerate(rids):
    np.testing.assert_array_equal(outp[rid], refs[i])
eng.allocator.check_invariants()
s = eng.stats()
print(f"paged smoke OK: kernel==xla; {s['n']} requests, "
      f"{s['n_decode_steps']} decode sweeps, {s['n_pages']} pages, "
      f"peak {s['peak_pages_in_use']} in use")
EOF
echo "CI OK"
