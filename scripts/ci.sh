#!/usr/bin/env bash
# One-step CI for a bare CPU image:
#   1. tier-1 suite (the ROADMAP verify command)
#   2. fast continuous-batching engine smoke on the tiny config
#
#   bash scripts/ci.sh
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

echo "== tier-1: pytest =="
python -m pytest -x -q

echo "== engine smoke (tiny config) =="
python - <<'EOF'
import warnings; warnings.filterwarnings("ignore")
import jax, numpy as np, jax.numpy as jnp
from repro.configs import get_config
from repro.launch.engine import Engine
from repro.launch.serve import generate
from repro.models import init_params

cfg = get_config("tiny-dense")
params = init_params(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(0)
prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
           for n in (5, 9, 7)]
refs = [np.asarray(generate(cfg, params, jnp.asarray(p)[None],
                            max_new=4))[0] for p in prompts]
eng = Engine(cfg, params, max_len=16, n_slots=2)
rids = [eng.submit(p, 4) for p in prompts]
out = eng.run()
for i, rid in enumerate(rids):
    np.testing.assert_array_equal(out[rid], refs[i])
s = eng.stats()
print(f"engine smoke OK: {s['n']} requests, {s['n_decode_steps']} decode "
      f"sweeps, {s['n_slots']} slots")
EOF
echo "CI OK"
