"""Benchmark harness — one entry per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast]

  table_compression   Tables 2/3/4 analog: NBL vs DROP vs SLEB at equal m
                      (perplexity + successor-probe accuracy on a trained
                      small model, offline stand-in for the HF suites)
  table_calibration   Tables 1/7: Algorithm-2 calibration runtime vs d
  fig3_prefill        Figure 3: analytic prefill speed-up vs context length
  table21_kv_cache    Table 21: KV-cache bytes vs context × NBL-m
  criterion_ablation  Appendix F.3: CCA-bound vs cosine selection
  serving_throughput  throughput under load: continuous-batching engine at a
                      FIXED cache byte budget — requests/s and p50/p99
                      latency vs number of NBL-linearized layers (the freed
                      KV budget converts into concurrent slots)
  paged_throughput    paged vs ring KV management at EQUAL HBM budget on a
                      short-prompt-heavy mix: the paged engine bills pages
                      actually used instead of max_len rings, so it admits
                      more concurrent requests — requests/s, decode sweeps
                      (deterministic), pool utilization, p99 TTFT vs NBL-m
  prefix_throughput   prefix sharing (copy-on-write paged KV) vs plain
                      paged at EQUAL HBM budget on a shared-system-prompt
                      workload: suffix-only prefill (n_prefill_tokens and
                      p50 TTFT strictly lower), shared pages billed once
                      (admitted concurrency up, monotone in NBL-m), exact
                      token parity vs generate()
  chunked_throughput  chunked prefill (page-aligned prefill-decode
                      interleaving) vs non-chunked paged at EQUAL HBM
                      budget while a long prompt is admitted next to
                      active decodes: p99 inter-token latency of the
                      in-flight decodes strictly below non-chunked, long-
                      prompt TTFT within 1.2x, exact token parity, decodes
                      provably emitting BETWEEN chunks
  fused_throughput    fused one-dispatch step pipeline vs the legacy
                      two-dispatch path (docs/architecture.md) at EQUAL
                      HBM budget on the chunked-admission workload: p99
                      inter-token latency during long-prompt admission
                      strictly below legacy, one fused launch per step
                      (dispatch counters), exact token parity
  async_throughput    AsyncEngine host loop under concurrent streamed
                      submission at a FIXED HBM budget: streamed tokens/s
                      and p50/p99 queue delay (submit->admission) vs
                      NBL-m, token-exact parity of the streamed tokens vs
                      generate(), zero leaked pages after shutdown
  speculative_throughput  engine-native self-speculative decoding (Table 6
                      analog): calibrated NBL drafter sharing the target's
                      page table vs non-spec paged decode at EQUAL HBM
                      budget on single streams — tokens/s, tokens/burst,
                      acceptance vs (draft-m, γ); token-exact greedy
                      parity + zero leaked pages every pass
  kernels             µs/call of the three Pallas kernels (interpret mode —
                      CPU-emulated, structural check only)

Prints ``name,value,derived`` CSV rows; writes benchmarks/out.json plus a
stable per-scenario artifact benchmarks/out/<scenario>.json (one sorted
rows list per scenario — the trajectory-tracking unit across PRs).
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import clock

ROWS: list[tuple[str, object, str]] = []

# Every TIMED scenario runs >= this many measured passes after its warmup
# and minimizes EACH metric independently across them (latencies/runtimes:
# min; rates: computed from the min elapsed time). A single descheduling
# blip on a loaded CI box inflates a summed latency one-sidedly — best-of-3
# was observed flaking where best-of-4 with per-metric minima holds — and a
# lexicographic best-of-tuple can keep a bad TTFT because another pass had
# a lower p99. Structural metrics (slot counts, decode sweeps, prefill
# tokens) are deterministic per pass and taken from the first one.
TIMED_REPEATS = 4


def emit(name: str, value, derived: str = "") -> None:
    ROWS.append((name, value, derived))
    print(f"{name},{value},{derived}", flush=True)


# ---------------------------------------------------------------------------
def bench_compression(fast: bool) -> None:
    """Train a small LM, compress with each method, compare quality."""
    from repro.configs import get_config
    from repro.core import drop_compress, nbl_compress, sleb_compress
    from repro.data import ZipfMarkov, calib_factory
    from repro.eval import eval_suite
    from repro.launch.train import train

    cfg = get_config("tiny-dense")
    steps = 120 if fast else 300
    out = train(cfg, steps=steps, global_batch=16, seq=64, peak_lr=3e-3,
                log_every=max(steps // 3, 1), log_fn=lambda s: None)
    params = out["params"]
    proc = ZipfMarkov(cfg.vocab_size, seed=0)
    fac = calib_factory(cfg, batch=4, seq=64, n_batches=4 if fast else 8)
    evalfac = calib_factory(cfg, batch=4, seq=64, n_batches=4, seed=999)

    base = eval_suite(cfg, params, evalfac, proc.succ)
    emit("compression/baseline/ppl", round(base["ppl"], 3))
    emit("compression/baseline/succ_acc", round(base["succ_acc"], 4))

    ms = [1, 2] if fast else [1, 2, 3]
    for m in ms:
        ncfg, nparams, _ = nbl_compress(cfg, params, fac, m)
        e = eval_suite(ncfg, nparams, evalfac, proc.succ)
        emit(f"compression/attn_nbl-{m}/ppl", round(e["ppl"], 3))
        emit(f"compression/attn_nbl-{m}/succ_acc", round(e["succ_acc"], 4))

        dcfg, dparams, _ = drop_compress(cfg, params, fac, m)
        e = eval_suite(dcfg, dparams, evalfac, proc.succ)
        emit(f"compression/attn_drop-{m}/ppl", round(e["ppl"], 3))
        emit(f"compression/attn_drop-{m}/succ_acc", round(e["succ_acc"], 4))

        bcfg, bparams, _ = nbl_compress(cfg, params, fac, m, block=True)
        e = eval_suite(bcfg, bparams, evalfac, proc.succ)
        emit(f"compression/block_nbl-{m}/ppl", round(e["ppl"], 3))

    if not fast:
        scfg, sparams, _ = sleb_compress(cfg, params, fac, 2)
        e = eval_suite(scfg, sparams, evalfac, proc.succ)
        emit("compression/sleb-2/ppl", round(e["ppl"], 3))


# ---------------------------------------------------------------------------
def bench_calibration_runtime(fast: bool) -> None:
    """Algorithm-2 cost (moments→eigh→SVD→solve) vs embedding dim; the paper
    reports 26 s/layer @ d=4096 on A100 (Tables 1/7). O(d³+s·t·d²) scaling
    is asserted by the cubic fit in tests."""
    from repro.core.cca import cca_bound_from_moments
    from repro.core.lmmse import lmmse_from_moments
    from repro.core.moments import finalize, init_moments, update_moments

    dims = (256, 512) if fast else (256, 512, 1024)
    tokens = 4096
    for d in dims:
        rng = np.random.default_rng(0)
        x = rng.standard_normal((tokens, d)).astype(np.float32)
        y = (x @ (rng.standard_normal((d, d)).astype(np.float32) * 0.1))
        ts = []
        for _ in range(TIMED_REPEATS):       # min-over-repeats (see top)
            t0 = clock()
            mom = init_moments(d, d)
            for i in range(0, tokens, 1024):
                mom = update_moments(mom, x[i:i + 1024], y[i:i + 1024])
            jax.block_until_ready(mom["sxx"])
            fin = finalize(mom)
            cca_bound_from_moments(fin)
            lmmse_from_moments(fin)
            ts.append(clock() - t0)
        emit(f"calibration/layer_runtime_d{d}", round(min(ts) * 1e6, 1),
             "us_per_layer")


# ---------------------------------------------------------------------------
def bench_fig3_prefill(fast: bool) -> None:
    """Analytic prefill speed-up (K−m)·n²d + m·nd vs K·n²d (paper §4.2);
    reproduces the Fig. 3 shape: gains grow with context length."""
    K, d = 32, 4096
    for n in (2048, 8192, 32_768, 131_072):
        base = K * n * n * d
        for m in (4, 8, 12, 16):
            sped = (K - m) * n * n * d + m * n * d
            emit(f"prefill_speedup/n{n}/nbl-{m}", round(base / sped, 4),
                 "analytic")


# ---------------------------------------------------------------------------
def bench_kv_cache(fast: bool) -> None:
    """Paper Table 21: KV-cache GB for Llama-3.1-8B-class GQA at batch 64,
    half precision, vs context × NBL-m — the structural cache_bytes() is
    asserted equal to the analytic 2·bs·n·d·(g/h)·((K−m)/K) formula."""
    from repro.configs import get_config
    from repro.core.surgery import compress_config
    from repro.models.kv_cache import cache_bytes

    cfg = get_config("llama-3.1-8b").replace(compute_dtype="bfloat16")
    K = cfg.n_blocks
    for n in ((512, 4096) if fast else (512, 1024, 2048, 4096)):
        for m in (0, 4, 8, 12, 16):
            c = compress_config(cfg, cfg.attn_layer_indices()[-m:], "nbl") \
                if m else cfg
            got = cache_bytes(c, 64, n) - 4 * (K - m) * n   # minus kpos i32
            want = 2 * 64 * n * cfg.n_kv_heads * cfg.head_dim * 2 * (K - m)
            assert got == want, (got, want)
            emit(f"kv_cache/n{n}/nbl-{m}_GB", round(got / 2**30, 3),
                 "structural==analytic")


# ---------------------------------------------------------------------------
def bench_criterion_ablation(fast: bool) -> None:
    """Appendix F.3: CCA-bound vs cosine-distance selection overlap."""
    from repro.configs import get_config
    from repro.core import calibrate, rank_layers
    from repro.data import calib_factory
    from repro.models import init_params

    cfg = get_config("tiny-dense")
    params = init_params(jax.random.PRNGKey(0), cfg)
    fac = calib_factory(cfg, batch=4, seq=64, n_batches=4)
    calib = calibrate(cfg, params, fac)
    cca = rank_layers(calib, "cca")
    cos = rank_layers(calib, "cosine")
    k = 3
    overlap = len(set(cca[:k]) & set(cos[:k])) / k
    emit("criterion/cca_vs_cosine_top3_overlap", round(overlap, 3))
    emit("criterion/cca_ranking", "|".join(map(str, cca)))


# ---------------------------------------------------------------------------
def bench_serving(fast: bool) -> None:
    """Throughput under load (ROADMAP north-star scenario): the continuous-
    batching engine serves a ragged request stream at a FIXED cache byte
    budget while m attention layers are NBL-linearized. Linearized layers
    carry no KV cache, so the same budget admits ~K/(K−m)× more slots
    (launch/scheduler.nbl_slot_budget) and requests/s rises with m.
    Reported per m: slots, requests/s, tokens/s, p50/p99 latency, and the
    (deterministic) number of batched decode sweeps."""
    from repro.configs import get_config
    from repro.core.surgery import nbl_variant
    from repro.launch.engine import Engine
    from repro.launch.scheduler import latency_stats
    from repro.models import init_params
    from repro.models.kv_cache import cache_bytes

    cfg = get_config("tiny-dense")
    max_len = 64
    budget = 2 * cache_bytes(cfg, 1, max_len)      # 2 slots uncompressed
    n_req = 8 if fast else 16
    max_new = 8
    rng = np.random.default_rng(0)
    lens = rng.integers(8, 25, n_req)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in lens]

    for m in (0, 1, 2, 3):
        c = nbl_variant(cfg, m)
        params = init_params(jax.random.PRNGKey(0), c)
        eng = Engine(c, params, max_len=max_len, cache_budget_bytes=budget)
        # warmup pass: compiles every prompt-length prefill + the decode jit
        for p in prompts:
            eng.submit(p, max_new)
        eng.run()
        # timed passes on warm jits: per-metric min over TIMED_REPEATS
        dts, p50s, p99s, toks, sweeps = [], [], [], [], []
        for _ in range(TIMED_REPEATS):
            steps0 = eng.n_decode_steps
            t0 = clock()
            rids = [eng.submit(p, max_new) for p in prompts]
            eng.run()
            dts.append(clock() - t0)
            timed = [eng.finished[r] for r in rids]
            s = latency_stats(timed)
            p50s.append(s["p50_latency_s"])
            p99s.append(s["p99_latency_s"])
            toks.append(sum(len(r.tokens) for r in timed))
            sweeps.append(eng.n_decode_steps - steps0)
        emit(f"serving/nbl-{m}/n_slots", eng.n_slots, "fixed_budget")
        emit(f"serving/nbl-{m}/requests_per_s", round(n_req / min(dts), 2))
        emit(f"serving/nbl-{m}/tokens_per_s", round(toks[0] / min(dts), 1))
        emit(f"serving/nbl-{m}/p50_latency_ms", round(min(p50s) * 1e3, 1))
        emit(f"serving/nbl-{m}/p99_latency_ms", round(min(p99s) * 1e3, 1))
        assert len(set(sweeps)) == 1, sweeps     # same work every pass
        emit(f"serving/nbl-{m}/decode_sweeps", sweeps[0], "deterministic")


# ---------------------------------------------------------------------------
def bench_paged(fast: bool) -> None:
    """Paged vs ring engine at EQUAL HBM budget (tentpole scenario): a
    short-prompt-heavy mix where per-slot max_len rings strand most of their
    reservation. The paged engine converts the stranded bytes into admitted
    requests (requests/s up, decode sweeps down — the sweeps count is
    deterministic) and composes with NBL: linearized layers carry no page
    pool, so concurrency is monotone in m in BOTH engines but the paged one
    starts from page-granular accounting."""
    from repro.configs import get_config
    from repro.core.surgery import nbl_variant
    from repro.launch.engine import Engine
    from repro.launch.scheduler import latency_stats
    from repro.models import init_params
    from repro.models.kv_cache import cache_bytes

    cfg = get_config("tiny-dense")
    max_len = 64
    page_size = 8
    budget = 2 * cache_bytes(cfg, 1, max_len)      # 2 full rings
    n_req = 8 if fast else 16
    max_new = 6
    rng = np.random.default_rng(0)
    lens = rng.integers(4, 13, n_req)              # short prompts: ~18 toks
    expected = int(np.percentile(lens, 90)) + max_new
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in lens]

    for m in (0, 1, 2, 3):
        c = nbl_variant(cfg, m)
        params = init_params(jax.random.PRNGKey(0), c)
        row = {}
        for mode in ("ring", "paged"):
            kw = dict(paged=True, page_size=page_size,
                      expected_len=expected) if mode == "paged" else {}
            eng = Engine(c, params, max_len=max_len,
                         cache_budget_bytes=budget, **kw)
            for p in prompts:                      # warmup: compile jits
                eng.submit(p, max_new)
            eng.run()
            # per-metric min over TIMED_REPEATS passes on warm jits
            dts, p99s, sweeps = [], [], []
            for _ in range(TIMED_REPEATS):
                steps0 = eng.n_decode_steps
                t0 = clock()
                rids = [eng.submit(p, max_new) for p in prompts]
                eng.run()
                dts.append(clock() - t0)
                s = latency_stats([eng.finished[r] for r in rids])
                p99s.append(s["p99_ttft_s"])
                sweeps.append(eng.n_decode_steps - steps0)
            assert len(set(sweeps)) == 1, sweeps   # same work every pass
            row[mode] = (eng, sweeps[0])
            emit(f"paged/nbl-{m}/{mode}/concurrency", eng.n_slots,
                 "equal_budget")
            emit(f"paged/nbl-{m}/{mode}/requests_per_s",
                 round(n_req / min(dts), 2))
            emit(f"paged/nbl-{m}/{mode}/decode_sweeps",
                 sweeps[0], "deterministic")
            emit(f"paged/nbl-{m}/{mode}/p99_ttft_ms",
                 round(min(p99s) * 1e3, 1))
        eng_p = row["paged"][0]
        emit(f"paged/nbl-{m}/pool_utilization",
             round(eng_p.stats()["pool_utilization"], 3))
        emit(f"paged/nbl-{m}/preemptions", eng_p.n_preemptions)
        # structural claim, timing-free: page-granular admission never does
        # WORSE than ring admission on the same budget
        assert row["paged"][0].n_slots >= row["ring"][0].n_slots, \
            (m, row["paged"][0].n_slots, row["ring"][0].n_slots)
        assert row["paged"][1] <= row["ring"][1], "paged needs more sweeps"


# ---------------------------------------------------------------------------
def bench_prefix(fast: bool) -> None:
    """Prefix sharing (copy-on-write paged KV) vs plain paged at EQUAL HBM
    budget on the dominant serving pattern: every request carries the same
    system prompt plus a short unique tail. The sharing engine prefills
    each prompt's suffix only (shared pages are referenced, not recomputed
    — n_prefill_tokens drops), admits more concurrent requests (shared
    pages billed once: scheduler.nbl_page_budget) and cuts p50 TTFT, while
    emitting tokens EXACTLY equal to generate(). Composes with NBL:
    linearized layers carry no pool, so admitted concurrency stays monotone
    in m with sharing on."""
    from repro.configs import get_config
    from repro.core.surgery import nbl_variant
    from repro.launch.engine import Engine
    from repro.launch.scheduler import latency_stats
    from repro.launch.serve import generate
    from repro.models import init_params
    from repro.models.kv_cache import cache_bytes

    cfg = get_config("tiny-dense")
    max_len = 64
    page_size = 8
    budget = 2 * cache_bytes(cfg, 1, max_len)      # 2 full rings
    n_req = 12 if fast else 24
    max_new = 6
    rng = np.random.default_rng(0)
    sys_len = 32                                   # 4 shared pages
    sys_prompt = rng.integers(0, cfg.vocab_size, sys_len)
    tails = rng.integers(2, 9, n_req)
    prompts = [np.concatenate([sys_prompt,
                               rng.integers(0, cfg.vocab_size, t)])
               .astype(np.int32) for t in tails]
    expected = sys_len + int(np.percentile(tails, 90)) + max_new

    shared_slots = []
    # per-request TTFTs pooled across m, kept SEPARATE per timed repeat so
    # the final claim can take the min of the pooled p50s (per-metric
    # minima over >= 4 repeats — a single-shot pooled comparison still
    # flakes when one whole pass lands on a descheduling blip)
    ttfts = {"paged": [[] for _ in range(TIMED_REPEATS)],
             "shared": [[] for _ in range(TIMED_REPEATS)]}
    for m in (0, 1, 2, 3):
        c = nbl_variant(cfg, m)
        params = init_params(jax.random.PRNGKey(0), c)
        refs = [np.asarray(generate(c, params, jnp.asarray(p)[None],
                                    max_new=max_new))[0] for p in prompts]
        row = {}
        for mode in ("paged", "shared"):
            kw = dict(paged=True, page_size=page_size, expected_len=expected)
            if mode == "shared":
                kw.update(prefix_sharing=True, shared_prefix_len=sys_len)
            eng = Engine(c, params, max_len=max_len,
                         cache_budget_bytes=budget, **kw)
            for p in prompts:                      # warmup: compile jits and
                eng.submit(p, max_new)             # (shared) seed the index
            eng.run()
            hit0, shr0 = eng.n_prefix_hits, eng.n_shared_prompt_tokens
            dts, p50s, ptoks_reps = [], [], []
            for rep in range(TIMED_REPEATS):
                tok0 = eng.n_prefill_tokens
                t0 = clock()
                rids = [eng.submit(p, max_new) for p in prompts]
                out = eng.run()
                dts.append(clock() - t0)
                for rid, want in zip(rids, refs):  # exact parity, both modes
                    np.testing.assert_array_equal(out[rid], want)
                s = latency_stats([eng.finished[r] for r in rids])
                p50s.append(s["p50_ttft_s"])
                ttfts[mode][rep] += [eng.finished[r].ttft for r in rids]
                ptoks_reps.append(eng.n_prefill_tokens - tok0)
            assert len(set(ptoks_reps)) == 1, ptoks_reps  # deterministic
            ptoks = ptoks_reps[0]
            row[mode] = (eng, ptoks)
            emit(f"prefix/nbl-{m}/{mode}/concurrency", eng.n_slots,
                 "equal_budget")
            emit(f"prefix/nbl-{m}/{mode}/n_prefill_tokens", ptoks,
                 "deterministic")
            emit(f"prefix/nbl-{m}/{mode}/requests_per_s",
                 round(n_req / min(dts), 2))
            emit(f"prefix/nbl-{m}/{mode}/p50_ttft_ms",
                 round(min(p50s) * 1e3, 2))
        eng_s = row["shared"][0]
        emit(f"prefix/nbl-{m}/prefix_hits",
             (eng_s.n_prefix_hits - hit0) // TIMED_REPEATS, "per_pass")
        emit(f"prefix/nbl-{m}/shared_prompt_tokens",
             (eng_s.n_shared_prompt_tokens - shr0) // TIMED_REPEATS,
             "per_pass")
        shared_slots.append(eng_s.n_slots)
        # structural claims, exact-token-parity already asserted above:
        # sharing prefills strictly fewer tokens and never admits less
        assert row["shared"][1] < row["paged"][1], \
            (m, row["shared"][1], row["paged"][1])
        assert row["shared"][0].n_slots >= row["paged"][0].n_slots
    assert shared_slots == sorted(shared_slots), shared_slots
    # timing claim, gated on the per-request TTFTs POOLED across every m
    # (a per-m p50 comparison is load-sensitive on a shared CI box; the
    # pooled median is dominated by queueing structure, not noise) with the
    # pooled p50 minimized over the timed repeats per mode
    p50_s = min(float(np.percentile(t, 50)) for t in ttfts["shared"])
    p50_p = min(float(np.percentile(t, 50)) for t in ttfts["paged"])
    assert p50_s < p50_p, (p50_s, p50_p)
    emit("prefix/pooled_p50_ttft_ms/shared", round(p50_s * 1e3, 2))
    emit("prefix/pooled_p50_ttft_ms/paged", round(p50_p * 1e3, 2))
    emit("prefix/shared_concurrency_monotone_in_m", 1, "assert")


# ---------------------------------------------------------------------------
def bench_chunked(fast: bool) -> None:
    """Chunked prefill vs non-chunked paged at EQUAL HBM budget (the
    prefill-decode interleaving claim): two short requests are mid-decode
    when a long prompt arrives. Non-chunked, the admission step runs the
    whole prompt's prefill serially — every active decode stalls for it,
    and that stall IS the decodes' inter-token latency spike. Chunked, at
    most one page-aligned chunk runs per step, so decodes keep emitting
    between chunks: p99 inter-token latency during the admission window
    must be STRICTLY below non-chunked, the long prompt's TTFT within
    1.2x, and every request's tokens exactly equal generate()'s."""
    from repro.configs import get_config
    from repro.launch.engine import Engine
    from repro.launch.serve import generate
    from repro.models import init_params
    from repro.models.kv_cache import cache_bytes

    cfg = get_config("tiny-dense")
    max_len, page_size = 1024, 64
    # the long prompt must be big enough that prefill COMPUTE (not
    # per-step dispatch overhead) dominates, or the TTFT comparison
    # measures the host loop: at 768 tokens the 3 chunks skip the full
    # prefill's masked upper triangle and chunked TTFT lands ~0.5-0.6x
    # non-chunked; --fast only trims the timed repetitions
    long_len, chunk = 768, 256
    short_len, short_new, long_new = 16, 40, 8
    budget = 3 * cache_bytes(cfg, 1, max_len)      # 3 full reservations
    rng = np.random.default_rng(0)
    shorts = [rng.integers(0, cfg.vocab_size, short_len).astype(np.int32)
              for _ in range(2)]
    longp = rng.integers(0, cfg.vocab_size, long_len).astype(np.int32)
    params = init_params(jax.random.PRNGKey(0), cfg)
    refs = [np.asarray(generate(cfg, params, jnp.asarray(p)[None],
                                max_new=n))[0]
            for p, n in [(shorts[0], short_new), (shorts[1], short_new),
                         (longp, long_new)]]

    def run_once(chunked: bool):
        kw = dict(paged=True, page_size=page_size, expected_len=max_len)
        if chunked:
            kw.update(chunked_prefill=True, prefill_chunk_tokens=chunk)
        eng = Engine(cfg, params, max_len=max_len,
                     cache_budget_bytes=budget, **kw)
        sids = [eng.submit(p, short_new) for p in shorts]
        for _ in range(3):                         # shorts mid-decode
            eng.step()
        lid = eng.submit(longp, long_new)
        gaps = []
        long_first = None
        while eng.has_work:
            t0 = clock()
            eng.step()
            dt = clock() - t0
            req = eng.finished.get(lid) or next(
                (r for r in eng.slot_req
                 if r is not None and r.rid == lid), None)
            if long_first is None:
                # the admission window: every step until the long prompt's
                # first token is a decode gap the short requests ate
                gaps.append(dt)
                if req is not None and req.t_first:
                    long_first = req.t_first - req.t_submit
        outs = {rid: np.asarray(eng.finished[rid].tokens, np.int32)
                for rid in sids + [lid]}
        for got, want in zip([outs[sids[0]], outs[sids[1]], outs[lid]],
                             refs):                # exact parity, each mode
            np.testing.assert_array_equal(got, want)
        interleaved = eng.n_interleaved_decode_steps
        return eng, gaps, long_first, interleaved

    rows = {}
    for mode, chunked in (("paged", False), ("chunked", True)):
        run_once(chunked)                          # warmup: compile jits
        # TIMED_REPEATS passes, with p99-ITL and TTFT minimized
        # INDEPENDENTLY: both are sums/maxima over steps, so a single
        # descheduling blip on a loaded CI box inflates them one-sidedly
        # — per-claim minima estimate the latency structure under test,
        # not the box's background load
        p99s, ttfts, inters = [], [], []
        for _ in range(TIMED_REPEATS):
            eng, gaps, ttft, interleaved = run_once(chunked)
            p99s.append(float(np.percentile(gaps, 99)))
            ttfts.append(ttft)
            inters.append(interleaved)
        p99, ttft, interleaved = min(p99s), min(ttfts), max(inters)
        rows[mode] = (p99, ttft, interleaved)
        emit(f"chunked/{mode}/concurrency", eng.n_slots, "equal_budget")
        emit(f"chunked/{mode}/p99_itl_ms", round(p99 * 1e3, 2),
             "long_admission_window")
        emit(f"chunked/{mode}/long_ttft_ms", round(ttft * 1e3, 2))
        if chunked:
            emit("chunked/n_chunks", eng.n_chunks, "deterministic")
            emit("chunked/interleaved_steps", interleaved, "deterministic")
    # structural + latency claims (parity already asserted per mode):
    # chunking strictly caps the decode stall, within 1.2x TTFT, and the
    # decodes demonstrably emitted between chunks
    assert rows["chunked"][0] < rows["paged"][0], rows
    assert rows["chunked"][1] <= 1.2 * rows["paged"][1], rows
    assert rows["chunked"][2] >= 1, rows
    emit("chunked/p99_itl_ratio",
         round(rows["paged"][0] / rows["chunked"][0], 2), "assert_gt_1")


# ---------------------------------------------------------------------------
def bench_fused(fast: bool) -> None:
    """Fused one-dispatch step vs the legacy two-dispatch path at EQUAL
    HBM budget (docs/architecture.md): both engines run chunked prefill
    over the same workload — two short requests mid-decode when a long
    prompt arrives — each paced by its own ONLY knob. Legacy runs
    prefill_chunk_tokens-sized chunks, launching the chunk's prefill jit
    AND the batched decode jit each interleaved step; the fused engine
    runs under a decode-priority step_tokens budget (decoders charged
    first, the remainder funding one page of chunk progress), folding
    chunk + decodes into ONE mixed dispatch whose width the budget keeps
    at a single page. Bounded per-step work + the dropped second launch
    and readback is exactly what the budget buys: p99 inter-token latency
    during the long prompt's admission window must be STRICTLY below the
    two-dispatch path, with exact generate() parity, and the dispatch
    counters prove the one-dispatch contract per step. The price is the
    long prompt's TTFT (more, smaller chunks) — reported, not asserted:
    the budget is the latency/TTFT dial."""
    from repro.configs import get_config
    from repro.launch.engine import Engine
    from repro.launch.serve import generate
    from repro.models import init_params
    from repro.models.kv_cache import cache_bytes

    cfg = get_config("tiny-dense")
    max_len, page_size = 1024, 32
    long_len, chunk = 768, 256
    short_len, short_new, long_new = 16, 40, 8
    budget = 3 * cache_bytes(cfg, 1, max_len)      # 3 full reservations
    rng = np.random.default_rng(0)
    shorts = [rng.integers(0, cfg.vocab_size, short_len).astype(np.int32)
              for _ in range(2)]
    longp = rng.integers(0, cfg.vocab_size, long_len).astype(np.int32)
    params = init_params(jax.random.PRNGKey(0), cfg)
    refs = [np.asarray(generate(cfg, params, jnp.asarray(p)[None],
                                max_new=n))[0]
            for p, n in [(shorts[0], short_new), (shorts[1], short_new),
                         (longp, long_new)]]

    # the fused engine's pacing knob: every decoder funded first, the
    # remainder grants the chunking row exactly one page per step
    step_tokens = page_size + len(shorts)

    def run_once(fused: bool):
        eng = Engine(cfg, params, max_len=max_len, paged=True,
                     page_size=page_size, expected_len=max_len,
                     chunked_prefill=True, prefill_chunk_tokens=chunk,
                     cache_budget_bytes=budget, fused_step=fused,
                     step_tokens=step_tokens if fused else None)
        assert eng.fused == fused
        sids = [eng.submit(p, short_new) for p in shorts]
        for _ in range(3):                         # shorts mid-decode
            eng.step()
        lid = eng.submit(longp, long_new)
        gaps = []
        long_first = None
        while eng.has_work:
            d0 = eng.n_fused_dispatches
            t0 = clock()
            eng.step()
            dt = clock() - t0
            # the one-dispatch contract, step by step (the PR 6 dispatch
            # counter machinery): never a second fused launch
            assert eng.n_fused_dispatches - d0 <= 1
            req = eng.finished.get(lid) or next(
                (r for r in eng.slot_req
                 if r is not None and r.rid == lid), None)
            if long_first is None:
                gaps.append(dt)
                if req is not None and req.t_first:
                    long_first = req.t_first - req.t_submit
        outs = {rid: np.asarray(eng.finished[rid].tokens, np.int32)
                for rid in sids + [lid]}
        for got, want in zip([outs[sids[0]], outs[sids[1]], outs[lid]],
                             refs):                # exact parity, each mode
            np.testing.assert_array_equal(got, want)
        if fused:
            assert eng.n_fused_dispatches > 0
            assert eng.n_legacy_dispatches == 0
        else:
            assert eng.n_fused_dispatches == 0
            assert eng.n_legacy_dispatches > 0
        return eng, gaps, long_first

    rows = {}
    for mode, fused in (("legacy", False), ("fused", True)):
        run_once(fused)                            # warmup: compile jits
        p99s, ttfts = [], []
        for _ in range(TIMED_REPEATS):             # per-claim minima, as
            eng, gaps, ttft = run_once(fused)      # in bench_chunked
            p99s.append(float(np.percentile(gaps, 99)))
            ttfts.append(ttft)
        p99, ttft = min(p99s), min(ttfts)
        rows[mode] = (p99, ttft)
        emit(f"fused/{mode}/p99_itl_ms", round(p99 * 1e3, 2),
             "long_admission_window")
        emit(f"fused/{mode}/long_ttft_ms", round(ttft * 1e3, 2))
        emit(f"fused/{mode}/dispatches",
             eng.n_fused_dispatches or eng.n_legacy_dispatches,
             "deterministic")
        if fused:
            emit("fused/interleaved_steps", eng.n_interleaved_decode_steps,
                 "deterministic")
            emit("fused/step_tokens", step_tokens, "deterministic")
            emit("fused/budget_utilization",
                 round(eng.stats()["step_budget_utilization"], 3))
    # the budget-bounded one-dispatch step strictly caps the legacy
    # chunk-step decode stall
    assert rows["fused"][0] < rows["legacy"][0], rows
    emit("fused/p99_itl_ratio",
         round(rows["legacy"][0] / rows["fused"][0], 2), "assert_gt_1")


# ---------------------------------------------------------------------------
def bench_async(fast: bool) -> None:
    """Async host loop under concurrent streamed traffic at a FIXED HBM
    budget vs NBL-m: client threads submit through AsyncEngine.submit_stream
    while the background step thread serves, measuring streamed tokens/s
    end-to-end (submission -> last stream closed) and p50/p99 QUEUE DELAY
    (submit -> admission wait — the metric backpressure acts on). Every
    pass asserts token-exact generate() parity on the streamed tokens and
    a zero-leak pool after shutdown; linearized layers carry no page pool,
    so admitted concurrency is monotone in m at equal budget and the queue
    drains wider.

    Runs with the observability registry ATTACHED: the artifact's token
    count is the registry's ``nbl_tokens_emitted_total`` (cross-validated
    against the hand count from the streams every pass), and at m=0 the
    scenario asserts the two obs acceptance bounds — streamed tok/s with
    the registry enabled within 3% of disabled (per-metric minima over
    TIMED_REPEATS on BOTH sides), and zero extra device dispatches on the
    step path (obs on/off produce identical deterministic sweep counts on
    a sync engine replay)."""
    import threading

    from repro.configs import get_config
    from repro.core.surgery import nbl_variant
    from repro.launch.engine import AsyncEngine, Engine
    from repro.launch.serve import generate
    from repro.models import init_params
    from repro.models.kv_cache import cache_bytes
    from repro.obs import Observability

    cfg = get_config("tiny-dense")
    max_len, page_size = 64, 8
    budget = 2 * cache_bytes(cfg, 1, max_len)      # 2 full rings
    n_req = 8 if fast else 16
    max_new = 8
    n_client_threads = 4
    rng = np.random.default_rng(0)
    lens = rng.integers(6, 21, n_req)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in lens]
    expected = int(np.percentile(lens, 90)) + max_new

    slots_by_m = []
    for m in (0, 1, 2, 3):
        c = nbl_variant(cfg, m)
        params = init_params(jax.random.PRNGKey(0), c)
        refs = [np.asarray(generate(c, params, jnp.asarray(p)[None],
                                    max_new=max_new))[0] for p in prompts]

        def run_once(with_obs: bool = True):
            obs = Observability() if with_obs else None
            eng = Engine(c, params, max_len=max_len,
                         cache_budget_bytes=budget, paged=True,
                         page_size=page_size, expected_len=expected,
                         obs=obs)
            aeng = AsyncEngine(eng, max_pending=2 * n_req)
            streams = [None] * n_req
            t0 = clock()

            def client(tid):                 # round-robin request sharding
                for i in range(tid, n_req, n_client_threads):
                    streams[i] = aeng.submit_stream(prompts[i], max_new)
                for i in range(tid, n_req, n_client_threads):
                    streams[i].result(timeout=300)

            ts = [threading.Thread(target=client, args=(t,))
                  for t in range(n_client_threads)]
            for t in ts:
                t.start()
            for t in ts:
                t.join(300)
            dt = clock() - t0
            aeng.shutdown(drain=True)
            ntok = 0
            for s, want in zip(streams, refs):
                got = s.result(timeout=1)
                np.testing.assert_array_equal(got, want)  # streamed == ref
                ntok += len(got)
            assert eng.allocator.in_use == 0   # zero leaked pages
            if obs is not None:
                # the artifact's token count is the REGISTRY's view; the
                # hand count from the streams only cross-validates it
                assert obs.tokens.value == ntok, (obs.tokens.value, ntok)
                assert obs.finished.value == n_req
            qd = np.array([eng.finished[s.rid].t_admit
                           - eng.finished[s.rid].t_submit for s in streams])
            return eng, obs, dt, ntok, qd

        run_once()                             # warmup: compile jits
        n_slots, dts, p50s, p99s, ntok = None, [], [], [], 0
        for _ in range(TIMED_REPEATS):         # per-metric min (see top)
            eng, obs, dt, ntok, qd = run_once()
            n_slots = eng.n_slots
            dts.append(dt)
            p50s.append(float(np.percentile(qd, 50)))
            p99s.append(float(np.percentile(qd, 99)))
        slots_by_m.append(n_slots)
        emit(f"async/nbl-{m}/concurrency", n_slots, "equal_budget")
        emit(f"async/nbl-{m}/streamed_tokens_per_s",
             round(ntok / min(dts), 1), "registry")
        emit(f"async/nbl-{m}/p50_queue_delay_ms",
             round(min(p50s) * 1e3, 2))
        emit(f"async/nbl-{m}/p99_queue_delay_ms",
             round(min(p99s) * 1e3, 2))
        if m == 0:
            rate_on = ntok / min(dts)
            # overhead guard: same workload with obs=None, per-metric min
            off_dts = []
            for _ in range(TIMED_REPEATS):
                _, _, dt, ntok_off, _ = run_once(with_obs=False)
                off_dts.append(dt)
            assert ntok_off == ntok, (ntok_off, ntok)   # same tokens served
            rate_off = ntok_off / min(off_dts)
            over_pct = (rate_off - rate_on) / rate_off * 100.0
            assert rate_on >= 0.97 * rate_off, (rate_on, rate_off)
            emit("async/obs_overhead_pct", round(over_pct, 2), "assert_le_3")
            # dispatch guard: every obs hook is host-side, so a DETERMINISTIC
            # sync replay must do identical device work with obs on vs off —
            # sweep counts, prefill counts/tokens, and the tokens themselves
            sweep = {}
            for on in (True, False):
                o = Observability() if on else None
                e = Engine(c, params, max_len=max_len,
                           cache_budget_bytes=budget, paged=True,
                           page_size=page_size, expected_len=expected, obs=o)
                rids = [e.submit(p, max_new) for p in prompts]
                out = e.run()
                sweep[on] = (e.n_decode_steps, e.n_prefills,
                             e.n_prefill_tokens,
                             tuple(tuple(out[r]) for r in rids))
                if o is not None:
                    assert o.decode_steps.value == e.n_decode_steps
                    assert o.prefills.value == e.n_prefills
            assert sweep[True] == sweep[False], "obs changed device work"
            emit("async/obs_zero_extra_dispatches", 1, "assert")
    # structural claims (parity + zero-leak asserted inside every pass)
    assert slots_by_m == sorted(slots_by_m), slots_by_m
    emit("async/concurrency_monotone_in_m", 1, "assert")
    # the scenario artifact carries the last pass's full registry snapshot
    # (obs here is the TIMED_REPEATS loop's last binding, from run_once()
    # with the with_obs=True default — never None on this path)
    return {"registry": obs.snapshot()}  # nbl: disable=obs-hygiene -- bound by run_once(with_obs=True)


# ---------------------------------------------------------------------------
def bench_kernels(fast: bool) -> None:
    from repro.kernels import ops

    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 4, 256, 64))
    k = jax.random.normal(key, (1, 2, 256, 64))
    v = jax.random.normal(key, (1, 2, 256, 64))
    x = jax.random.normal(key, (1, 512, 256))
    w = jax.random.normal(key, (256, 256)) * 0.05
    b = jnp.zeros((256,))
    acc = jnp.zeros((256, 256))

    for name, fn in [
        ("flash_attention", lambda: ops.attention(q, k, v)),
        ("nbl_linear", lambda: ops.nbl_apply(x, w, b)),
        ("cov_accum", lambda: ops.cov_update(acc, x[0])),
    ]:
        fn()  # compile
        ts = []
        for _ in range(TIMED_REPEATS):       # min-over-repeats (see top)
            t0 = clock()
            jax.block_until_ready(fn())
            ts.append(clock() - t0)
        emit(f"kernels/{name}", round(min(ts) * 1e6, 1),
             "us_per_call_interpret")


# ---------------------------------------------------------------------------
def bench_spec_throughput(fast: bool) -> None:
    """Table 6 analog, engine-native: self-speculative decoding (the SAME
    trained params under a deeper NBL plan drafting through the target's
    own page table) vs non-spec paged decode at EQUAL HBM budget on a
    single-stream workload — the latency scenario speculation targets.
    The drafter's linear maps are CALIBRATED (core.calibrate on the
    deepest-m attention layers), because acceptance is what converts the
    2-dispatch burst (one scanned γ-token draft + one batched verify)
    into >1 token per step. Reported per (draft-m, γ): tokens/s,
    tokens/burst, acceptance; draft-m=0 (the target drafting for itself,
    acceptance 1) bounds the machinery's ceiling. Every timed pass
    asserts token-exact generate() parity and a drained, zero-leak pool;
    the headline asserts a CALIBRATED draft (m >= 1) emits > 1
    token/burst and beats the non-spec engine's tokens/s."""
    from repro.configs import get_config
    from repro.core import calibrate
    from repro.data import ZipfMarkov, calib_factory
    from repro.launch.engine import Engine
    from repro.launch.serve import generate
    from repro.launch.speculative import make_nbl_draft
    from repro.launch.train import train

    cfg = get_config("tiny-dense")
    params = train(cfg, steps=120 if fast else 200, global_batch=16, seq=64,
                   peak_lr=3e-3, log_fn=lambda s: None)["params"]
    fac = calib_factory(cfg, batch=4, seq=64, n_batches=4)
    calib = calibrate(cfg, params, fac)

    from repro.models.kv_cache import cache_bytes

    max_len, page_size = 64, 8
    budget = 2 * cache_bytes(cfg, 1, max_len)      # 2 full rings, both sides
    n_req = 4 if fast else 8
    max_new = 24                       # decode-dominated single streams
    proc = ZipfMarkov(cfg.vocab_size, seed=0)
    prompts = [np.asarray(p, np.int32) for p in proc.sample(n_req, 12,
                                                            seed=3)]
    refs = [np.asarray(generate(cfg, params, jnp.asarray(p)[None],
                                max_new=max_new))[0] for p in prompts]

    def run_sweep(eng, gamma, draft_m):
        """One sequential pass over the stream; asserts parity + zero
        leak. Sequential submit->drain is the single-stream latency
        shape: batched decode cannot hide the per-token dispatch."""
        t0 = clock()
        for p, want in zip(prompts, refs):
            rid = eng.submit(p, max_new, spec_gamma=gamma,
                             draft_m=draft_m)
            out = eng.run()
            np.testing.assert_array_equal(out[rid], want)
        dt = clock() - t0
        assert eng.allocator.in_use == 0
        return dt

    ms = ((0, 1, 2) if fast else (0, 1, 2, 3))
    gammas = (4,) if fast else (2, 4)
    drafts = {m: make_nbl_draft(
        cfg, params, m,
        linear_maps={i: calib[i].linear
                     for i in cfg.attn_layer_indices()[-m:]} if m else None)
        for m in ms}

    # non-spec baseline: same budget, same stream, plain paged decode
    eng = Engine(cfg, params, max_len=max_len, cache_budget_bytes=budget,
                 paged=True, page_size=page_size)
    run_sweep(eng, 0, None)                       # warmup: compile jits
    dts = [run_sweep(eng, 0, None) for _ in range(TIMED_REPEATS)]
    ntok = n_req * max_new
    base_rate = ntok / min(dts)
    emit("spec/baseline/tokens_per_s", round(base_rate, 1), "equal_budget")

    best = {}                                     # m -> best tok/s
    tpb = {}                                      # m -> tokens/burst at best
    for m in ms:
        for gamma in gammas:
            eng = Engine(cfg, params, max_len=max_len,
                         cache_budget_bytes=budget, paged=True,
                         page_size=page_size, drafts={m: drafts[m]})
            run_sweep(eng, gamma, m)              # warmup: compile jits
            b0, t0 = eng.n_spec_bursts, eng.n_spec_tokens
            a0, d0 = eng.n_spec_accepted_tokens, eng.n_spec_draft_tokens
            dts, bursts = [], []
            for _ in range(TIMED_REPEATS):
                s0 = eng.n_spec_bursts
                dts.append(run_sweep(eng, gamma, m))
                bursts.append(eng.n_spec_bursts - s0)
            assert len(set(bursts)) == 1, bursts  # same work every pass
            rate = ntok / min(dts)
            per_burst = (eng.n_spec_tokens - t0) / max(eng.n_spec_bursts
                                                       - b0, 1)
            acc = (eng.n_spec_accepted_tokens - a0) / max(
                eng.n_spec_draft_tokens - d0, 1)
            emit(f"spec/nbl-{m}/gamma-{gamma}/tokens_per_s",
                 round(rate, 1), "equal_budget")
            emit(f"spec/nbl-{m}/gamma-{gamma}/tokens_per_burst",
                 round(per_burst, 2), "deterministic")
            emit(f"spec/nbl-{m}/gamma-{gamma}/acceptance",
                 round(acc, 3), "deterministic")
            if rate > best.get(m, 0.0):
                best[m], tpb[m] = rate, per_burst
    # headline: a CALIBRATED self-draft multiplies tokens per step AND
    # converts it into throughput over the non-spec engine (parity and
    # zero-leak already asserted inside every pass)
    winner = max((m for m in best if m >= 1), key=lambda m: best[m])
    assert tpb[winner] > 1.0, (winner, tpb)
    assert best[winner] > base_rate, (winner, best[winner], base_rate)
    emit("spec/best_calibrated_m", winner, "assert_beats_baseline")
    emit("spec/speedup_vs_baseline",
         round(best[winner] / base_rate, 2), "assert_gt_1")


def bench_quant_compose(fast: bool) -> None:
    """Table 5 analog (§4.3): NBL on a weight-quantized model. Reports the
    byte compression and the ppl of fp / int8 / int8+NBL (int4 in full
    mode, matching the paper's AWQ-4bit 70B setup)."""
    from repro.configs import get_config
    from repro.core import nbl_compress
    from repro.data import calib_factory
    from repro.eval import perplexity
    from repro.launch.train import train
    from repro.quant import quantize_model

    cfg = get_config("tiny-dense")
    params = train(cfg, steps=120, global_batch=16, seq=64, peak_lr=3e-3,
                   log_fn=lambda s: None)["params"]
    fac = calib_factory(cfg, batch=4, seq=64, n_batches=4)
    evalfac = calib_factory(cfg, batch=4, seq=64, n_batches=2, seed=77)
    emit("quant/fp/ppl", round(perplexity(cfg, params, evalfac), 3))
    for bits in ((8,) if fast else (8, 4)):
        qp, rep = quantize_model(cfg, params, bits=bits)
        emit(f"quant/int{bits}/ppl",
             round(perplexity(cfg, qp, evalfac), 3))
        emit(f"quant/int{bits}/compression",
             round(rep.fp_bytes / max(rep.q_bytes, 1), 2))
        ncfg, np_, _ = nbl_compress(cfg, qp, fac, 2)
        emit(f"quant/int{bits}+nbl-2/ppl",
             round(perplexity(ncfg, np_, evalfac), 3))


def bench_lora(fast: bool) -> None:
    """Appendix F.2: LoRA refinement of NBL layers — marginal by design."""
    from repro.configs import get_config
    from repro.core import nbl_compress
    from repro.core.lora import lora_finetune
    from repro.data import calib_factory
    from repro.eval import perplexity
    from repro.launch.train import train

    cfg = get_config("tiny-dense")
    params = train(cfg, steps=120, global_batch=16, seq=64, peak_lr=3e-3,
                   log_fn=lambda s: None)["params"]
    fac = calib_factory(cfg, batch=4, seq=64, n_batches=4)
    ncfg, nparams, _ = nbl_compress(cfg, params, fac, 2)
    evalfac = calib_factory(ncfg, batch=4, seq=64, n_batches=2, seed=99)
    emit("lora/nbl-2/ppl", round(perplexity(ncfg, nparams, evalfac), 3))
    tuned = lora_finetune(ncfg, nparams, fac, steps=15 if fast else 30,
                          rank=4, lr=5e-4)
    emit("lora/nbl-2+lora/ppl", round(perplexity(ncfg, tuned, evalfac), 3))


BENCHES = {
    "table_compression": bench_compression,
    "table_calibration": bench_calibration_runtime,
    "fig3_prefill": bench_fig3_prefill,
    "table21_kv_cache": bench_kv_cache,
    "criterion_ablation": bench_criterion_ablation,
    "serving_throughput": bench_serving,
    "paged_throughput": bench_paged,
    "prefix_throughput": bench_prefix,
    "chunked_throughput": bench_chunked,
    "fused_throughput": bench_fused,
    "async_throughput": bench_async,
    "speculative_throughput": bench_spec_throughput,
    "quant_compose": bench_quant_compose,
    "lora": bench_lora,
    "kernels": bench_kernels,
}


def _provenance() -> dict:
    """Where this artifact came from: git SHA (best-effort — "unknown"
    outside a checkout), UTC timestamp, and the repeat count every timed
    metric was minimized over."""
    import datetime
    import subprocess
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        ).stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError):
        sha = "unknown"
    ts = datetime.datetime.now(datetime.timezone.utc)
    return {"git_sha": sha,
            "timestamp_utc": ts.isoformat(timespec="seconds"),
            "timed_repeats": TIMED_REPEATS}


def write_scenario_artifact(name: str, rows: list, extra: dict = None) -> str:
    """One stable JSON artifact per scenario under benchmarks/out/ — a
    sorted rows list with a fixed schema, so successive PRs can diff the
    same file path for trajectory tracking. Schema v2 adds provenance
    (git SHA, timestamp, repeats) and lets a scenario attach extra
    derived views (e.g. the observability registry snapshot)."""
    outdir = os.path.join(os.path.dirname(__file__), "out")
    os.makedirs(outdir, exist_ok=True)
    path = os.path.join(outdir, f"{name}.json")
    payload = {"schema_version": 2,
               "scenario": name,
               "provenance": _provenance(),
               "rows": sorted(({"name": n, "value": v, "derived": d}
                               for n, v, d in rows), key=lambda r: r["name"])}
    for k, v in (extra or {}).items():
        payload.setdefault(k, v)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
        f.write("\n")
    return path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    names = [args.only] if args.only else list(BENCHES)
    print("name,value,derived")
    for name in names:
        start = len(ROWS)
        extra = BENCHES[name](args.fast)
        write_scenario_artifact(name, ROWS[start:], extra)
    out = os.path.join(os.path.dirname(__file__), "out.json")
    with open(out, "w") as f:
        json.dump([{"name": n, "value": v, "derived": d}
                   for n, v, d in ROWS], f, indent=1)


if __name__ == "__main__":
    main()
